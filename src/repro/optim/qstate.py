"""8-bit block-quantized Adam moments (memory-bound giant-model configs).

For the ≥300 B assigned architectures (deepseek-v3-671b, jamba-1.5-large)
fp32 Adam moments alone exceed per-chip HBM even at 256-way sharding.
This transform stores (m, v) as int8 codes with per-block fp32 absmax
scales (block = 128 along the LAST dim), an ~8× reduction.

Moments are *shape-preserving*: codes keep the parameter's rank (last dim
padded to the block multiple), so under pjit they inherit the parameter's
PartitionSpec verbatim — a flat layout would force a full re-shard
(all-gather of the entire moment tensor) between the optimizer update and
the parameter application, measured at 436 GB/device on deepseek-v3
(§Perf iteration log).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import Optimizer, chain, scale, \
    clip_by_global_norm, add_decayed_weights

PyTree = Any
_BLOCK = 128


def _padded(n: int) -> int:
    return n + (-n) % _BLOCK


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """float (..., N) → (int8 codes (..., Np), fp32 scales (..., Np/B))."""
    n = x.shape[-1]
    pad = _padded(n) - n
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xp.shape[:-1], -1, _BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=-1)
    safe = jnp.where(scales == 0, 1.0, scales)[..., None]
    codes = jnp.clip(jnp.round(blocks / safe * 127.0), -127, 127
                     ).astype(jnp.int8)
    return codes.reshape(*xp.shape), scales


def _dequantize(codes: jax.Array, scales: jax.Array, n: int) -> jax.Array:
    blocks = codes.reshape(*codes.shape[:-1], -1, _BLOCK)
    x = blocks.astype(jnp.float32) * (scales / 127.0)[..., None]
    return x.reshape(*codes.shape)[..., :n]


class QMoment(NamedTuple):
    codes: jax.Array   # int8, param shape with padded last dim
    scales: jax.Array  # fp32, (..., padded/_BLOCK)


class Adam8bitState(NamedTuple):
    count: jax.Array
    mu: PyTree   # of QMoment
    nu: PyTree   # of QMoment


def _qzeros(p: jax.Array) -> QMoment:
    shp = p.shape if p.ndim else (1,)
    padded = shp[:-1] + (_padded(shp[-1]),)
    return QMoment(jnp.zeros(padded, jnp.int8),
                   jnp.zeros(padded[:-1] + (padded[-1] // _BLOCK,),
                             jnp.float32))


def scale_by_adam_8bit(b1: float = 0.9, b2: float = 0.95,
                       eps: float = 1e-8) -> Optimizer:
    def init(params):
        mu = jax.tree.map(_qzeros, params)
        nu = jax.tree.map(_qzeros, params)
        return Adam8bitState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state, params=None):
        count = state.count + 1
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        # |m̂/√v̂| ≤ 1/√(1−b2) for stationary gradients; block-quantized v
        # can round small entries to 0 while m keeps quantization noise,
        # exploding the ratio — element-wise clipping at the theoretical
        # bound restores stability (the bitsandbytes recipe).
        u_clip = 1.5 / float(np.sqrt(1.0 - b2))

        def upd(g, qm, qv):
            shp = g.shape if g.ndim else (1,)
            n = shp[-1]
            gf = g.reshape(shp).astype(jnp.float32)
            m = _dequantize(qm.codes, qm.scales, n)
            v = _dequantize(qv.codes, qv.scales, n)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            u = ((m / bc1) / (jnp.sqrt(v / bc2) + eps)).reshape(g.shape)
            u = jnp.clip(u, -u_clip, u_clip)
            return u, QMoment(*_quantize(m)), QMoment(*_quantize(v))

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        outs = [upd(g, m, v) for g, m, v in zip(flat_g, flat_m, flat_v)]
        updates = treedef.unflatten([o[0] for o in outs])
        mu = treedef.unflatten([o[1] for o in outs])
        nu = treedef.unflatten([o[2] for o in outs])
        return updates, Adam8bitState(count, mu, nu)

    return Optimizer(init, update)


def adam_8bit(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
              weight_decay: float = 0.1,
              max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    parts = []
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_adam_8bit(b1, b2, eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale(-lr) if not callable(lr) else
                 _schedule_scale(lr))
    return chain(*parts)


def _schedule_scale(lr_fn):
    from repro.optim.optimizers import scale_by_schedule
    return scale_by_schedule(lr_fn)
