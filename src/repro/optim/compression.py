"""Top-k gradient compression with error feedback — cross-pod DP traffic.

The paper's K-WTA write sparsification, reinterpreted for the 1000-node
regime: before the cross-pod (DCN) gradient all-reduce, keep only the top-k
fraction of each gradient tensor and accumulate the residual locally
(error feedback), so the compression is unbiased over time. The compressed
gradient is still a dense tensor of mostly-zeros at the XLA level (GSPMD has
no sparse all-reduce); the *information* is k·(index+value) and a real
deployment would pack it — the dry-run HLO records the schedule, and the
roofline's collective term is scaled by ``keep_frac`` analytically
(EXPERIMENTS.md §Perf documents where this is applied).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kwta import kwta_global
from repro.optim.optimizers import Optimizer

PyTree = Any


class EFState(NamedTuple):
    residual: PyTree
    inner: Any


def topk_compress_error_feedback(inner: Optimizer, keep_frac: float = 0.1,
                                 min_size: int = 4096) -> Optimizer:
    """g' = ζ(g + e);  e ← (g + e) − g';  inner.update(g')."""

    def init(params):
        residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if p.size > min_size
            else jnp.zeros((), jnp.float32), params)
        return EFState(residual, inner.init(params))

    def update(grads, state, params=None):
        def compress(g, e):
            if g.size <= min_size or g.ndim < 2:
                return g, e
            acc = g.astype(jnp.float32) + e
            sent = kwta_global(acc, keep_frac)
            return sent.astype(g.dtype), acc - sent

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(state.residual)
        outs = [compress(g, e) for g, e in zip(flat_g, flat_e)]
        sent = treedef.unflatten([o[0] for o in outs])
        residual = treedef.unflatten([o[1] for o in outs])
        updates, inner_state = inner.update(sent, state.inner, params)
        return updates, EFState(residual, inner_state)

    return Optimizer(init, update)
