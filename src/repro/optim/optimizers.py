"""Minimal optax-style gradient-transformation optimizers.

An ``Optimizer`` is (init, update):
    state          = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params         = apply_updates(params, updates)

Everything is jit-able and shard-transparent (states inherit the sharding
of their parameters under pjit — required for the FSDP dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]],
                     tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


# ---------------------------------------------------------------------------
# Primitive transforms
# ---------------------------------------------------------------------------

def scale(factor) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: g * factor, grads), state

    return Optimizer(init, update)


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> Optimizer:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params=None):
        lr = schedule(count)
        return jax.tree.map(lambda g: g * -lr, grads), count + 1

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads)]
        gnorm = jnp.sqrt(jnp.sum(jnp.stack(leaves)))
        factor = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        return jax.tree.map(lambda g: g * factor, grads), state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                  moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
        return AdamState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state, params=None):
        count = state.count + 1
        grads32 = jax.tree.map(lambda g: g.astype(moment_dtype), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state.nu, grads32)
        bc1 = 1 - b1 ** count.astype(moment_dtype)
        bc2 = 1 - b2 ** count.astype(moment_dtype)
        updates = jax.tree.map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return updates, AdamState(count, mu, nu)

    return Optimizer(init, update)


def add_decayed_weights(weight_decay: float,
                        mask_fn: Optional[Callable[[str], bool]] = None
                        ) -> Optimizer:
    """AdamW-style decoupled weight decay. ``mask_fn(path)`` may exclude
    biases/norms; by default only tensors with ndim >= 2 decay."""
    def init(params):
        return ()

    def update(grads, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights needs params")

        def add_wd(g, p):
            if p.ndim >= 2:
                return g + weight_decay * p.astype(g.dtype)
            return g

        return jax.tree.map(add_wd, grads, params), state

    return Optimizer(init, update)


def chain(*transforms: Optimizer) -> Optimizer:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Canonical recipes
# ---------------------------------------------------------------------------

def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    if momentum == 0.0:
        return scale(-lr)

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, vel, params=None):
        vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
        return jax.tree.map(lambda v: -lr * v, vel), vel

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return chain(scale_by_adam(b1, b2, eps), scale(-lr))


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, max_grad_norm: Optional[float] = 1.0,
          moment_dtype=jnp.float32) -> Optimizer:
    """LM-training default: clip → adam → weight decay → lr.

    ``lr`` may be a float or a schedule ``step -> lr``.
    """
    parts = []
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_adam(b1, b2, eps, moment_dtype))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    if callable(lr):
        parts.append(scale_by_schedule(lr))
    else:
        parts.append(scale(-lr))
    return chain(*parts)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, total_steps: int,
                    min_frac: float = 0.1) -> Callable:
    def schedule(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (min_frac + (1 - min_frac) * cos)
    return schedule


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_frac: float = 0.05) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
