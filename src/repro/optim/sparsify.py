"""K-WTA gradient sparsification as an optimizer transform — the paper's ζ.

Wraps any optimizer: gradients are sparsified (per-tensor global top-k by
magnitude) *before* the inner update, exactly as Algorithm 1 lines 19-21
apply ζ before the SGD write. On M2RU this cuts memristor write traffic
~47 %; at datacenter scale the same transform cuts gradient all-reduce
payload (see optim.compression for the error-feedback variant).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.kwta import kwta_global
from repro.optim.optimizers import Optimizer

PyTree = Any


def kwta_sparsify(inner: Optimizer, keep_frac: float = 0.57,
                  min_size: int = 64) -> Optimizer:
    """Apply ζ(·) with ``keep_frac`` to every gradient tensor with more than
    ``min_size`` elements (scalars/biases pass through untouched, as the
    hardware only sparsifies crossbar writes)."""
    if not (0.0 < keep_frac <= 1.0):
        raise ValueError("keep_frac in (0,1]")

    def init(params):
        return inner.init(params)

    def update(grads, state, params=None):
        def zeta(g):
            if g.size <= min_size or g.ndim < 2:
                return g
            return kwta_global(g, keep_frac)

        sparse = jax.tree.map(zeta, grads)
        return inner.update(sparse, state, params)

    return Optimizer(init, update)


def write_masks(updates: PyTree) -> PyTree:
    """Which synapses receive a write this step (for EnduranceTracker)."""
    return jax.tree.map(lambda u: u != 0, updates)
