"""Self-contained optimizer stack (no optax dependency).

- optimizers:  GradientTransformation-style: sgd, adam, adamw, chain,
               clip_by_global_norm, schedules.
- qstate:      8-bit block-quantized Adam moments (for ≥300 B configs).
- sparsify:    K-WTA gradient sparsification (the paper's ζ) as a transform.
- compression: top-k + error-feedback gradient compression (cross-pod DP).
"""
from repro.optim.optimizers import (Optimizer, sgd, adam, adamw, chain,
                                    clip_by_global_norm, apply_updates,
                                    scale, scale_by_adam, add_decayed_weights,
                                    cosine_schedule, warmup_cosine)
from repro.optim.qstate import adam_8bit
from repro.optim.sparsify import kwta_sparsify
from repro.optim.compression import topk_compress_error_feedback

__all__ = [
    "Optimizer", "sgd", "adam", "adamw", "chain", "clip_by_global_norm",
    "apply_updates", "scale", "scale_by_adam", "add_decayed_weights",
    "cosine_schedule", "warmup_cosine", "adam_8bit", "kwta_sparsify",
    "topk_compress_error_feedback",
]
