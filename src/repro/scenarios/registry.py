"""Name-keyed registry of continual-learning scenarios.

A *scenario* is a builder that turns (seed, sizing kwargs) into a task
sequence — a ``list[TaskData]`` — plus the metadata the sweep runner
needs to execute it: whether the stream is shape-uniform (the
precondition for the compiled scan-over-tasks path) and any trainer
overrides the protocol imposes (the online streaming regime is
single-pass regardless of the trainer's ``epochs_per_task``).

    @register_scenario("my_stream", description="...")
    def make_my_stream(seed, n_tasks=5, n_train=1000, n_test=400, **kw):
        return [...TaskData...]

    tasks = build_scenario("my_stream", seed=0, n_tasks=3)

Every builder takes ``(seed, n_tasks=..., n_train=..., n_test=...)`` so
the sweep can size any scenario uniformly; extra knobs are
scenario-specific keywords. See docs/scenarios.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

from repro.data.synthetic import (TaskData, make_class_incremental_tasks,
                                  make_drift_tasks, make_noisy_label_tasks,
                                  make_permuted_tasks, make_rotated_tasks,
                                  make_split_tasks, make_streaming_tasks)

Builder = Callable[..., list[TaskData]]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A registered scenario: the builder plus how to run it."""
    name: str
    builder: Builder
    description: str = ""
    # Shape-uniform across tasks (same n_train/T/F and a fixed head) —
    # required for the compiled scan-over-tasks sweep; non-uniform
    # scenarios fall back to the per-task Python loop.
    uniform: bool = True
    # TrainerSpec fields the protocol pins (e.g. single-pass streaming
    # forces epochs_per_task=1). Applied by the sweep on top of the
    # caller's TrainerSpec.
    trainer_overrides: Mapping[str, Any] = \
        dataclasses.field(default_factory=dict)
    # Preferred repro.replay policy for this stream (e.g. the
    # class-incremental protocol rehearses best class-balanced). Resolved
    # by run_sweep / the example driver exactly like trainer_overrides:
    # only when the caller's ReplaySpec.policy is None (no explicit
    # choice). None keeps the global default (reservoir).
    replay_policy: Optional[str] = None
    # The padding policy (repro.data.ragged.PadPolicy) ragged streams
    # declare so the compiled sweep can run them through the masked
    # program instead of the Python-loop fallback. None (every uniform
    # scenario) changes nothing.
    pad: Optional[Any] = None

    def build(self, seed: int = 0, **kwargs) -> list[TaskData]:
        return self.builder(seed, **kwargs)

    def resolve_replay(self, replay):
        """Apply this scenario's preferred replay policy to a ReplaySpec
        (or None → the default spec) unless the caller pinned one."""
        from repro.core.continual import ReplaySpec
        replay = replay if replay is not None else ReplaySpec()
        if replay.policy is None and self.replay_policy is not None:
            return dataclasses.replace(replay, policy=self.replay_policy)
        return replay


_REGISTRY: dict[str, ScenarioSpec] = {}


def register_scenario(name: str, *, description: str = "",
                      uniform: bool = True,
                      trainer_overrides: Optional[Mapping[str, Any]] = None,
                      replay_policy: Optional[str] = None,
                      pad: Optional[Any] = None):
    """Register a scenario builder (usable as a decorator). Re-registering
    a name overwrites it (tests, experiment sweeps)."""
    def _do(builder: Builder) -> Builder:
        _REGISTRY[name] = ScenarioSpec(
            name=name, builder=builder, description=description,
            uniform=uniform,
            trainer_overrides=dict(trainer_overrides or {}),
            replay_policy=replay_policy, pad=pad)
        return builder
    return _do


def unregister_scenario(name: str) -> None:
    """Remove a registered scenario (test teardown helper)."""
    _REGISTRY.pop(name, None)


def available_scenarios() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; "
            f"available: {', '.join(available_scenarios()) or '(none)'}"
        ) from None


def build_scenario(name: str, seed: int = 0, **kwargs) -> list[TaskData]:
    """Build the task sequence for a registered scenario."""
    return get_scenario(name).build(seed, **kwargs)


# ---------------------------------------------------------------------------
# Built-in scenarios — the streams from repro.data.synthetic
# ---------------------------------------------------------------------------

register_scenario(
    "permuted",
    description="Permuted-pixel domain-incremental stream (permuted-MNIST "
                "protocol, §VI-A); task 0 is the identity permutation.",
)(make_permuted_tasks)

register_scenario(
    "split",
    description="Split feature-space stream: consecutive class pairs on a "
                "shared binary head (domain-incremental split CIFAR-10).",
)(make_split_tasks)

register_scenario(
    "rotated",
    description="Rotated-image stream: one dataset viewed under a "
                "per-task rotation ramping 0→max_angle degrees.",
    # Each rotation is a distinct view of the same classes: stratifying
    # the buffer by task keeps every past view represented.
    replay_policy="task_stratified",
)(make_rotated_tasks)

register_scenario(
    "noisy_label",
    description="Label-noise robustness stream: fixed domain, train-label "
                "corruption ramping 0→max_flip across tasks (clean test).",
)(make_noisy_label_tasks)

register_scenario(
    "drift",
    description="Gradual domain drift: class prototypes interpolate from "
                "a start to an end set across the sequence.",
    # Under gradual drift old prototypes go stale; the FIFO ring's
    # recency bias rehearses the still-relevant neighborhood.
    replay_policy="ring",
)(make_drift_tasks)

register_scenario(
    "class_incremental",
    description="Class-incremental stream with a logically expanding "
                "head: task t introduces classes [t·c, (t+1)·c) with "
                "global labels over the full head.",
    # Per-class reservoir sized for the full expanding head: early
    # classes keep fixed buffer share as later classes stream in.
    replay_policy="class_balanced",
)(make_class_incremental_tasks)

register_scenario(
    "streaming",
    description="Online single-pass streaming regime: a restart-safe "
                "(seed, step)-deterministic stream chopped into segments "
                "under fresh permutations; each example is seen once.",
    trainer_overrides={"epochs_per_task": 1},
)(make_streaming_tasks)


# ---------------------------------------------------------------------------
# Real sequential streams — repro.data.real (surrogate fallback offline)
# ---------------------------------------------------------------------------

def _register_real_scenarios():
    # Deferred so repro.data.real's module import cost (none at import
    # time — downloads happen inside the builders) stays off the
    # registry's critical path and the import cycle stays clean.
    from repro.data.ragged import PadPolicy
    from repro.data.real import (make_keyword_fewshot_tasks,
                                 make_seq_cifar10_tasks,
                                 make_seq_mnist_tasks)

    register_scenario(
        "seq_mnist",
        description="Permuted sequential MNIST on real data (row-by-row, "
                    "28×28; surrogate offline): the paper's §VI-A "
                    "benchmark stream.",
        pad=PadPolicy(last_batch="pad"),
    )(make_seq_mnist_tasks)

    register_scenario(
        "seq_cifar10",
        description="Split sequential CIFAR-10 on real data (row-by-row "
                    "32×96 RGB rows, class-pair binary head; surrogate "
                    "offline).",
        pad=PadPolicy(last_batch="pad"),
    )(make_seq_cifar10_tasks)

    register_scenario(
        "keyword_fewshot",
        description="Few-shot continual keyword stream: variable-length "
                    "utterances and per-task decreasing shot counts — "
                    "ragged in T and n_train; compiles via PadPolicy.",
        uniform=False,
        pad=PadPolicy(last_batch="pad"),
    )(make_keyword_fewshot_tasks)


_register_real_scenarios()
