"""Continual-learning scenario suite — registry, metrics, compiled sweep.

- registry: name-keyed scenario builders (permuted, split, rotated,
            noisy_label, drift, class_incremental, streaming) all
            emitting the ``TaskData`` shape, with per-scenario run
            metadata (shape uniformity, trainer overrides).
- metrics:  average accuracy, forgetting, backward/forward transfer
            from the accuracy matrix.
- sweep:    the compiled sweep runner — the whole task sequence inside
            one jit (``lax.scan`` over tasks, vmapped over seeds,
            donated buffers), bit-comparable to ``run_continual``, with
            telemetry threaded per scenario × backend cell.

See docs/scenarios.md.
"""
from repro.scenarios.metrics import (average_accuracy, backward_transfer,
                                     continual_metrics, forgetting,
                                     forward_transfer)
from repro.scenarios.registry import (ScenarioSpec, available_scenarios,
                                      build_scenario, get_scenario,
                                      register_scenario,
                                      unregister_scenario)
from repro.scenarios.sweep import (run_compiled, run_sweep,
                                   scenario_miru_config)

__all__ = [
    "ScenarioSpec", "available_scenarios", "build_scenario", "get_scenario",
    "register_scenario", "unregister_scenario",
    "average_accuracy", "backward_transfer", "continual_metrics",
    "forgetting", "forward_transfer",
    "run_compiled", "run_sweep", "scenario_miru_config",
]
