"""Compiled scenario sweeps: the whole task sequence inside one jit.

``run_continual`` drives training with a per-batch Python loop — one
jitted dispatch per step, one per eval. This module executes the *entire*
sequence as a ``lax.scan`` over tasks whose body is a ``lax.scan`` over
replay-mixed batches, with the input buffers donated to XLA and an
optional ``vmap`` over seeds. Because the batch stream is materialized by
the same :func:`repro.core.continual.build_batch_schedule` and the step
functions are the same :func:`repro.core.continual._make_raw_steps`
closures, the compiled run consumes bit-identical inputs and PRNG streams
to the Python loop — the permuted/ideal parity is asserted in
tests/test_scenarios.py.

After each task the runner evaluates *every* task (not just the seen
prefix), so the accuracy matrix ``R_full`` also carries the
unseen-task upper triangle that forward transfer needs; the standard
lower-triangular ``R`` (zeros above the diagonal, as ``run_continual``
reports) is derived from it.

Telemetry is threaded through jit-exactly: the metered forward's
interior flush is suppressed (``Telemetry.deferred``), per-trace deltas
are multiplied by the scan/map/vmap multiplicities (``Telemetry.scaled``)
and drained through one io_callback per compiled execution.
Data-dependent write pulses are summed inside the scan as per-device
count maps and folded into the telemetry/endurance tracker host-side.

Scenarios whose streams are not shape-uniform across tasks cannot scan;
:func:`run_compiled` falls back to the Python loop for those and says so
in the result (``"compiled": False``).

Device substrates with a fused recurrence (wbs/analog) ride it inside
the compiled sweep automatically — the step functions come from the same
:func:`_make_raw_steps` closures, so the per-batch loop and the
scan-over-tasks stay bit-comparable on the fused path too
(``TrainerSpec.fused_recurrence=False`` forces the per-step scan).

Replay policies (``ReplaySpec.policy`` → :mod:`repro.replay`) compose
with the sweep: host-materialized policies change only the schedule
content; the in-graph ``loss_aware`` policy carries its device-resident
buffer through the scan (and the seed vmap). ``run_sweep`` resolves
each scenario's preferred policy (``ScenarioSpec.replay_policy``) the
same way it applies ``trainer_overrides``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import DeviceBackend, get_backend
from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  _ingraph_replay_traffic, _init_run,
                                  _make_ingraph_replay_step,
                                  _make_raw_steps, build_batch_schedule,
                                  run_continual)
from repro.core.replay import _split_chain
from repro.replay import get_policy_class, ingraph_init
from repro.data.synthetic import TaskData
from repro.scenarios.metrics import continual_metrics
from repro.scenarios.registry import get_scenario

__all__ = ["run_compiled", "run_sweep", "scenario_miru_config"]


# ---------------------------------------------------------------------------
# Per-seed inputs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SeedInputs:
    """Everything one seed's compiled run consumes. The mask fields are
    populated only on schedules built under a
    :class:`repro.data.ragged.PadPolicy` (the step axis is padded to the
    longest task; masks say what is real)."""
    params: Any
    opt_state: Any
    dev_state: Any
    xs: np.ndarray          # (n_tasks, S, B, T, F)
    ys: np.ndarray          # (n_tasks, S, B)
    step_keys: np.ndarray   # (n_tasks, S, 2)
    eval_keys: np.ndarray   # (n_tasks, 2)
    rstate: Any = None      # in-graph replay buffer (loss_aware), or None
    step_valid: Any = None  # (n_tasks, S) bool — False on step padding
    row_valid: Any = None   # (n_tasks, S, B) bool — False on row padding
    lengths: Any = None     # (n_tasks, S, B) int32 true sequence lengths

    def as_arrays(self) -> tuple:
        """The positional argument tuple ``_make_run_fn``'s run consumes
        (minus the shared eval buffers) — one definition used by the
        seed-vmapped path here and the fleet runner's device axis."""
        return (self.params, self.opt_state, self.dev_state, self.rstate,
                jnp.asarray(self.xs), jnp.asarray(self.ys),
                jnp.asarray(self.step_keys), jnp.asarray(self.eval_keys))

    def as_masked_arrays(self) -> tuple:
        """``as_arrays`` plus the validity masks — the argument tuple of
        ``_make_masked_run_fn``'s run."""
        return self.as_arrays() + (jnp.asarray(self.step_valid),
                                   jnp.asarray(self.row_valid),
                                   jnp.asarray(self.lengths))


def _pad_step_axis(a: np.ndarray, s_max: int, fill=0) -> np.ndarray:
    """Pad a per-task (S_t, ...) array to (s_max, ...) with ``fill``."""
    if a.shape[0] == s_max:
        return a
    pad = np.full((s_max - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad])


def _build_seed_inputs(cfg, trainer: TrainerSpec, rspec: ReplaySpec,
                       backend: DeviceBackend, tasks: list[TaskData],
                       opt, pad=None) -> tuple[_SeedInputs, Any]:
    """Materialize one seed's schedule, initial state and PRNG streams —
    the exact sequences :func:`run_continual` would consume.

    With ``pad`` (a :class:`repro.data.ragged.PadPolicy`) a ragged
    stream never bails to the loop: per-task step counts pad to the
    longest task with ``step_valid`` masks (the PRNG chain is split over
    the *real* step count only, so it stays bit-identical to the loop's;
    pad steps consume dummy zero keys whose results the scan discards).
    """
    schedule = build_batch_schedule(trainer, rspec, tasks, pad=pad)
    if pad is None and not schedule.uniform:
        return None, schedule
    key, params, psi, dev_state = _init_run(cfg, trainer, backend)
    opt_state = opt.init(params) if trainer.algo == "adam" else {"psi": psi}
    steps = schedule.steps_per_task
    n_tasks = len(tasks)
    # run_continual's key chain: per task, S step splits then one eval
    # split — a single sequential chain, computed in one scan dispatch.
    _, subs = _split_chain(key, sum(steps) + n_tasks)
    subs = np.asarray(subs)
    step_keys, eval_keys, at = [], [], 0
    for S in steps:
        step_keys.append(subs[at:at + S])
        eval_keys.append(subs[at + S])
        at += S + 1
    rstate = None
    if get_policy_class(rspec.resolved_policy).in_graph:
        T, F = tasks[0].x_train.shape[1:]
        rstate = ingraph_init(rspec.capacity, (T, F), rspec.bits)
    if pad is None:
        return _SeedInputs(
            params=params, opt_state=opt_state, dev_state=dev_state,
            xs=np.stack(schedule.x), ys=np.stack(schedule.y),
            step_keys=np.stack(step_keys), eval_keys=np.stack(eval_keys),
            rstate=rstate,
        ), schedule
    s_max = max(steps) if steps else 0
    return _SeedInputs(
        params=params, opt_state=opt_state, dev_state=dev_state,
        xs=np.stack([_pad_step_axis(x, s_max) for x in schedule.x]),
        ys=np.stack([_pad_step_axis(y, s_max) for y in schedule.y]),
        step_keys=np.stack([_pad_step_axis(k, s_max) for k in step_keys]),
        eval_keys=np.stack(eval_keys),
        rstate=rstate,
        step_valid=np.stack([np.arange(s_max) < s for s in steps]),
        row_valid=np.stack([_pad_step_axis(v, s_max, fill=False)
                            for v in schedule.row_valid]),
        # Pad-step lengths are 1 (an always-in-range gather index; the
        # step's results are discarded anyway, and 1 avoids the 1/0 in
        # the DFA time normalization).
        lengths=np.stack([_pad_step_axis(ln, s_max, fill=1)
                          for ln in schedule.lengths]),
    ), schedule


# ---------------------------------------------------------------------------
# The compiled run
# ---------------------------------------------------------------------------

def _make_run_fn(cfg, trainer: TrainerSpec, backend: DeviceBackend,
                 n_tasks: int, S: int, track_writes: bool, baseline: bool,
                 ingraph_rspec: Optional[ReplaySpec] = None,
                 obs_metrics: bool = False):
    """Build the jitted whole-protocol run. When ``ingraph_rspec`` names
    an in-graph replay policy (loss_aware), the step is the replay-
    wrapped one and the device-resident buffer rides the scan carry —
    per-task replay enablement (past task 0) enters as a scanned flag.

    ``obs_metrics`` threads the :mod:`repro.obs` per-step scalars
    (write pulses, Σ|ΔG|, replay occupancy) through the scan as extra
    ``ys`` outputs — pure reads of values the step already computes, so
    the training results are unchanged; False (the default) emits
    exactly the pre-obs trace."""
    raw_train, raw_eval, _ = _make_raw_steps(cfg, trainer, backend)
    ingraph_step = None
    if ingraph_rspec is not None:
        ingraph_step = _make_ingraph_replay_step(
            cfg, trainer, ingraph_rspec, backend, raw_train)
    if obs_metrics:
        from repro.obs.runlog import step_stats
    tele = backend.telemetry

    def run(params, opt_state, dev_state, rstate, xs, ys, step_keys,
            eval_keys, eval_x, eval_y):

        def eval_all(p, k_eval, dstate):
            def one(exy):
                return raw_eval(p, k_eval, exy[0], exy[1], dstate)
            with tele.scaled(n_tasks):
                return jax.lax.map(one, (eval_x, eval_y))

        def task_body(carry, inp):
            xs_t, ys_t, keys_t, k_eval, r_on = inp

            def step_body(c, sinp):
                p, o, d, wc, rs = c
                x, y, k = sinp
                if ingraph_step is not None:
                    p, o, loss, applied, d, rs = ingraph_step(
                        p, o, k, x, y, d, rs, r_on)
                else:
                    p, o, loss, applied, d = raw_train(p, o, k, x, y, d)
                if wc is not None:
                    wc = {n: wc[n] + (applied[n] != 0).astype(jnp.int32)
                          for n in wc}
                ys_out = (loss, *step_stats(applied, rs)) \
                    if obs_metrics else loss
                return (p, o, d, wc, rs), ys_out

            with tele.scaled(S):
                carry, step_ys = jax.lax.scan(step_body, carry,
                                              (xs_t, ys_t, keys_t))
            p, _, d, _, _ = carry
            accs = eval_all(p, k_eval, d)
            return carry, (accs, step_ys)

        wc0 = {n: jnp.zeros(p.shape, jnp.int32)
               for n, p in params.items()
               if jnp.ndim(p) >= 2} if track_writes else None
        replay_on = jnp.arange(n_tasks) > 0
        with tele.deferred():
            base_row = eval_all(params, eval_keys[0], dev_state) \
                if baseline else jnp.zeros((n_tasks,), jnp.float32)
            with tele.scaled(n_tasks):
                carry, (R_full, step_ys) = jax.lax.scan(
                    task_body,
                    (params, opt_state, dev_state, wc0, rstate),
                    (xs, ys, step_keys, eval_keys, replay_on))
        tele.emit_pending()
        params, opt_state, dev_state, wcounts, rstate = carry
        if obs_metrics:
            losses, pulses, dgs, occs = step_ys
        else:
            losses = step_ys
        out = {"params": params, "dev_state": dev_state,
               "R_full": R_full, "losses": losses,
               "wcounts": wcounts, "baseline_row": base_row}
        if obs_metrics:
            out["obs"] = {"write_pulses": pulses, "dg_mag": dgs,
                          "replay_occupancy": occs}
        return out

    return run


def _make_masked_run_fn(cfg, trainer: TrainerSpec, backend: DeviceBackend,
                        n_tasks: int, total_real_steps: int,
                        track_writes: bool, baseline: bool):
    """The masked twin of :func:`_make_run_fn` for padded ragged
    schedules: row-validity/true-length aware steps
    (:func:`repro.core.continual._make_masked_steps`), step-axis padding
    discarded by a ``jnp.where`` carry select on ``step_valid``, and
    telemetry metered for the real step total only (padded rows and
    timesteps *inside* an executed batch still meter — the chip streams
    them; see docs/data.md).

    On a stream with no actual raggedness (``PadPolicy(force=True)``)
    every mask is all-true, the carry select is the identity, and the
    outputs agree with ``_make_run_fn``'s to float32 ulp-level (the
    tolerance contract of :mod:`repro.data.ragged`, gated in
    benchmarks/data_bench.py). In-graph replay is unsupported here
    (:func:`run_compiled` raises before getting this far), so
    ``rstate`` never rides the carry."""
    from repro.core.continual import _make_masked_steps
    raw_train, raw_eval, _ = _make_masked_steps(cfg, trainer, backend)
    tele = backend.telemetry

    def run(params, opt_state, dev_state, rstate, xs, ys, step_keys,
            eval_keys, step_valid, row_valid, lengths,
            eval_x, eval_y, eval_valid, eval_len):
        del rstate  # host-materialized policies only on the masked path

        def eval_all(p, k_eval, dstate, scale):
            def one(args):
                ex, ey, ev, el = args
                return raw_eval(p, k_eval, ex, ey, dstate, ev, el)
            with tele.scaled(scale):
                return jax.lax.map(one, (eval_x, eval_y,
                                         eval_valid, eval_len))

        def task_body(carry, inp):
            xs_t, ys_t, keys_t, k_eval, sv_t, rv_t, ln_t = inp

            def step_body(c, sinp):
                p, o, d, wc = c
                x, y, k, sv, rv, ln = sinp
                p2, o2, loss, applied, d2 = raw_train(p, o, k, x, y, d,
                                                      rv, ln)
                # Step-axis padding: compute-and-discard. The pad step's
                # dummy key was never split from the loop's chain, so
                # keeping the incoming carry preserves PRNG parity.
                def keep(new, old):
                    return jax.tree.map(
                        lambda a, b: jnp.where(sv, a, b), new, old)
                p, o, d = keep(p2, p), keep(o2, o), keep(d2, d)
                loss = jnp.where(sv, loss, 0.0)
                if wc is not None:
                    wc = {n: wc[n] + jnp.where(
                        sv, (applied[n] != 0).astype(jnp.int32), 0)
                        for n in wc}
                return (p, o, d, wc), loss

            # One scale for the whole (padded) step scan: the real step
            # total across tasks. On a uniform stream this equals the
            # unmasked program's nested S × n_tasks product exactly.
            with tele.scaled(total_real_steps):
                carry, losses_t = jax.lax.scan(
                    step_body, carry,
                    (xs_t, ys_t, keys_t, sv_t, rv_t, ln_t))
            p, _, d, _ = carry
            accs = eval_all(p, k_eval, d, n_tasks * n_tasks)
            return carry, (accs, losses_t)

        wc0 = {n: jnp.zeros(p.shape, jnp.int32)
               for n, p in params.items()
               if jnp.ndim(p) >= 2} if track_writes else None
        with tele.deferred():
            base_row = eval_all(params, eval_keys[0], dev_state,
                                n_tasks) \
                if baseline else jnp.zeros((n_tasks,), jnp.float32)
            carry, (R_full, losses) = jax.lax.scan(
                task_body, (params, opt_state, dev_state, wc0),
                (xs, ys, step_keys, eval_keys, step_valid,
                 row_valid, lengths))
        tele.emit_pending()
        params, opt_state, dev_state, wcounts = carry
        return {"params": params, "dev_state": dev_state,
                "R_full": R_full, "losses": losses,
                "wcounts": wcounts, "baseline_row": base_row}

    return run


def _summarize_run(R_full, base_row, losses, baseline: bool) -> dict:
    """One run's summary dict from its raw outputs — shared by the
    seed-vmapped path here and the fleet runner's device axis.

    float64 like run_continual's R (float32 accuracies are exactly
    representable, so the widening keeps bit-equality with the loop)."""
    R_full = np.asarray(R_full, np.float64)
    n_tasks = R_full.shape[0]
    R = np.tril(R_full)
    return {
        "R": R, "R_full": R_full,
        "MA": float(R_full[-1].mean()),
        "acc_after_each": [float(R[t, :t + 1].mean())
                           for t in range(n_tasks)],
        "losses": [float(v) for v in np.asarray(losses).reshape(-1)],
        "metrics": continual_metrics(
            R_full, base_row if baseline else None),
        "baseline_row": base_row,
    }


def _aggregate_seeds(per_seed: list[dict], seeds: Sequence[int]) -> dict:
    """Cross-seed aggregation shared by the compiled and fallback paths:
    metrics (and MA ≡ average_accuracy) become the seed mean, with a
    ``metrics_std`` companion and the raw ``per_seed`` cells."""
    keys = per_seed[0]["metrics"]
    metrics = {k: float(np.mean([p["metrics"][k] for p in per_seed]))
               for k in keys}
    return {
        "per_seed": per_seed,
        "seeds": list(seeds),
        "metrics": metrics,
        "metrics_std": {k: float(np.std([p["metrics"][k]
                                         for p in per_seed]))
                        for k in keys},
        "MA": metrics["average_accuracy"],
    }


def _fallback_python(cfg, trainer, tasks, rspec, backend, seeds,
                     obs=None):
    """Non-uniform streams cannot scan: run the per-task Python loop.
    Mirrors the compiled path's multi-seed reporting (metrics are the
    cross-seed mean, with ``metrics_std``), minus FWT — the loop never
    evaluates unseen tasks or the untrained baseline. ``obs`` rides
    through to :func:`run_continual`; a multi-seed fallback reports the
    first seed's RunLog."""
    runs = []
    for s in (seeds if seeds is not None else [trainer.seed]):
        tsp = dataclasses.replace(trainer, seed=s)
        runs.append(run_continual(cfg, tsp, tasks, replay=rspec,
                                  device=backend, obs=obs))
    per_seed = [{"R": r["R"], "MA": r["MA"],
                 "metrics": continual_metrics(r["R"])} for r in runs]
    out = dict(runs[0])
    out["compiled"] = False
    out["metrics"] = per_seed[0]["metrics"]
    if seeds is not None and len(runs) > 1:
        out.update(_aggregate_seeds(per_seed, seeds))
    return out


def run_compiled(cfg, spec: TrainerSpec, tasks: list[TaskData],
                 replay: Optional[ReplaySpec] = None,
                 device: Union[str, DeviceBackend, None] = None,
                 *, seeds: Optional[Sequence[int]] = None,
                 baseline: bool = True,
                 uniform: bool = True,
                 obs: Optional[Any] = None,
                 pad: Optional[Any] = None) -> dict[str, Any]:
    """Train through the task sequence inside one compiled program.

    Same contract as :func:`run_continual` (and bit-identical ``R``/
    ``MA``/``params`` on deterministic backends — asserted for
    permuted × ideal in the tests), plus:

      R_full        (n_tasks, n_tasks) with the unseen-task upper triangle
      metrics       average_accuracy / forgetting / BWT (+ FWT when
                    ``baseline``), from :mod:`repro.scenarios.metrics`
      baseline_row  untrained-model accuracy per task (when ``baseline``)
      compiled      False when the stream was not shape-uniform and the
                    run fell back to the per-task Python loop

    ``uniform=False`` (a :class:`ScenarioSpec` declares it) goes straight
    to the Python-loop fallback without materializing the (ragged)
    schedule first; ragged streams are also auto-detected either way.
    ``seeds`` replicates the run across trainer seeds inside one
    ``vmap``-ed program; per-seed R matrices and metric mean/std come
    back under ``"per_seed"``/``"metrics"``. Initial-state and schedule
    buffers are donated to XLA.

    ``obs`` is a :class:`repro.obs.ObsSpec`: metric streams come back
    as ``"runlog"`` (with a leading per-seed axis under ``seeds``), and
    a tracer records ``schedule`` / ``compile`` / ``execute`` spans —
    compile separated from execute by lowering ahead of time, which is
    also what ``"compile_s"``/``"execute_s"`` report. ``obs=None`` (the
    default) compiles and runs the exact pre-obs program.

    ``pad`` is a :class:`repro.data.ragged.PadPolicy`: ragged streams
    (unequal n_train/n_test/sequence length across tasks) pad onto one
    bucketed shape with validity masks and run *compiled* through the
    masked program instead of falling back to the loop. With a policy
    attached but nothing actually ragged (and ``force=False``), the
    exact pre-refactor unmasked program runs — bitwise-identical
    outputs. Masked runs keep host-materialized replay only (an
    in-graph policy raises) and do not support obs metric streams.
    """
    trainer = spec
    if not isinstance(trainer, TrainerSpec):
        raise TypeError("run_compiled takes a TrainerSpec; legacy "
                        "ContinualConfig is only supported by run_continual")
    rspec = replay if replay is not None else ReplaySpec()
    backend = get_backend(device if device is not None else "ideal")
    tele = backend.telemetry
    obs_on = obs is not None and getattr(obs, "metrics", False)
    tracer = getattr(obs, "tracer", None) if obs is not None else None

    in_graph = get_policy_class(rspec.resolved_policy).in_graph
    eval_padded = False
    if pad is not None:
        from repro.data.ragged import pad_tasks
        if in_graph:
            raise ValueError(
                "a PadPolicy cannot be combined with an in-graph replay "
                "policy (loss_aware): the device-resident buffer has no "
                "row-validity channel; use a host-materialized policy")
        tasks, eval_padded = pad_tasks(tasks, pad)

    test_shapes = {(t.x_test.shape, t.y_test.shape) for t in tasks}
    seed_list = list(seeds) if seeds is not None else None
    many = seed_list is not None and len(seed_list) > 1

    if not uniform and pad is None:
        # Declared ragged (ScenarioSpec.uniform=False) with no padding
        # policy: skip schedule materialization and run the loop.
        return _fallback_python(cfg, trainer, tasks, rspec, backend,
                                seed_list, obs=obs)

    _, _, opt = _make_raw_steps(cfg, trainer, backend)
    sched_scope = tracer.span("schedule", n_tasks=len(tasks)) \
        if tracer is not None else contextlib.nullcontext()
    inputs, scheds = [], []
    with sched_scope:
        for s in (seed_list if seed_list is not None else [trainer.seed]):
            tsp = dataclasses.replace(trainer, seed=s)
            inp, sched = _build_seed_inputs(cfg, tsp, rspec, backend,
                                            tasks, opt, pad=pad)
            inputs.append(inp)
            scheds.append(sched)
    if any(i is None for i in inputs) or len(test_shapes) != 1:
        # The materialized schedules are discarded — their replay
        # traffic is *not* credited here; the loop fallback meters its
        # own (run_continual records its schedule's traffic).
        return _fallback_python(cfg, trainer, tasks, rspec, backend,
                                seed_list, obs=obs)

    masked = False
    if pad is not None:
        from repro.data.ragged import needs_masked_program
        # The mask *structure* (step counts, row/length masks present)
        # is seed-independent — only the shuffled content differs — so
        # one schedule decides for all seeds.
        masked = needs_masked_program(pad, eval_padded, scheds[0])
    if masked and obs_on:
        raise ValueError(
            "obs metric streams are not supported on the masked "
            "(padded) program; drop ObsSpec.metrics or run the loop")

    n_tasks = len(tasks)
    S = inputs[0].xs.shape[1]
    total_real = sum(scheds[0].steps_per_task)
    track_writes = backend.tracker is not None or tele.enabled
    if tele.enabled:
        # Credit the replay DRAM traffic of every schedule this compiled
        # run will actually consume (host policies), or the exact
        # scan-carried buffer traffic (in-graph policies) — once.
        T, F = tasks[0].x_train.shape[1:]
        for sched in scheds:
            traffic = _ingraph_replay_traffic(
                rspec, trainer.batch_size, sched.steps_per_task,
                (T, F)) if in_graph else sched.replay_traffic
            if traffic:
                tele.record(traffic)
    if masked:
        run = _make_masked_run_fn(cfg, trainer, backend, n_tasks,
                                  total_real, track_writes, baseline)
    else:
        run = _make_run_fn(cfg, trainer, backend, n_tasks, S,
                           track_writes, baseline,
                           ingraph_rspec=rspec if in_graph else None,
                           obs_metrics=obs_on)

    eval_x = jnp.asarray(np.stack([t.x_test for t in tasks]))
    eval_y = jnp.asarray(np.stack([t.y_test for t in tasks]))
    eval_extra = ()
    if masked:
        from repro.data.ragged import eval_masks
        ev_valid, ev_len = eval_masks(tasks)
        eval_extra = (jnp.asarray(ev_valid), jnp.asarray(ev_len))
    n_seed_args = 11 if masked else 8

    # Donate the mutated state buffers (params; the conductance pairs).
    # opt_state is excluded: DFA's is the pass-through Ψ and XLA declines
    # to alias the Adam moments on CPU — donating either only warns.
    # Vmapped leaves don't alias at all.
    donate = (0, 2) if not many else ()
    if many:
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[(i.as_masked_arrays() if masked else i.as_arrays())
              for i in inputs])
        fn = jax.jit(jax.vmap(
            run, in_axes=(0,) * n_seed_args
            + (None,) * (2 + len(eval_extra))))
        scope = tele.scaled(len(seed_list))
    else:
        stacked = inputs[0].as_masked_arrays() if masked \
            else inputs[0].as_arrays()
        fn = jax.jit(run, donate_argnums=donate)
        scope = contextlib.nullcontext()

    t0 = time.perf_counter()
    compile_s = execute_s = None
    if tracer is not None:
        # Lower ahead of time so the compile span excludes execution.
        # The telemetry scale scope wraps the *lowering* — trace-time
        # pending deltas are what the multiplier applies to.
        with tracer.span("compile", backend=backend.name,
                         n_tasks=n_tasks, steps_per_task=S):
            with scope:
                lowered = fn.lower(*stacked, eval_x, eval_y, *eval_extra)
            compiled_fn = lowered.compile()
        compile_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        with tracer.span("execute", backend=backend.name):
            res = compiled_fn(*stacked, eval_x, eval_y, *eval_extra)
            res = jax.tree.map(np.asarray, res)
        execute_s = time.perf_counter() - t1
    else:
        with scope:
            res = fn(*stacked, eval_x, eval_y, *eval_extra)
        res = jax.tree.map(np.asarray, res)
    wall_s = time.perf_counter() - t0
    obs_streams = res.pop("obs", None)

    # Host-side accounting of the data-dependent write pulses the scan
    # summed (the Python loop meters these per step in record_endurance).
    # Masked runs zeroed the pad steps' pulses in-graph, so the event
    # count is the real step total.
    total_steps = (total_real if masked else n_tasks * S) \
        * (len(seed_list) if many else 1)
    wcounts = res.pop("wcounts")
    if track_writes and wcounts:
        counts = {k: (v.sum(axis=0) if many else v)
                  for k, v in wcounts.items()}
        tele.meter_write_counts(counts, total_steps)
        if backend.tracker is not None:
            backend.tracker.record_counts(counts, total_steps)

    def _trim(losses):
        # Masked runs pad the step axis; report real steps only, in the
        # same task-major order the loop's loss list uses.
        if not masked:
            return losses
        return np.concatenate(
            [np.asarray(losses[t, :st])
             for t, st in enumerate(scheds[0].steps_per_task)])

    out: dict[str, Any]
    if many:
        per_seed = [_summarize_run(res["R_full"][i], res["baseline_row"][i],
                                   _trim(res["losses"][i]), baseline)
                    for i in range(len(seed_list))]
        out = dict(per_seed[0])
        out.update(_aggregate_seeds(per_seed, seed_list))
        out["params"] = jax.tree.map(lambda v: v[0], res["params"])
    else:
        out = _summarize_run(res["R_full"], res["baseline_row"],
                             _trim(res["losses"]), baseline)
        out["params"] = res["params"]
        if res["dev_state"]:
            out["device_state"] = res["dev_state"]
    out["compiled"] = True
    out["wall_s"] = wall_s
    out["steps_per_task"] = S
    if compile_s is not None:
        out["compile_s"] = compile_s
        out["execute_s"] = execute_s
    if obs_on:
        from repro.obs.runlog import build_runlog, drift_stream

        def _ps(a):
            # Per-step stream: (n_tasks, S) → (total,), with the seed
            # axis leading under vmap.
            a = np.asarray(a)
            return a.reshape(len(seed_list), -1) if many \
                else a.reshape(-1)

        if in_graph:
            occ = _ps(obs_streams["replay_occupancy"])
        else:
            # Host-materialized policies: the buffer lives outside the
            # graph; its fill was recorded when the schedule was built.
            occ = np.stack([sc.occupancy_stream() for sc in scheds]) \
                if many else scheds[0].occupancy_stream()
        cb = backend.spec.crossbar
        drifting = (inputs[0].dev_state is not None and cb is not None
                    and getattr(cb, "drift_rate", 0.0) > 0)
        drift = drift_stream(n_tasks * S, drifting=drifting)
        if many:
            drift = np.broadcast_to(drift,
                                    (len(seed_list),) + drift.shape)
        out["runlog"] = build_runlog(
            cadence=obs.cadence,
            steps_per_task=scheds[0].steps_per_task,
            loss=_ps(res["losses"]),
            write_pulses=_ps(obs_streams["write_pulses"]),
            dg_mag=_ps(obs_streams["dg_mag"]),
            replay_occupancy=occ,
            drift_ticks=drift,
            task_acc=res["R_full"])
    if backend.tracker is not None:
        out["endurance"] = backend.tracker
    if tele.enabled:
        out["telemetry"] = tele
    return out


# ---------------------------------------------------------------------------
# Scenario × backend sweeps
# ---------------------------------------------------------------------------

def scenario_miru_config(tasks: list[TaskData], n_h: int = 100):
    """MiRUConfig sized to a task sequence: n_x from the feature width,
    n_y from the label range across *all* tasks (class-incremental
    streams allocate the full expanding head up front)."""
    from repro.core.miru import MiRUConfig
    F = tasks[0].x_train.shape[2]
    n_y = int(max(int(t.y_train.max()) for t in tasks)) + 1
    return MiRUConfig(n_x=F, n_h=n_h, n_y=max(n_y, 2))


def run_sweep(scenarios: Sequence[str], backends: Sequence[str],
              trainer: Optional[TrainerSpec] = None,
              replay: Optional[ReplaySpec] = None,
              *, seed: int = 0, seeds: Optional[Sequence[int]] = None,
              n_h: int = 100, meter: bool = True,
              scenario_kwargs: Optional[dict] = None,
              obs: Optional[Any] = None) -> dict[str, Any]:
    """The scenario × backend grid. Each cell runs the compiled sweep
    (falling back to the Python loop for non-uniform streams) and reports
    average accuracy, forgetting, BWT, FWT — and, when ``meter`` is set
    and the substrate is a metered device, the live-metered power and
    GOPS/W from ``repro.telemetry``.

    ``obs`` (an :class:`repro.obs.ObsSpec`) rides into every cell's
    :func:`run_compiled`: each cell opens a ``cell:{scenario}/{backend}``
    span on the tracer, metered cells grow a ``timeline`` section in
    their report, and the cell dict carries ``compile_s``/``execute_s``.

    Returns ``{"cells": {f"{scenario}/{backend}": cell, ...}, ...}``.
    """
    from repro.analog.costmodel import M2RUCostModel
    from repro.analog.endurance import EnduranceTracker
    from repro.telemetry import telemetry_report

    trainer = trainer if trainer is not None else TrainerSpec()
    skw = dict(scenario_kwargs or {})
    cells: dict[str, Any] = {}
    for sc_name in scenarios:
        sc = get_scenario(sc_name)
        tasks = sc.build(seed, **skw)
        cfg = scenario_miru_config(tasks, n_h=n_h)
        tsp = dataclasses.replace(trainer, **sc.trainer_overrides)
        # Scenario-conditional replay: the stream's preferred policy
        # applies unless the caller pinned one (same resolution rule as
        # trainer_overrides).
        rsp = sc.resolve_replay(replay)
        for be_name in backends:
            backend = get_backend(be_name)
            metered = meter and backend.spec.input_bits is not None
            if metered:
                backend.telemetry.enable()
                # Endurance tracking rides along: the compiled run's
                # write-count maps land in the tracker host-side, so the
                # cell gets lifetime columns (incl. per-cell ζ write-rate
                # percentiles) at no extra trace cost.
                if backend.tracker is None:
                    backend.tracker = EnduranceTracker()
            tracer = getattr(obs, "tracer", None) if obs is not None \
                else None
            cell_scope = tracer.span(f"cell:{sc_name}/{be_name}") \
                if tracer is not None else contextlib.nullcontext()
            with cell_scope:
                res = run_compiled(cfg, tsp, tasks, replay=rsp,
                                   device=backend, seeds=seeds,
                                   uniform=sc.uniform, obs=obs,
                                   pad=sc.pad)
            cell = {
                "scenario": sc_name, "backend": be_name,
                "replay_policy": rsp.resolved_policy,
                "compiled": res["compiled"],
                "MA": res["MA"],
                "metrics": res["metrics"],
                "wall_s": res.get("wall_s"),
                "R": np.asarray(res["R"]).tolist(),
            }
            if "metrics_std" in res:
                cell["metrics_std"] = res["metrics_std"]
            if "compile_s" in res:
                cell["compile_s"] = res["compile_s"]
                cell["execute_s"] = res["execute_s"]
            if "runlog" in res:
                cell["runlog"] = res["runlog"]
            if metered:
                kind = "cmos" if be_name == "cmos" else "analog"
                rep = telemetry_report(
                    backend.telemetry, model=M2RUCostModel(n_h=n_h),
                    kind=kind, tracker=backend.tracker,
                    runlog=res.get("runlog"))
                cell["power_mw"] = rep["metered"]["power_mw"]
                cell["gops_per_w"] = rep["metered"]["gops_per_w"]
                cell["pj_per_op"] = rep["metered"]["pj_per_op"]
                if "lifetime" in rep:
                    lt = rep["lifetime"]
                    cell["lifetime_years"] = lt["years_mean"]
                    cell["lifetime_hot_tail_years"] = lt["years_hot_tail"]
                    # Per-cell ζ write-rate percentiles, not just the
                    # mean — the wear spread across the write map.
                    cell["zeta_write_rate"] = lt["rate_percentiles"]
            cells[f"{sc_name}/{be_name}"] = cell
    return {"cells": cells,
            "scenarios": list(scenarios), "backends": list(backends),
            "n_h": n_h, "seed": seed,
            "seeds": list(seeds) if seeds is not None else None}
