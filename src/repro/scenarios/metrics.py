"""Standard continual-learning metrics from the accuracy matrix.

``R[t, i]`` is accuracy on task i after training through task t (the
Fig. 4 protocol's matrix; eq. 20's MA is the mean of the final row).
The sweep runner evaluates *every* task after every task, so its
``R_full`` also populates the upper triangle (accuracy on not-yet-seen
tasks), which is what forward transfer needs. The lower-triangular
metrics (average accuracy, forgetting, BWT) are defined on either form.

Definitions (Lopez-Paz & Ranzato, 2017; Chaudhry et al., 2018):

  average accuracy  ACC  = mean_i R[T-1, i]
  backward transfer BWT  = mean_{i<T-1} (R[T-1, i] − R[i, i])
  forgetting        F    = mean_{i<T-1} (max_{t∈[i,T-2]} R[t, i] − R[T-1, i])
  forward transfer  FWT  = mean_{i≥1} (R[i-1, i] − b[i])

where b[i] is the accuracy of the untrained (initialization) model on
task i. BWT ≤ 0 means forgetting; F is its nonnegative max-referenced
form; FWT > 0 means earlier tasks prime later ones.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def _as_matrix(R) -> np.ndarray:
    R = np.asarray(R, dtype=np.float64)
    if R.ndim != 2 or R.shape[0] != R.shape[1]:
        raise ValueError(f"R must be a square (n_tasks, n_tasks) matrix, "
                         f"got shape {R.shape}")
    return R


def average_accuracy(R) -> float:
    """Mean final-row accuracy (eq. 20's MA)."""
    return float(_as_matrix(R)[-1].mean())


def backward_transfer(R) -> float:
    """BWT: how training on later tasks changed earlier-task accuracy.
    0 for a single task."""
    R = _as_matrix(R)
    n = R.shape[0]
    if n < 2:
        return 0.0
    return float(np.mean([R[-1, i] - R[i, i] for i in range(n - 1)]))


def forgetting(R) -> float:
    """Average forgetting: drop from each task's best-ever accuracy
    (while it was still being revisited) to its final accuracy.
    0 for a single task; ≥ max(0, −BWT)."""
    R = _as_matrix(R)
    n = R.shape[0]
    if n < 2:
        return 0.0
    return float(np.mean([R[i:n - 1, i].max() - R[-1, i]
                          for i in range(n - 1)]))


def forward_transfer(R_full, baseline) -> float:
    """FWT from a fully-populated R (upper triangle = accuracy on unseen
    tasks) against the untrained-model baseline accuracies b[i]."""
    R = _as_matrix(R_full)
    b = np.asarray(baseline, dtype=np.float64)
    n = R.shape[0]
    if n < 2:
        return 0.0
    if b.shape != (n,):
        raise ValueError(f"baseline must have shape ({n},), got {b.shape}")
    return float(np.mean([R[i - 1, i] - b[i] for i in range(1, n)]))


def continual_metrics(R, baseline: Optional[np.ndarray] = None) -> dict:
    """All metrics for one run. ``forward_transfer`` is included only when
    the untrained-model ``baseline`` row is supplied (and R's upper
    triangle is populated — the compiled sweep does both)."""
    out = {
        "average_accuracy": average_accuracy(R),
        "backward_transfer": backward_transfer(R),
        "forgetting": forgetting(R),
    }
    if baseline is not None:
        out["forward_transfer"] = forward_transfer(R, baseline)
    return out
