"""The ``ReplayPolicy`` protocol and the name-keyed policy registry.

A replay policy owns the two decisions the rehearsal pipeline makes:

  select-on-insert   which buffer slot (if any) an offered example
                     overwrites — the paper's counter + xorshift32 +
                     modulus hardware implements the ``reservoir``
                     answer (Algorithm R);
  select-on-sample   which occupied slots a rehearsal batch reads.

Policies are host-side objects driven by :class:`repro.core.replay.
ReplayBuffer` while the batch schedule is materialized
(``core.continual.build_batch_schedule``). A policy whose insertion
decision depends on *training state* (``loss_aware``) cannot be
materialized up front: it sets ``in_graph = True`` and the trainer
carries a device-resident buffer through the step scan instead
(:mod:`repro.replay.ingraph`).

    @register_policy("my_policy")
    class MyPolicy(ReplayPolicy):
        def select_insert(self, y, task_id=0): ...
        def select_sample(self, rng, batch): ...

See docs/replay.md for the contracts each policy must keep.
"""
from __future__ import annotations

from typing import Optional, Type

import numpy as np


class ReplayPolicy:
    """Base class: slot selection for insert and sample.

    ``capacity`` is the total number of buffer slots; ``seed`` feeds the
    policy's own deterministic RNG (policies must never touch global RNG
    state — schedules are bit-reproducible). ``n_classes`` / ``n_tasks``
    give stream context to partitioned policies; unused kwargs are
    accepted so every policy constructs through one uniform signature.
    """

    name: str = "?"
    #: True when insertion depends on training state, so the buffer must
    #: live in-graph (scan-carried) instead of in the host schedule.
    in_graph: bool = False

    def __init__(self, capacity: int, seed: int = 7, *,
                 n_classes: Optional[int] = None,
                 n_tasks: Optional[int] = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.seed = seed
        self.n_classes = n_classes
        self.n_tasks = n_tasks

    # ------------------------------------------------------------------
    def select_insert(self, y: int, task_id: int = 0) -> Optional[int]:
        """Offer one (label, task) example; return the slot index to
        overwrite, or None to reject the example."""
        raise NotImplementedError

    def select_sample(self, rng: np.random.Generator, batch: int
                      ) -> np.ndarray:
        """Return ``batch`` occupied slot indices for a rehearsal draw.
        Draws exclusively from ``rng`` (the schedule's host RNG)."""
        raise NotImplementedError

    @property
    def occupancy(self) -> int:
        """Number of currently occupied slots."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Type[ReplayPolicy]] = {}


def register_policy(name: str):
    """Register a policy class under ``name`` (usable as a decorator).
    Re-registering overwrites (tests, experiments)."""
    def _do(cls: Type[ReplayPolicy]) -> Type[ReplayPolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return _do


def unregister_policy(name: str) -> None:
    """Remove a registered policy (test teardown helper)."""
    _REGISTRY.pop(name, None)


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_policy_class(name: str) -> Type[ReplayPolicy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown replay policy {name!r}; "
            f"available: {', '.join(available_policies()) or '(none)'}"
        ) from None


def make_policy(name: str, capacity: int, seed: int = 7, *,
                n_classes: Optional[int] = None,
                n_tasks: Optional[int] = None, **kwargs) -> ReplayPolicy:
    """Instantiate a registered policy with stream context."""
    return get_policy_class(name)(capacity, seed, n_classes=n_classes,
                                  n_tasks=n_tasks, **kwargs)
