"""In-graph replay buffer: a device-resident, scan-carried rehearsal
store for training-state-dependent policies (``loss_aware``).

Host-side policies decide slot selection while the batch schedule is
materialized, *before* training starts — possible only because their
decisions never look at training state. Loss-prioritized replay does:
an example's insertion priority is its last-seen loss. So the buffer
here is a plain pytree of arrays — quantized feature codes, labels,
priorities, an occupancy counter — threaded through the training step
as part of the ``lax.scan`` carry, with pure functions for the three
buffer operations:

  ingraph_init     allocate the empty buffer
  ingraph_insert   offer a batch (fill → evict-min-priority when full)
  ingraph_sample / ingraph_mix
                   priority-proportional rehearsal draw, spliced into
                   the tail of the fresh batch

Everything is a deterministic function of (state, PRNG key, inputs):
the same step sequence produces bit-identical buffers whether the steps
run as a Python loop of jitted calls or inside one ``lax.scan`` — the
property the loop/compiled parity tests pin down.

Features are stored as stochastic-quantized integer codes (same
quantizer and dtype rule as the host buffer: uint8 up to 8 bits, uint16
up to 16) and dequantized on the paper's 1/2^n scale at sample time.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.replay import code_dtype, dequantize, stochastic_quantize

ReplayState = dict[str, jax.Array]

#: Priority floor added before the log in priority-proportional sampling:
#: keeps just-filled (zero-priority) slots drawable and the categorical
#: logits finite.
_PRIO_EPS = 1e-6


def ingraph_init(capacity: int, feature_shape: tuple[int, ...],
                 n_bits: int) -> ReplayState:
    """The empty buffer: all slots unoccupied (``size == 0``)."""
    return {
        "feat": jnp.zeros((capacity, *feature_shape),
                          dtype=code_dtype(n_bits)),
        "label": jnp.zeros((capacity,), jnp.int32),
        "prio": jnp.zeros((capacity,), jnp.float32),
        "size": jnp.zeros((), jnp.int32),
    }


def ingraph_insert(state: ReplayState, key: jax.Array, xs: jax.Array,
                   ys: jax.Array, prios: jax.Array, n_bits: int,
                   valid: Optional[jax.Array] = None,
                   decay: float = 1.0,
                   n_classes: Optional[int] = None) -> ReplayState:
    """Offer a batch of (features, label, priority) rows sequentially.

    While the buffer is filling, every valid row is appended. Once full,
    a row replaces the current minimum-priority slot iff its priority
    exceeds it — the buffer keeps the ``capacity`` highest-last-seen-loss
    examples seen so far. ``valid`` masks rows that must not be offered
    (rehearsed rows spliced into the batch tail are never re-offered,
    mirroring the host schedule's fresh-rows-only rule).

    ``decay`` < 1 applies a *staleness decay* to every stored priority
    once per offer round, before the new rows compete: CE scores are
    nonstationary (the model keeps training after a row is scored), so
    an undecayed stored score is not comparable to a fresh one.
    ``decay=1`` reproduces the legacy no-decay buffer bit-for-bit.

    ``n_classes`` switches eviction to *class-aware* loss prioritization
    — the fix for the loss_aware task-boundary collapse. With global
    min-priority eviction, every task boundary floods the buffer: the
    new task's fresh rows are scored under a model that has never seen
    their classes, so their CE beats anything stored (decayed or not)
    and within a few batches the buffer holds only current-task rows —
    rehearsal then protects nothing and class-incremental accuracy
    collapses to last-task-only. Class-aware eviction keeps the
    *coverage* invariant instead: an incoming row whose class is
    under-represented always enters by evicting the minimum-priority
    slot of the most-over-represented class; a row of an already-largest
    class must beat the minimum stored priority of its own class. Slot
    occupancy stays balanced across observed classes (the property that
    makes the host ``class_balanced`` policy work), while retention
    *within* a class — and the rehearsal draw itself — remain
    loss-prioritized. ``n_classes=None`` is the legacy global rule.

    Rows are stochastically quantized with per-row keys folded from
    ``key`` — one vmapped dispatch, like the host buffer's add_batch.
    """
    B = xs.shape[0]
    capacity = state["feat"].shape[0]
    if valid is None:
        valid = jnp.ones((B,), bool)
    if decay != 1.0:
        state = dict(state)
        state["prio"] = state["prio"] * jnp.float32(decay)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(B))
    q = jax.vmap(lambda x, k: stochastic_quantize(x, k, n_bits))(xs, keys)

    def body(i, st):
        size = st["size"]
        full = size >= capacity
        if n_classes is None:
            evict = jnp.argmin(st["prio"]).astype(jnp.int32)
            beat = prios[i] > st["prio"][evict]
        else:
            occ = jnp.arange(capacity) < size
            counts = jnp.zeros((n_classes,), jnp.int32) \
                .at[st["label"]].add(occ.astype(jnp.int32),
                                     mode="drop")
            cls = ys[i].astype(jnp.int32)
            big = jnp.argmax(counts).astype(jnp.int32)
            under = counts[cls] < counts[big]
            victim_cls = jnp.where(under, big, cls)
            in_cls = (st["label"] == victim_cls) & occ
            evict = jnp.argmin(
                jnp.where(in_cls, st["prio"], jnp.inf)).astype(jnp.int32)
            beat = under | (prios[i] > st["prio"][evict])
        slot = jnp.where(full, evict, size)
        accept = valid[i] & (~full | beat)
        return {
            "feat": st["feat"].at[slot].set(
                jnp.where(accept, q[i], st["feat"][slot])),
            "label": st["label"].at[slot].set(
                jnp.where(accept, ys[i].astype(jnp.int32),
                          st["label"][slot])),
            "prio": st["prio"].at[slot].set(
                jnp.where(accept, prios[i], st["prio"][slot])),
            "size": jnp.minimum(size + accept.astype(jnp.int32), capacity),
        }

    return jax.lax.fori_loop(0, B, body, state)


def ingraph_sample(state: ReplayState, key: jax.Array, batch: int,
                   n_bits: int, n_classes: Optional[int] = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Priority-proportional rehearsal draw (with replacement) over the
    occupied slots: P(slot) ∝ priority + ε. Dequantizes on the paper's
    1/2^n scale. On an empty buffer the draw degenerates to slot 0
    (zeros) — callers gate mixing on ``size > 0``.

    With ``n_classes`` the priorities are *normalized per class* before
    the draw: each observed class gets equal total probability, split
    within the class ∝ priority. Raw global weighting concentrates the
    rehearsal draw on whichever rows were scored most recently (their CE
    is least decayed and the model least trained on them — i.e. the
    current task), starving the very classes rehearsal exists to
    protect; class normalization keeps the exposure balanced while
    retention and within-class emphasis stay loss-aware."""
    capacity = state["feat"].shape[0]
    occupied = jnp.arange(capacity) < state["size"]
    pr = jnp.where(occupied, state["prio"] + _PRIO_EPS, 0.0)
    if n_classes is not None:
        cls_sum = jnp.zeros((n_classes,), pr.dtype) \
            .at[state["label"]].add(pr, mode="drop")
        pr = pr / jnp.maximum(cls_sum[state["label"]], _PRIO_EPS)
    logits = jnp.where(occupied, jnp.log(pr), -jnp.inf)
    safe = jnp.where(jnp.arange(capacity) == 0, 0.0, -jnp.inf)
    logits = jnp.where(state["size"] > 0, logits, safe)
    idx = jax.random.categorical(key, logits, shape=(batch,))
    return dequantize(state["feat"][idx], n_bits), state["label"][idx]


def ingraph_mix(state: ReplayState, key: jax.Array, x: jax.Array,
                y: jax.Array, n_rep: int, active: jax.Array, n_bits: int,
                n_classes: Optional[int] = None
                ) -> tuple[jax.Array, jax.Array]:
    """Replace the tail ``n_rep`` rows of a fresh batch with a rehearsal
    draw when ``active`` (a traced bool: replay enabled, past task 0,
    buffer non-empty) — the same tail-splice layout the host schedule
    materializes. ``n_classes`` enables the class-normalized draw (see
    :func:`ingraph_sample`)."""
    if n_rep <= 0:
        return x, y
    B = x.shape[0]
    active = active & (state["size"] > 0)
    xr, yr = ingraph_sample(state, key, n_rep, n_bits, n_classes)
    mixed_x = jnp.concatenate([x[:B - n_rep], xr.astype(x.dtype)])
    mixed_y = jnp.concatenate([y[:B - n_rep], yr.astype(y.dtype)])
    return (jnp.where(active, mixed_x, x), jnp.where(active, mixed_y, y))


def per_example_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example softmax cross-entropy — the ``loss_aware`` priority
    signal (utils.softmax_cross_entropy reduces to the batch mean)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return logz - label_logits
