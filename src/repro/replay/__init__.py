"""Pluggable replay-policy subsystem (§IV-A's rehearsal, generalized).

- base:     the ``ReplayPolicy`` protocol (select-on-insert +
            select-on-sample) and the name-keyed registry.
- policies: registered implementations — ``reservoir`` (the paper's
            hardware sampler, bit-identical default), ``ring`` (FIFO),
            ``class_balanced``, ``task_stratified`` (partitioned
            reservoirs), ``loss_aware`` (in-graph, loss-prioritized).
- ingraph:  the device-resident, scan-carried buffer that
            training-state-dependent policies run on.

Wired through ``ReplaySpec.policy``, scenario metadata
(``ScenarioSpec.replay_policy``), the telemetry DRAM-traffic meters,
``examples/continual_learning.py --replay-policy`` and the
``benchmarks/scenarios_grid.py`` policy columns. See docs/replay.md.
"""
from repro.replay.base import (ReplayPolicy, available_policies,
                               get_policy_class, make_policy,
                               register_policy, unregister_policy)
from repro.replay.ingraph import (ingraph_init, ingraph_insert,
                                  ingraph_mix, ingraph_sample,
                                  per_example_ce)
from repro.replay.policies import (ClassBalancedPolicy, LossAwarePolicy,
                                   ReservoirPolicy, RingPolicy,
                                   TaskStratifiedPolicy)

__all__ = [
    "ReplayPolicy", "available_policies", "get_policy_class",
    "make_policy", "register_policy", "unregister_policy",
    "ReservoirPolicy", "RingPolicy", "ClassBalancedPolicy",
    "TaskStratifiedPolicy", "LossAwarePolicy",
    "ingraph_init", "ingraph_insert", "ingraph_mix", "ingraph_sample",
    "per_example_ce",
]
