"""Registered host-side replay policies.

All schedule-time policies are built from the paper's hardware RNG
primitives (:class:`repro.core.replay.Xorshift32`,
:class:`~repro.core.replay.ReservoirSampler`) so every schedule stays a
bit-reproducible function of (trainer seed, stream). The ``reservoir``
policy is the pre-refactor behavior bit-for-bit — same sampler seed
derivation, same host-RNG consumption on sample — which is what keeps
the pinned schedule golden hash (tests/test_determinism.py) and the
loop/compiled parity gates green.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.replay import ReservoirSampler, Xorshift32
from repro.replay.base import ReplayPolicy, register_policy

# The seed whitening ReplayBuffer has always applied to its sampler;
# kept here so policy-built samplers walk the identical xorshift stream.
_SAMPLER_SEED_XOR = 0x5BD1E995


def _region_seed(seed: int, region: int) -> int:
    """Per-region sampler seed: decorrelated, deterministic, 32-bit."""
    return (seed ^ _SAMPLER_SEED_XOR
            ^ ((region + 1) * 0x9E3779B9)) & 0xFFFFFFFF


@register_policy("reservoir")
class ReservoirPolicy(ReplayPolicy):
    """Algorithm-R over the whole stream — the paper's §IV-A hardware
    (counter + xorshift32 + modulus) and the default policy. Every stream
    element ends up in the buffer with equal probability k/i; sampling is
    uniform over the occupied prefix."""

    def __init__(self, capacity: int, seed: int = 7, *,
                 n_classes: Optional[int] = None,
                 n_tasks: Optional[int] = None):
        super().__init__(capacity, seed, n_classes=n_classes,
                         n_tasks=n_tasks)
        self.sampler = ReservoirSampler(capacity,
                                        seed=seed ^ _SAMPLER_SEED_XOR)

    def select_insert(self, y: int, task_id: int = 0) -> Optional[int]:
        return self.sampler.offer()

    def select_sample(self, rng: np.random.Generator, batch: int
                      ) -> np.ndarray:
        # Exactly the pre-refactor draw: one integers() call over the
        # occupied prefix [0, size).
        return rng.integers(0, self.occupancy, size=batch)

    @property
    def occupancy(self) -> int:
        return min(self.sampler.count, self.capacity)


@register_policy("ring")
class RingPolicy(ReplayPolicy):
    """FIFO ring: every offer is accepted and overwrites the oldest slot.
    Maximal recency — the right bias under fast domain drift, the wrong
    one for long-range retention. Identical to ``reservoir`` for the
    first ``capacity`` offers (both fill slots 0..capacity-1 in order)."""

    def __init__(self, capacity: int, seed: int = 7, *,
                 n_classes: Optional[int] = None,
                 n_tasks: Optional[int] = None):
        super().__init__(capacity, seed, n_classes=n_classes,
                         n_tasks=n_tasks)
        self.count = 0

    def select_insert(self, y: int, task_id: int = 0) -> Optional[int]:
        slot = self.count % self.capacity
        self.count += 1
        return slot

    def select_sample(self, rng: np.random.Generator, batch: int
                      ) -> np.ndarray:
        return rng.integers(0, self.occupancy, size=batch)

    @property
    def occupancy(self) -> int:
        return min(self.count, self.capacity)


class _BalancedPolicy(ReplayPolicy):
    """Shared machinery for group-balanced reservoirs (the CBRS scheme —
    Chrysakis & Moens 2020): the buffer always runs at full capacity;
    groups (classes or tasks) are discovered as they appear in the
    stream and share it dynamically.

      fill      while slots are free, every offer is accepted;
      largest   once full, an offer from a currently-largest group runs
                an in-group Algorithm-R (kept with probability
                m_g / n_g, replacing a uniformly drawn member);
      smaller   an offer from any other group always enters, evicting a
                uniformly drawn member of a (uniformly drawn) largest
                group.

    A *static* equal partition would idle the regions of groups that
    have not arrived yet — exactly when rehearsal diversity matters
    most; the dynamic share keeps every slot in use while guaranteeing
    that early groups are never crowded out (once full, group sizes
    re-balance toward ±1 of each other as new groups stream in).

    Slot selection draws from the policy's own Xorshift32 (the paper's
    hardware RNG) so schedules stay bit-reproducible; sampling is
    group-balanced — uniform over seen groups, then uniform within the
    group's members.
    """

    def __init__(self, capacity: int, seed: int = 7, **kwargs):
        super().__init__(capacity, seed, **kwargs)
        self._rng = Xorshift32(_region_seed(seed, 0))
        self._filled = 0
        # group key -> list of owned slot indices; insertion-ordered
        # (dict) so iteration order is deterministic.
        self._members: dict[int, list[int]] = {}
        self._seen: dict[int, int] = {}     # group -> stream count n_g

    def _group_of(self, y: int, task_id: int) -> int:
        raise NotImplementedError

    def select_insert(self, y: int, task_id: int = 0) -> Optional[int]:
        g = self._group_of(int(y), int(task_id))
        self._seen[g] = self._seen.get(g, 0) + 1
        members = self._members.setdefault(g, [])
        if self._filled < self.capacity:
            slot = self._filled
            self._filled += 1
            members.append(slot)
            return slot
        max_m = max(len(m) for m in self._members.values())
        if len(members) >= max_m:
            # Largest group: in-group reservoir over its own stream.
            j = self._rng.randint(1, self._seen[g])
            return members[j - 1] if j <= len(members) else None
        # Under-represented group: take a slot from a largest group.
        largest = [k for k, m in self._members.items()
                   if len(m) == max_m]
        donor = largest[self._rng.randint(0, len(largest) - 1)]
        k = self._rng.randint(0, max_m - 1)
        slot = self._members[donor].pop(k)
        members.append(slot)
        return slot

    def select_sample(self, rng: np.random.Generator, batch: int
                      ) -> np.ndarray:
        groups = [g for g, m in self._members.items() if m]
        counts = np.array([len(self._members[g]) for g in groups])
        gi = rng.integers(0, len(groups), size=batch)
        local = rng.integers(0, counts[gi])
        return np.array([self._members[groups[a]][b]
                         for a, b in zip(gi, local)])

    def group_sizes(self) -> dict[int, int]:
        """Buffer share per seen group (occupancy bookkeeping — the
        balance invariant the tests pin)."""
        return {g: len(m) for g, m in self._members.items()}

    @property
    def occupancy(self) -> int:
        return self._filled


@register_policy("class_balanced")
class ClassBalancedPolicy(_BalancedPolicy):
    """Class-balanced reservoir for the expanding-head
    ``class_incremental`` stream: seen classes share the full buffer
    dynamically (±1 once balanced), so early classes keep their share —
    and stay in the rehearsal mix — no matter how many new classes
    stream in later, and draws are class-uniform instead of
    stream-frequency-weighted. ``n_classes`` (the full head) is
    accepted for context but classes are discovered as they arrive."""

    def _group_of(self, y: int, task_id: int) -> int:
        return y


@register_policy("task_stratified")
class TaskStratifiedPolicy(_BalancedPolicy):
    """Task-stratified reservoir: seen tasks share the full buffer
    dynamically, so every past domain keeps representation regardless
    of how many examples later tasks stream; rehearsal is stratified
    uniformly over seen tasks."""

    def _group_of(self, y: int, task_id: int) -> int:
        return task_id


@register_policy("loss_aware")
class LossAwarePolicy(ReplayPolicy):
    """Loss-prioritized replay. Insertion keeps the highest-last-seen-loss
    examples (fill while not full; then evict the minimum-priority slot
    when the newcomer's loss exceeds it) and sampling is
    priority-proportional. Because the priority *is* training state, this
    policy cannot be materialized into a host schedule: ``in_graph=True``
    routes the trainer onto the scan-carried device-resident buffer in
    :mod:`repro.replay.ingraph`, and the host-side hooks below are never
    called."""

    in_graph = True

    def select_insert(self, y: int, task_id: int = 0) -> Optional[int]:
        raise RuntimeError(
            "loss_aware is an in-graph policy; insertion happens inside "
            "the compiled step (repro.replay.ingraph), not on the host "
            "schedule path")

    def select_sample(self, rng: np.random.Generator, batch: int
                      ) -> np.ndarray:
        raise RuntimeError(
            "loss_aware is an in-graph policy; sampling happens inside "
            "the compiled step (repro.replay.ingraph), not on the host "
            "schedule path")

    @property
    def occupancy(self) -> int:
        return 0
