"""llava-next-34b [vlm]: yi-34b backbone (60L, d_model=7168, 56H kv=8,
d_ff=20480, vocab=64000) + anyres vision frontend (stub patch
embeddings). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Anyres tiling: base 576 patches + 4 tiles × 576 = 2880 image tokens,
provided precomputed by input_specs per the brief. long_500k skipped."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    frontend="vision",
    n_frontend_tokens=2880,
    kv_cache_dtype="int8",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke",
        family="vlm",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        frontend="vision",
        n_frontend_tokens=8,
        kv_cache_dtype="int8",
        dtype=jnp.float32,
    )
