"""seamless-m4t-medium [audio]: enc-dec, 12L, d_model=1024, 16H (kv=16),
d_ff=4096, vocab=256206. [arXiv:2308.11596]

Multimodal: the speech frontend is a stub — input_specs provides
precomputed frame embeddings (B, T_enc, d_model) per the brief. 12L is
read as 12 encoder + 12 decoder layers (the M4T medium speech-to-text
stack). MHA (kv == heads). Full attention ⇒ long_500k skipped.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    n_enc_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    frontend="audio",
    max_enc_len=4096,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke",
        family="audio",
        n_layers=2,
        n_enc_layers=2,
        is_encoder_decoder=True,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        head_dim=8,
        d_ff=64,
        vocab=128,
        frontend="audio",
        max_enc_len=16,
        dtype=jnp.float32,
    )
