"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Every assigned architecture (plus the paper's own M2RU network) is a
module exporting CONFIG (full size — dry-run only) and smoke_config()
(reduced — runs a real step on CPU in tests).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-0.5b": "qwen2_0_5b",
    "yi-34b": "yi_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llava-next-34b": "llava_next_34b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-370m": "mamba2_370m",
}


def list_archs() -> list[str]:
    return list(ARCH_MODULES)


def _module(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


__all__ = ["ModelConfig", "ARCH_MODULES", "list_archs", "get_config",
           "get_smoke_config"]
