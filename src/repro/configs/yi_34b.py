"""yi-34b [dense]: 60L, d_model=7168, 56H (GQA kv=8), d_ff=20480,
vocab=64000 — llama-arch GQA. [arXiv:2403.04652]
Full attention ⇒ long_500k skipped. decode_32k uses the int8
stochastic-quantized KV cache (EXPERIMENTS.md §Perf) to fit 16 GB/chip."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    kv_cache_dtype="int8",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        kv_cache_dtype="int8",
        dtype=jnp.float32,
    )
