"""internlm2-1.8b [dense]: 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192,
vocab=92544. [arXiv:2403.17297]  Full attention ⇒ long_500k skipped."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92544,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        dtype=jnp.float32,
    )
