"""qwen3-4b [dense]: 36L, d_model=2560, 32H (GQA kv=8), d_ff=9728,
vocab=151936, qk-norm. [hf:Qwen/Qwen3-8B]  long_500k skipped."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        qk_norm=True,
        dtype=jnp.float32,
    )
