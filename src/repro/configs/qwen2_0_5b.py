"""qwen2-0.5b [dense]: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864,
vocab=151936, QKV bias, tied embeddings. [arXiv:2407.10671]
long_500k skipped."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        qkv_bias=True,
        tie_embeddings=True,
        dtype=jnp.float32,
    )
