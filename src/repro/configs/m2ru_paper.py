"""The paper's own network: 28×100×10 MiRU (Table I), plus the n_h=256
variant (Fig. 4b/4d). This is a MiRUConfig, not a ModelConfig — the
continual-learning stack (repro.core) consumes it directly."""
from repro.core.miru import MiRUConfig

PAPER_CONFIG = MiRUConfig(n_x=28, n_h=100, n_y=10, beta=0.8, lam=0.5)
PAPER_CONFIG_256 = MiRUConfig(n_x=28, n_h=256, n_y=10, beta=0.8, lam=0.5)
CIFAR_FEATURE_CONFIG = MiRUConfig(n_x=32, n_h=100, n_y=2, beta=0.8, lam=0.5)
