"""Assigned input shapes × per-arch input_specs() (ShapeDtypeStructs).

  train_4k     seq 4096  × global_batch 256   (training step)
  prefill_32k  seq 32768 × global_batch 32    (inference prefill)
  decode_32k   KV 32768  × global_batch 128   (one-token decode)
  long_500k    KV 524288 × global_batch 1     (long-context decode;
                                               SSM/hybrid only)

decode shapes lower ``serve_step`` (decode_step with the cache passed as
an input ShapeDtypeStruct); train_4k lowers ``train_step``; prefill
lowers the forward. Multimodal archs receive stub frame/patch embeddings
in the batch (the brief: frontend is a stub providing precomputed
embeddings)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if shape_name == "long_500k" and cfg.layer_pattern == "attn":
        return ("pure full-attention arch: O(L²) attention at 524k context "
                "— skipped per brief; run only for SSM/hybrid")
    return None


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.is_encoder_decoder:
        enc = min(S, cfg.max_enc_len)
        batch["frames"] = _sds((B, enc, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds((B, S), jnp.int32)
    elif cfg.frontend == "vision":
        n_img = cfg.n_frontend_tokens
        batch["patches"] = _sds((B, n_img, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds((B, S - n_img), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    t = batch["tokens"].shape
    batch["labels"] = _sds(t, jnp.int32)
    batch["mask"] = _sds(t, jnp.float32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    from repro.models import lm
    B, S = shape.global_batch, shape.seq_len
    enc_len = min(4096, cfg.max_enc_len) if cfg.is_encoder_decoder else 0
    caches = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, S, enc_len=enc_len))
    return {
        "caches": caches,
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return train_batch_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
