"""granite-moe-3b-a800m [moe]: 32L, d_model=1536, 24H (GQA kv=8),
expert d_ff=512, vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]  long_500k skipped.

40 experts do not divide the 16-way model axis ⇒ expert bank shards
TP-over-F instead of EP (distributed/sharding.py fallback)."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,                     # all layers MoE
    vocab=49155,
    n_experts=40,
    top_k=8,
    moe_d_ff=512,
    capacity_factor=1.25,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke",
        family="moe",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=0,
        vocab=128,
        n_experts=4,
        top_k=2,
        moe_d_ff=16,
        dtype=jnp.float32,
    )
