"""deepseek-v3-671b [moe]: 61L, d_model=7168, 128H, MLA
(q_lora=1536, kv_lora=512, nope=128, rope=64, v=128), MoE 256 routed
top-8 + 1 shared (expert d_ff=2048), first 3 layers dense (d_ff=18432),
vocab=129280. [arXiv:2412.19437]

MTP (multi-token prediction) head is NOT implemented — it is a training-
objective add-on orthogonal to the paper's technique (DESIGN.md §5).
MLA is O(L²) attention ⇒ long_500k skipped. Decode caches latents only.
Optimizer for this config defaults to 8-bit Adam moments (optim.qstate).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense layers (first 3)
    vocab=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    capacity_factor=1.25,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke",
        family="moe",
        n_layers=3,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=128,
        use_mla=True,
        q_lora_rank=24,
        kv_lora_rank=16,
        qk_nope_head_dim=8,
        qk_rope_head_dim=4,
        v_head_dim=8,
        n_experts=4,
        n_shared_experts=1,
        top_k=2,
        moe_d_ff=16,
        first_dense_layers=1,
        dtype=jnp.float32,
    )
