"""jamba-1.5-large-398b [hybrid]: 72L, d_model=8192, 64H (GQA kv=8),
d_ff=24576, vocab=65536, MoE 16e top-2 every other layer, Mamba:attn 7:1
interleave. [arXiv:2403.19887]

Layer pattern: period-8 superblocks — 7 SSD mixers + 1 attention (slot 4);
MoE FFN on odd slots, dense FFN on even. Param total ≈ 398 B (validated in
tests/test_configs.py). SSD mixer follows Mamba-2 (the assigned pool pairs
this entry with the SSD formulation; Jamba's original Mamba-1 layers are
adapted to SSD — DESIGN.md §2). Hybrid ⇒ long_500k RUNS."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    layer_pattern="hybrid",
    attn_every=8,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=8,
    kv_cache_dtype="int8",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-smoke",
        family="hybrid",
        n_layers=4,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        vocab=128,
        layer_pattern="hybrid",
        attn_every=4,
        n_experts=4,
        top_k=2,
        moe_d_ff=32,
        moe_every=2,
        ssm_state=16,
        ssm_head_dim=8,
        ssm_expand=2,
        ssm_groups=2,
        dtype=jnp.float32,
    )
