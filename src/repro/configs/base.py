"""ModelConfig — the single config record every architecture instantiates.

One file per assigned architecture lives next to this module; each exports
``CONFIG`` (full size, exercised only via the dry-run) and
``smoke_config()`` (reduced, runs a real forward/train step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads

    # Attention flavor
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen2
    rope_theta: float = 1e4
    attn_chunk: int = 1024            # flash-chunk size (S > chunk ⇒ chunked)

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0       # deepseek: first k layers dense
    moe_every: int = 1                # jamba: MoE every other layer ⇒ 2
    capacity_factor: float = 1.25

    # SSM / hybrid
    layer_pattern: str = "attn"       # "attn" | "ssm" | "hybrid"
    attn_every: int = 0               # hybrid: 1 attn per this many layers
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1

    # Encoder-decoder
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    max_enc_len: int = 4096

    # Modality frontend (stub embeddings per the brief)
    frontend: str = "none"            # none | audio | vision
    n_frontend_tokens: int = 0

    # Numerics / execution
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True
    quant_mode: str = "none"          # none | any repro.backends name (wbs…)
    kv_cache_dtype: str = "bf16"      # bf16 | int8 (stochastic-quantized)
    mixer: str = "default"            # default | miru (ablation, DESIGN §5)

    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model \
            // self.n_heads

    def is_ssm_layer(self, i: int) -> bool:
        if self.layer_pattern == "ssm":
            return True
        if self.layer_pattern == "hybrid":
            # Jamba: 1 attention per `attn_every` layers (1:7 ⇒ every 8th;
            # the attention layer sits mid-period, per the paper's fig.).
            return (i % self.attn_every) != (self.attn_every // 2)
        return False

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == (self.moe_every - 1) \
            if self.moe_every > 1 else True

    # ------------------------------------------------------------------
    # Parameter accounting (for MODEL_FLOPS = 6·N·D roofline term)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        D = self.d_model
        hd = self.hd()
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd

        if self.use_mla:
            attn = (D * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads
                    * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    + D * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_head_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * D)
        else:
            attn = D * q + 2 * D * kv + q * D

        dense_ffn = 3 * D * self.d_ff
        moe_ffn = self.n_experts * 3 * D * self.moe_d_ff \
            + self.n_shared_experts * 3 * D * self.moe_d_ff \
            + D * self.n_experts                    # router
        moe_active = ((self.top_k + self.n_shared_experts)
                      * 3 * D * self.moe_d_ff + D * self.n_experts)

        d_in = self.ssm_expand * D
        ssm = (D * (2 * d_in + 2 * self.ssm_groups * self.ssm_state
                    + d_in // self.ssm_head_dim)
               + d_in * D) if self.ssm_state else 0

        total = 0
        active = 0
        n_layers = self.n_layers
        for i in range(n_layers):
            if self.is_ssm_layer(i):
                total += ssm
                active += ssm
            else:
                total += attn
                active += attn
            if self.d_ff or self.n_experts:
                if self.is_moe_layer(i):
                    total += moe_ffn
                    active += moe_active
                elif self.d_ff:
                    total += dense_ffn
                    active += dense_ffn
        if self.is_encoder_decoder:
            # encoder: self-attn + ffn; decoder already counted above,
            # add cross-attention per decoder layer.
            total += self.n_enc_layers * (attn + dense_ffn)
            active += self.n_enc_layers * (attn + dense_ffn)
            total += n_layers * attn      # cross-attn
            active += n_layers * attn
        embed = self.vocab * D * (1 if self.tie_embeddings else 2)
        total += embed
        active += embed
        return {"total": total, "active": active}
