"""mamba2-370m [ssm]: 48L, d_model=1024, attn-free (d_ff=0),
vocab=50280, ssm_state=128 — SSD (state-space duality).
[arXiv:2405.21060]  SSM ⇒ long_500k RUNS (recurrent decode)."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,               # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    layer_pattern="ssm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        n_layers=2,
        d_model=32,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=128,
        layer_pattern="ssm",
        ssm_state=16,
        ssm_head_dim=8,
        ssm_expand=2,
        ssm_groups=1,
        tie_embeddings=True,
        dtype=jnp.float32,
    )
