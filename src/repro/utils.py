"""Shared small utilities: initializers, losses, tree helpers.

Kept dependency-free (jax + numpy only) so every layer of the framework can
import it without cycles.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def glorot_uniform(key: jax.Array, shape: tuple[int, ...],
                   dtype=jnp.float32, in_axis: int = -2,
                   out_axis: int = -1) -> jax.Array:
    """Glorot/Xavier uniform. Works for >=2-D shapes."""
    fan_in = shape[in_axis]
    fan_out = shape[out_axis]
    limit = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def normal_init(key: jax.Array, shape: tuple[int, ...], stddev: float,
                dtype=jnp.float32) -> jax.Array:
    return stddev * jax.random.normal(key, shape, dtype)


def truncated_normal_init(key: jax.Array, shape: tuple[int, ...],
                          stddev: float, dtype=jnp.float32) -> jax.Array:
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def onehot(labels: jax.Array, num_classes: int, dtype=jnp.float32) -> jax.Array:
    return jax.nn.one_hot(labels, num_classes, dtype=dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy. ``labels`` are integer class ids (...,)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - label_logits)


def softmax_cross_entropy_masked(logits: jax.Array, labels: jax.Array,
                                 mask: jax.Array) -> jax.Array:
    """Token-masked mean cross-entropy (LM training).

    logits (..., V); labels (...,) int; mask (...,) {0,1}.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    per_tok = (logz - label_logits) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok) / denom


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------

def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def flatten_dict(d: Mapping[str, Any], prefix: str = "",
                 sep: str = "/") -> dict[str, Any]:
    """Flatten a nested dict-of-dicts of arrays into {path: array}."""
    out: dict[str, Any] = {}
    for k, v in d.items():
        path = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, path, sep))
        else:
            out[path] = v
    return out


def unflatten_dict(flat: Mapping[str, Any], sep: str = "/") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for path, v in flat.items():
        keys = path.split(sep)
        cur = out
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = v
    return out


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def count_params(params: PyTree) -> int:
    return tree_size(params)


@functools.lru_cache(maxsize=None)
def cpu_device():
    return jax.devices("cpu")[0]
