"""Name-keyed registry of device backends.

Replaces the string-keyed ``if/elif`` hardware paths: every substrate is a
registered factory, and every entry point (continual trainer, model
``quant_mode``, kernels dispatch, benchmarks) resolves it here.

    @register_backend("my_device")
    class MyBackend(DeviceBackend):
        ...

    backend = get_backend("my_device", spec=DeviceSpec(adc_bits=6))
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Union

from repro.backends.base import DeviceBackend, DeviceSpec

_REGISTRY: dict[str, Callable[..., DeviceBackend]] = {}


def register_backend(name: str,
                     factory: Optional[Callable[..., DeviceBackend]] = None):
    """Register a backend factory (usable as a class decorator).

    The factory is called as ``factory(spec=...)`` and must return a
    :class:`DeviceBackend`. Re-registering a name overwrites it (useful for
    tests and experiment sweeps)."""
    def _do(f):
        _REGISTRY[name] = f
        inference_backend.cache_clear()
        return f
    return _do if factory is None else _do(factory)


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: Union[str, DeviceBackend],
                spec: Optional[DeviceSpec] = None,
                spec_overrides: Optional[dict[str, Any]] = None,
                **kwargs) -> DeviceBackend:
    """Instantiate a registered backend by name.

    A fresh instance is returned per call (backends carry per-run state —
    the endurance tracker). ``spec_overrides`` replaces individual fields
    on top of ``spec`` (or, when ``spec`` is None, on top of the backend's
    own default spec) — the rest of the substrate's physics is preserved.
    Passing an existing :class:`DeviceBackend` returns it unchanged, so
    call sites can accept either form."""
    if isinstance(name, DeviceBackend):
        if spec is not None or spec_overrides or kwargs:
            raise ValueError("cannot override the configuration of an "
                             "instantiated backend; construct a new one "
                             "instead")
        return name
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown device backend {name!r}; "
            f"available: {', '.join(available_backends()) or '(none)'}"
        ) from None
    if spec_overrides:
        if spec is None:
            default_spec = getattr(factory, "default_spec", None)
            spec = default_spec() if callable(default_spec) \
                else factory(spec=None, **kwargs).spec
        spec = dataclasses.replace(spec, **spec_overrides)
    return factory(spec=spec, **kwargs)


@functools.lru_cache(maxsize=None)
def inference_backend(name: str) -> DeviceBackend:
    """Shared per-name backend instance for inference-mode model layers
    (``models/layers.dense``, the serve engine).

    Inference overrides on the substrate's own spec: 8-bit quantized
    drive, no readout ADC, unit weight scale (activation normalization
    handles the range); gain noise and crossbar physics stay the
    backend's. Sharing one instance per name keeps a single telemetry
    accumulator across every projection of a serving run — and avoids
    re-instantiating a backend on every layer call."""
    return get_backend(name, spec_overrides=dict(input_bits=8,
                                                 adc_bits=None,
                                                 weight_clip=None))


def unregister_backend(name: str) -> None:
    """Remove a registered backend (test teardown helper)."""
    _REGISTRY.pop(name, None)
    inference_backend.cache_clear()
