"""Digital-CMOS baseline substrate — the paper's 29× comparison anchor.

A 65 nm all-digital MiRU datapath at the same 8-bit fixed-point precision
as the mixed-signal design: sign-magnitude quantized inputs, exact MACs in
digital accumulators (no ADC — there is nothing analog to convert), exact
clipped writes to SRAM weight registers, no device variability and no
endurance limit.

Numerically this is the WBS fixed-point path with ideal gains; what
distinguishes it is its *energy model*: the telemetry energy mapping
charges each metered op the paper-calibrated digital per-op energy
(``M2RUCostModel.digital_pj_per_op`` — MAC + memory traffic at
iso-throughput), which is what reproduces the 29× efficiency gap against
a metered analog run of the same workload (``repro.telemetry.report``).

No fused recurrence: with no readout ADC there is no per-step
re-quantization to absorb sub-LSB fp scheduling, so the WBS-family fused
scan cannot be bit-identical here — ``_fused_recurrence_ok`` keeps this
substrate on the per-step ``device_vmm`` path (see docs/kernels.md).
"""
from __future__ import annotations

from repro.backends.base import DeviceSpec
from repro.backends.registry import register_backend
from repro.backends.wbs import WBSBackend


@register_backend("cmos")
class CMOSBackend(WBSBackend):
    name = "cmos"

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        # 8-bit fixed-point drive, digital accumulation (no readout ADC),
        # same logical dynamic range as the crossbar design so the two
        # substrates train over identical weight ranges.
        return DeviceSpec(input_bits=8, adc_bits=None, adc_range=4.0,
                          gain_sigma=0.0, weight_clip=1.5)
