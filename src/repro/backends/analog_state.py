"""Conductance-domain crossbar substrate — stateful G⁺/G⁻ pairs.

The ``analog`` backend models device noise as perturbations around the
*logical* weight matrix: every forward re-derives effective conductances
from the trainer's weights. This backend instead carries the programmed
conductance pairs themselves (``analog/crossbar.program_pair``) through
the training loop as device state:

  init_device_state  programs every ≥2-D weight onto G⁺/G⁻ pairs with
                     ``crossbar.prog_sigma`` programming variability.
  device_vmm         reads *through the pairs* (per-access read noise on
                     each device, then WBS bit-streaming + plane gains);
                     the logical weights are only the STE gradient path.
  device_apply_update
                     drifts the pairs one retention tick
                     (``crossbar.drift_rate``), lands the noisy write
                     pulses in the conductance domain (one-sided G⁺/G⁻
                     potentiation, window saturation, optional Ziksa
                     level grid), and returns the *read-back* logical
                     weights so the trainer's view tracks the devices.

With all device noise and drift at zero the conductance map is exactly
affine, so the backend short-circuits to the parent's logical-weight
arithmetic — this is the same computation without the float round-trip,
and it makes ``analog_state`` bit-identical to ``analog`` in the ideal
limit (asserted in tests/test_telemetry.py). Biases (1-D params) live in
digital registers and take the parent's write path.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.analog.crossbar import (CrossbarSpec, drift_pair, pair_weights,
                                   program_pair, update_pair)
from repro.backends.analog import AnalogBackend
from repro.backends.base import DeviceSpec, PyTree
from repro.backends.registry import register_backend
from repro.backends.wbs import WBSBackend, _ste_matmul
from repro.faults.model import (apply_cell_faults, fault_state,
                                sample_fault_state)
from repro.telemetry import meters


@register_backend("analog_state")
class AnalogStateBackend(AnalogBackend):
    name = "analog_state"

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        return DeviceSpec(input_bits=8, adc_bits=8, adc_range=4.0,
                          gain_sigma=0.02, weight_clip=1.5,
                          crossbar=CrossbarSpec(write_sigma=0.10,
                                                read_sigma=0.0,
                                                w_clip=1.5,
                                                prog_sigma=0.10))

    # ------------------------------------------------------------------
    def _ideal_device(self) -> bool:
        """Zero noise/drift and no level grid: the conductance map is
        exactly affine, so logical-weight arithmetic is the same
        computation (bit-identical to the ``analog`` backend)."""
        cb = self.crossbar
        return (cb.write_sigma == 0.0 and cb.read_sigma == 0.0
                and cb.prog_sigma == 0.0 and cb.drift_rate == 0.0
                and cb.write_levels is None)

    @staticmethod
    def _is_crossbar_param(name: str, p: jax.Array) -> bool:
        return jnp.ndim(p) >= 2

    # ------------------------------------------------------------------
    @staticmethod
    def _state_het(state) -> Optional[dict]:
        """Per-chip heterogeneity overlay riding the device-state pytree
        (``repro.fleet``): traced scalar overrides for the crossbar's
        noise/drift knobs. Absent (the common case) → the static
        :class:`CrossbarSpec` values apply and every code path is
        bit-identical to the pre-fleet behavior."""
        return state.get("_het") if isinstance(state, dict) else None

    def init_device_state(self, params: PyTree,
                          key: Optional[jax.Array] = None, *,
                          het: Optional[dict] = None) -> Any:
        """Program every ≥2-D weight onto G⁺/G⁻ pairs. ``het`` (fleet
        heterogeneity) is a dict of per-chip scalar overrides — any of
        ``prog_sigma``/``read_sigma``/``write_sigma``/``drift_rate`` —
        that is applied at programming time (``prog_sigma``) and then
        carried in the state under ``"_het"`` for the read/write/drift
        paths. Values may be traced (vmap/shard_map over a fleet axis)."""
        cb = self.crossbar
        names = sorted(n for n, p in params.items()
                       if self._is_crossbar_param(n, p))
        keys = jax.random.split(key, len(names)) if key is not None \
            else [None] * len(names)
        prog_sigma = het.get("prog_sigma") if het else None
        state = {name: program_pair(k, params[name], cb,
                                    prog_sigma=prog_sigma)
                 for k, name in zip(keys, names)}
        if cb.drift_rate > 0 and cb.drift_cadence > 1:
            # Update counter for the drift cadence — threaded through the
            # train loop (and scans) with the pairs.
            state["_ticks"] = jnp.zeros((), jnp.int32)
        if het:
            state["_het"] = {k: jnp.asarray(v, jnp.float32)
                             for k, v in het.items()}
        if self.spec.faults is not None:
            # Fault masks ride next to the pairs (same vehicle as _het);
            # the sampler folds its own salt, so the mask stream is
            # disjoint from the programming keys above.
            fkey = key if key is not None else jax.random.PRNGKey(0)
            state["_faults"] = sample_fault_state(
                params, fkey, self.spec.faults,
                sa1_value=self._fault_value_scale())
        return state

    # ------------------------------------------------------------------
    def _fused_recurrence_ok(self, state) -> bool:
        # The conductance-domain substrate reads *through the carried
        # G⁺/G⁻ pairs* with per-device noise — its forward is defined by
        # the per-step device-state reads, so the logical-weight fused
        # scan never substitutes for it.
        return False

    # ------------------------------------------------------------------
    def _vmm_impl(self, drive, weights, key, state, tag, prepared=None):
        het = self._state_het(state)
        if state is None or tag not in state \
                or (het is None and self._ideal_device()):
            # Ideal limit or stateless call: the parent's logical path is
            # the exact same computation. (A het overlay disables the
            # short-circuit — per-chip sigmas are traced and nonzero.)
            return super()._vmm_impl(drive, weights, key, state, tag,
                                     prepared)
        cb = self.crossbar
        het_read = het.get("read_sigma") if het else None
        pair = state[tag]
        k_gain = key
        if key is not None and (het_read is not None or cb.read_sigma > 0):
            sigma = het_read if het_read is not None else cb.read_sigma
            kp, kn, k_gain = jax.random.split(key, 3)
            pair = {"g_pos": pair["g_pos"]
                    * (1.0 + sigma
                       * jax.random.normal(kp, pair["g_pos"].shape)),
                    "g_neg": pair["g_neg"]
                    * (1.0 + sigma
                       * jax.random.normal(kn, pair["g_neg"].shape))}
        w_eff = pair_weights(pair, cb)
        fstate = fault_state(state)
        if fstate is not None and tag in fstate:
            # Stuck cells override the conductance read-back itself —
            # the pairs may keep drifting underneath, but the column
            # current contribution is pinned at the stuck value.
            w_eff = apply_cell_faults(w_eff, fstate[tag])
        # WBS bit-streaming + plane gains over the device read-back; the
        # outer STE routes gradients to the trainer's logical weights.
        y = WBSBackend.vmm(self, drive, w_eff, k_gain)
        return _ste_matmul(jax.lax.stop_gradient(y), drive, weights)

    # ------------------------------------------------------------------
    def _apply_update_impl(self, params, updates, key, state):
        het = self._state_het(state)
        if state is None or (het is None and self._ideal_device()):
            new_params, applied = self.apply_update(params, updates, key)
            if state is not None:
                # Keep the pairs an exact mirror of the logical weights
                # (the cadence counter, when present, carries through).
                state = {n: (program_pair(None, new_params[n],
                                          self.crossbar)
                             if n in new_params else state[n])
                         for n in state}
            return new_params, applied, state
        cb = self.crossbar
        if key is None:
            raise ValueError("analog_state apply_update needs a PRNG key "
                             "(write variability is stochastic)")
        # Retention-drift cadence: with drift_cadence == 1 every update
        # drifts one tick (the original behavior, bit-identical); with a
        # cadence k > 1 the counter in the device state fires every k-th
        # update and applies k ticks at once — the same total relaxation,
        # amortized. Telemetry meters the cadence-amortized tick per
        # update (exact whenever k divides the update count).
        cadence = max(int(cb.drift_cadence), 1)
        het_write = het.get("write_sigma") if het else None
        het_drift = het.get("drift_rate") if het else None
        # A het drift override is traced, so the drift branch is taken
        # structurally (per-update tick; a zero rate multiplies through).
        drifting = het_drift is not None or cb.drift_rate > 0
        fire = None
        new_state = dict(state)
        if drifting:
            if het_drift is None and cadence > 1:
                ticks = state["_ticks"] + 1
                fire = ticks >= cadence
                new_state["_ticks"] = jnp.where(fire, 0, ticks)
            self.telemetry.record({meters.DRIFT_TICKS: 1},
                                  anchor=next(iter(updates.values())))

        def _drift(pair):
            if not drifting:
                return pair
            if het_drift is not None:
                return drift_pair(pair, cb, drift_rate=het_drift)
            if cadence == 1:
                return drift_pair(pair, cb)
            drifted = drift_pair(pair, cb, n_ticks=cadence)
            return {k: jnp.where(fire, drifted[k], pair[k])
                    for k in pair}

        keys = jax.random.split(key, len(params))
        new_params, applied = {}, {}
        for kw, (name, p) in zip(keys, sorted(params.items())):
            dw = updates[name]
            if name in state:
                pair = _drift(state[name])               # retention tick(s)
                pair = update_pair(kw, pair, dw, cb,
                                   write_sigma=het_write)  # noisy write
                w_read = pair_weights(pair, cb)          # device read-back
                # Unwritten devices: carry the logical value through
                # unchanged when there is no drift (recomputing the
                # read-back invites FMA re-rounding that would smear
                # phantom sub-ulp deltas over the whole array); with
                # drift the relaxation is visible in the read-back but is
                # not a write — ``applied`` stays exactly zero there.
                written = dw != 0
                w_new = w_read if drifting \
                    else jnp.where(written, w_read, p)
                new_state[name] = pair
                new_params[name] = w_new
                applied[name] = jnp.where(written, w_new - p, 0.0)
            else:
                # Digital registers (biases): the parent's logical write.
                sub_p, sub_a = AnalogBackend.apply_update(
                    self, {name: p}, {name: dw}, kw)
                new_params[name] = sub_p[name]
                applied[name] = sub_a[name]
        return new_params, applied, new_state
