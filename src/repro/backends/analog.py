"""Mixed-signal crossbar substrate — the full M2RU accelerator model.

Extends the WBS digital path with `CrossbarSpec`-driven device physics:

  forward  — per-plane memristor-ratio gain variability (``gain_sigma``),
             optional per-access conductance read noise
             (``crossbar.read_sigma``), fused ADC readout.
  write    — §V-B device-to-device write variation on every programmed
             synapse (``crossbar.write_sigma``), optional finite
             programming resolution (``crossbar.write_levels``, the Ziksa
             pulse quantization), clip to the crossbar's dynamic range.
  lifetime — per-device write counting through the endurance tracker;
             only nonzero updates (post K-WTA sparsification upstream)
             cost write pulses.

The default spec mirrors the paper's §V-B calibration as used by the
Fig. 4 hardware runs: 8-bit WBS drive, 8-bit ADC, 2 % plane-gain
variability, 10 % write variability, |w| ≤ 1.5. Read variability is
carried by the plane gains by default (``read_sigma=0``); set
``crossbar.read_sigma`` to add per-access conductance noise on top.

Fault injection (``DeviceSpec.faults``, see ``docs/faults.md``) rides
the shared WBS/base paths: stuck-cell masks apply to the logical
weights *before* the per-access read-noise perturbation (a stuck
device's conductance still jitters cycle to cycle), writes aimed at
stuck cells are rejected before the write-noise draw (no pulse, no
endurance cost), and per-access read noise continues to force the
per-step recurrence path exactly as it does without faults.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.analog.crossbar import CrossbarSpec
from repro.backends.base import DeviceSpec, PyTree
from repro.backends.registry import register_backend
from repro.backends.wbs import WBSBackend


@register_backend("analog")
class AnalogBackend(WBSBackend):
    name = "analog"

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        return DeviceSpec(input_bits=8, adc_bits=8, adc_range=4.0,
                          gain_sigma=0.02, weight_clip=1.5,
                          crossbar=CrossbarSpec(write_sigma=0.10,
                                                read_sigma=0.0,
                                                w_clip=1.5))

    @property
    def crossbar(self) -> CrossbarSpec:
        # Fallback mirrors default_spec: read variability is carried by the
        # plane gains unless a CrossbarSpec explicitly opts into read_sigma.
        return self.spec.crossbar if self.spec.crossbar is not None \
            else CrossbarSpec(read_sigma=0.0, w_clip=self._weight_scale())

    def _weight_scale(self) -> float:
        # One source of truth for the logical dynamic range: an explicit
        # DeviceSpec.weight_clip wins, else the crossbar's own w_clip.
        if self.spec.weight_clip:
            return self.spec.weight_clip
        if self.spec.crossbar is not None:
            return self.spec.crossbar.w_clip
        return 1.0

    # ------------------------------------------------------------------
    def _fused_recurrence_ok(self, state) -> bool:
        # Per-access conductance read noise draws a fresh perturbation of
        # the weight tile on every timestep — that cannot be hoisted into
        # a VMEM-resident tile, so the fused scan only engages without it.
        return super()._fused_recurrence_ok(state) \
            and self.crossbar.read_sigma == 0

    # ------------------------------------------------------------------
    def vmm(self, drive: jax.Array, weights: jax.Array,
            key: Optional[jax.Array] = None,
            prepared: Optional[dict] = None) -> jax.Array:
        cb = self.crossbar
        if key is not None and cb.read_sigma > 0:
            # Cycle-to-cycle conductance variation: each access sees a
            # perturbed effective weight (crossbar.vmm's read model, in
            # logical-weight units). The WBS layer draws it in-kernel on
            # the Pallas path, or on the weight matrix on the jnp path.
            k_read, k_gain = jax.random.split(key)
            return super().vmm(drive, weights, k_gain,
                               read_sigma=cb.read_sigma, read_key=k_read,
                               prepared=prepared)
        return super().vmm(drive, weights, key, prepared=prepared)

    # ------------------------------------------------------------------
    def apply_update(self, params: PyTree, updates: PyTree,
                     key: Optional[jax.Array] = None
                     ) -> tuple[PyTree, PyTree]:
        """In-situ training write. Only nonzero update entries receive
        write pulses (the K-WTA sparsifier upstream decides which); each
        pulse lands with multiplicative write noise, optionally snaps to
        the finite programming grid, and the result is clipped to the
        crossbar's dynamic range."""
        cb = self.crossbar
        clip = self._weight_scale()
        if key is None:
            raise ValueError("analog apply_update needs a PRNG key "
                             "(write variability is stochastic)")
        keys = jax.random.split(key, len(params))
        new_params, applied = {}, {}
        for kw, (name, p) in zip(keys, sorted(params.items())):
            dw = updates[name]
            noise = 1.0 + cb.write_sigma * jax.random.normal(kw, dw.shape)
            dw = jnp.where(dw != 0, dw * noise, 0.0)
            w = p + dw
            if cb.write_levels is not None:
                # Finite programming resolution: written devices snap to
                # the conductance grid (write_levels points across the
                # logical range [-clip, clip]); untouched devices keep
                # their analog value.
                step = 2.0 * clip / (cb.write_levels - 1)
                w = jnp.where(dw != 0, jnp.round(w / step) * step, w)
            w = jnp.clip(w, -clip, clip)
            new_params[name] = w
            applied[name] = w - p
        return new_params, applied
