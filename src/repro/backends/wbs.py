"""WBS digital substrate — quantized inputs + ADC, no device noise.

Models the digital portion of the M2RU datapath: drives are sign-magnitude
quantized to ``input_bits`` and bit-streamed (eqs. 11-19), the readout is
ADC-quantized, weights live in a finite logical dynamic range — but there
is no memristor variability (ideal plane gains, exact writes). This
isolates pure quantization error from device physics (Fig. 5a's axis).

Dispatch: the fused Pallas kernel (``kernels/ops.wbs_dense``) on
accelerators; the vectorized jnp reference (``analog/wbs.wbs_vmm``) on CPU,
where interpret-mode Pallas would be orders of magnitude slower. Both share
the same fixed-point semantics (swept against each other in
tests/test_kernels.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.analog.wbs import WBSSpec, ideal_gains, wbs_vmm
from repro.backends.base import DeviceBackend, DeviceSpec, PyTree
from repro.backends.registry import register_backend
from repro.faults.model import apply_cell_faults, fault_state


# ---------------------------------------------------------------------------
# Straight-through estimators. The sign-magnitude/ADC rounding inside the
# quantized paths has zero gradient a.e., which would zero every hidden-weight
# gradient under BPTT. These wrappers return the quantized value exactly on
# the forward pass (no extra compute for inference-only callers) while the
# backward pass sees the underlying linear op.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_matmul(y_quant: jax.Array, drive: jax.Array,
                weights: jax.Array) -> jax.Array:
    return y_quant


def _ste_matmul_fwd(y_quant, drive, weights):
    return y_quant, (drive, weights)


def _ste_matmul_bwd(res, g):
    drive, weights = res
    d2 = drive.reshape(-1, drive.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    return (jnp.zeros_like(g), g @ weights.T,
            (d2.T @ g2).astype(weights.dtype))


_ste_matmul.defvjp(_ste_matmul_fwd, _ste_matmul_bwd)


@jax.custom_vjp
def _ste_identity(y_quant: jax.Array, x: jax.Array) -> jax.Array:
    return y_quant


def _ste_identity_fwd(y_quant, x):
    return y_quant, None


def _ste_identity_bwd(_res, g):
    return jnp.zeros_like(g), g


_ste_identity.defvjp(_ste_identity_fwd, _ste_identity_bwd)


@register_backend("wbs")
class WBSBackend(DeviceBackend):
    name = "wbs"

    def __init__(self, spec: Optional[DeviceSpec] = None,
                 use_kernel: Optional[bool] = None,
                 fused_recurrence: bool = True):
        super().__init__(spec)
        # None = auto: Pallas kernel when compiled (non-CPU), jnp reference
        # in interpret-mode environments.
        self.use_kernel = use_kernel
        # Route miru recurrences through the one-kernel fused scan
        # (kernels/wbs_miru_scan) instead of the per-timestep device_vmm
        # loop. Bit-identical at read_sigma == 0 (asserted in tests);
        # False forces the per-step path.
        self.fused_recurrence = fused_recurrence

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        return DeviceSpec(input_bits=8, adc_bits=8, adc_range=4.0,
                          weight_clip=1.5)

    # ------------------------------------------------------------------
    def _weight_scale(self) -> float:
        return self.spec.weight_clip if self.spec.weight_clip else 1.0

    def _fault_value_scale(self) -> float:
        # SA1 cells saturate at the logical dynamic range (the analog
        # family derives it from the crossbar spec via _weight_scale).
        return self._weight_scale()

    def _sample_gains(self, key: Optional[jax.Array]) -> jax.Array:
        n_bits = self.spec.input_bits or 8
        gains = ideal_gains(n_bits)
        if key is not None and self.spec.gain_sigma > 0:
            gains = gains * (1.0 + self.spec.gain_sigma
                             * jax.random.normal(key, gains.shape))
        return gains

    def prepare_weights(self, params: PyTree, *, state=None
                        ) -> Optional[dict]:
        """Hoist the once-per-forward weight derivations out of the
        per-timestep scan: the logical-scale division for every ≥2-D
        weight, plus (on the Pallas path) the block-multiple padding the
        kernel wrapper otherwise re-applies per call. Entries are keyed
        by parameter name ≡ crossbar tag; each is bit-identical to the
        per-call derivation (same ops, same operands), so consuming them
        cannot change results. Fault masks (``state["_faults"]``) apply
        *before* the scale division — the same masked tensor
        ``device_vmm`` derives per call, so prepared-vs-unprepared stays
        bit-identical under faults too."""
        fstate = fault_state(state)
        scale = self._weight_scale()
        use_kernel = self.use_kernel if self.use_kernel is not None \
            else jax.default_backend() != "cpu"
        prepared = {}
        for name, p in params.items():
            if jnp.ndim(p) < 2:
                continue
            if fstate is not None and name in fstate:
                p = apply_cell_faults(p, fstate[name])
            w = p / scale
            entry = {"w": w}
            if use_kernel:
                from repro.kernels import ops as kops
                entry["padded"] = kops.pad_wbs_weights(
                    w.astype(jnp.float32))
            prepared[name] = entry
        return prepared or None

    def _vmm_impl(self, drive, weights, key, state, tag, prepared=None):
        entry = prepared.get(tag) if prepared else None
        return self.vmm(drive, weights, key, prepared=entry)

    def vmm(self, drive: jax.Array, weights: jax.Array,
            key: Optional[jax.Array] = None,
            read_sigma: float = 0.0,
            read_key: Optional[jax.Array] = None,
            prepared: Optional[dict] = None) -> jax.Array:
        """WBS crossbar product. ``read_sigma``/``read_key`` carry
        per-access conductance read noise (the analog backend's
        ``crossbar.read_sigma``): on the Pallas path the noise is drawn
        *inside* the kernel from the on-chip PRNG; the jnp reference path
        perturbs the weight matrix up front — same statistics, one draw
        per call instead of per access. ``prepared`` is this tile's
        :meth:`prepare_weights` entry (hoisted scale division/padding);
        it is ignored wherever the weights are perturbed per call."""
        n_bits = self.spec.input_bits or 8
        scale = self._weight_scale()
        use_kernel = self.use_kernel if self.use_kernel is not None \
            else jax.default_backend() != "cpu"
        if not use_kernel and read_sigma > 0 and read_key is not None:
            weights = weights * (1.0 + read_sigma
                                 * jax.random.normal(read_key,
                                                     weights.shape))
            prepared = None   # per-call perturbation, nothing to reuse
        w = prepared["w"] if prepared is not None else weights / scale
        if use_kernel:
            from repro.kernels import ops as kops
            y = kops.wbs_dense(drive, w.astype(jnp.float32), n_bits=n_bits,
                               adc_bits=None, gains=self._sample_gains(key),
                               read_sigma=read_sigma, read_key=read_key,
                               w_prepared=(prepared or {}).get("padded"))
        else:
            wspec = WBSSpec(n_bits=n_bits, gain_sigma=self.spec.gain_sigma,
                            adc_bits=None)
            y = wbs_vmm(drive, w, wspec,
                        key=key if self.spec.gain_sigma > 0 else None)
        return _ste_matmul(jax.lax.stop_gradient(y * scale), drive, weights)

    # ------------------------------------------------------------------
    # Fused one-kernel recurrence (kernels/wbs_miru_scan)
    # ------------------------------------------------------------------
    def _fused_recurrence_ok(self, state) -> bool:
        """The fused scan reads the logical weight matrices directly, so
        it is only valid for stateless substrates with a WBS drive — and
        only with the fused output ADC on. The ADC re-quantizes the
        integrator every step, which is what makes the fused kernel
        bit-identical to the per-step scan; without it (the cmos digital
        accumulator), sub-LSB fp scheduling differences between the two
        program shapes survive, so those substrates keep the per-step
        path.

        A device state that carries *only* fault masks does not block
        fusion — static stuck-cell masks apply to the logical weights
        before they enter either path, so the two stay bit-identical
        under faults. Transient read upsets do block it (they draw a
        fresh per-step corruption inside the scan)."""
        masks_only = state is None or (isinstance(state, dict)
                                       and not (set(state) - {"_faults"}))
        upsets = (self.spec.faults is not None
                  and self.spec.faults.upset_rate > 0
                  and fault_state(state) is not None)
        return (masks_only and not upsets
                and self.spec.input_bits is not None
                and self.spec.adc_bits is not None)

    def device_recurrence(self, params, cfg, x_seq, key, *,
                          state=None, fused=None, h0=None):
        """Fused WBS×MiRU recurrence: ONE batched crossbar call for the
        input projection (no sequential dependency) + one kernel for the
        sequential part with ``u_h`` and ``h`` VMEM-resident across all
        timesteps. Per-step plane-gain draws reproduce the per-step
        path's exact PRNG chain, so the result is bit-identical to the
        default per-timestep scan (including under ``gain_sigma > 0``);
        the per-step path remains available via ``fused=False`` /
        ``fused_recurrence=False`` and is the automatic fallback when
        per-access read noise or device state make fusion invalid."""
        use_fused = self.fused_recurrence if fused is None else fused
        if not (use_fused and self._fused_recurrence_ok(state)):
            return super().device_recurrence(params, cfg, x_seq, key,
                                             state=state, fused=fused,
                                             h0=h0)
        from repro.kernels import ops as kops
        fstate = fault_state(state)
        if fstate is not None:
            # Read the logical weights through their stuck-cell masks up
            # front — the identical masked tensors the per-step path
            # derives in prepare_weights/device_vmm, so fused-vs-per-step
            # stays bitwise identical under faults.
            params = {n: (apply_cell_faults(p, fstate[n])
                          if n in fstate else p)
                      for n, p in params.items()}
        B, T, _ = x_seq.shape
        n_bits = self.spec.input_bits or 8
        scale = self._weight_scale()
        gains_w = gains_u = None
        if self.spec.gain_sigma > 0:
            # The per-step scan splits (k, k1, k2) per timestep and draws
            # one gain vector per tile from (k1, k2); replay the exact
            # chain up front so the fused path consumes identical draws.
            def chain(k, _):
                k, k1, k2 = jax.random.split(k, 3)
                return k, (k1, k2)

            _, (k1s, k2s) = jax.lax.scan(chain, key, None, length=T)
            sample = jax.vmap(self._sample_gains)
            gains_w, gains_u = sample(k1s), sample(k2s)
        drive = kops.wbs_input_drive(x_seq, params["w_h"], n_bits,
                                     weight_scale=scale, gains=gains_w,
                                     use_kernel=self.use_kernel)
        drive = _ste_matmul(jax.lax.stop_gradient(drive), x_seq,
                            params["w_h"])
        h_all, h_prev, pre = kops.wbs_miru_scan(
            drive, params["u_h"], params["b_h"], h0, beta=cfg.beta,
            lam=cfg.lam, n_bits=n_bits, adc_bits=self.spec.adc_bits,
            adc_range=self.spec.adc_range, weight_scale=scale,
            gains=gains_u, use_kernel=self.use_kernel)
        # Metering: same counter keys and totals as the per-step path —
        # the hoisted drive is one (B·T)-row access of w_h; the scan is
        # T per-step accesses of u_h plus T ADC readouts.
        tele = self.telemetry
        tele.meter_vmm(x_seq, params["w_h"], n_bits, "w_h")
        with tele.scaled(T):
            tele.meter_vmm(h_all[:, 0, :], params["u_h"], n_bits, "u_h")
            if self.spec.adc_bits is not None:
                tele.meter_adc(pre[:, 0, :], "hidden")
        return h_all, h_prev, pre

    def quantize_readout(self, pre: jax.Array) -> jax.Array:
        if self.spec.adc_bits is None:
            return pre
        from repro.analog.adc import adc_quantize
        q = adc_quantize(pre, self.spec.adc_bits, self.spec.adc_range)
        return _ste_identity(jax.lax.stop_gradient(q), pre)

    # ------------------------------------------------------------------
    def apply_update(self, params: PyTree, updates: PyTree,
                     key: Optional[jax.Array] = None
                     ) -> tuple[PyTree, PyTree]:
        """Exact digital write, clipped to the logical dynamic range."""
        clip = self.spec.weight_clip
        new_params, applied = {}, {}
        for name, p in sorted(params.items()):
            w = p + updates[name]
            if clip is not None:
                w = jnp.clip(w, -clip, clip)
            new_params[name] = w
            applied[name] = w - p
        return new_params, applied
