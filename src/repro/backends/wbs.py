"""WBS digital substrate — quantized inputs + ADC, no device noise.

Models the digital portion of the M2RU datapath: drives are sign-magnitude
quantized to ``input_bits`` and bit-streamed (eqs. 11-19), the readout is
ADC-quantized, weights live in a finite logical dynamic range — but there
is no memristor variability (ideal plane gains, exact writes). This
isolates pure quantization error from device physics (Fig. 5a's axis).

Dispatch: the fused Pallas kernel (``kernels/ops.wbs_dense``) on
accelerators; the vectorized jnp reference (``analog/wbs.wbs_vmm``) on CPU,
where interpret-mode Pallas would be orders of magnitude slower. Both share
the same fixed-point semantics (swept against each other in
tests/test_kernels.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.analog.wbs import WBSSpec, ideal_gains, wbs_vmm
from repro.backends.base import DeviceBackend, DeviceSpec, PyTree
from repro.backends.registry import register_backend


# ---------------------------------------------------------------------------
# Straight-through estimators. The sign-magnitude/ADC rounding inside the
# quantized paths has zero gradient a.e., which would zero every hidden-weight
# gradient under BPTT. These wrappers return the quantized value exactly on
# the forward pass (no extra compute for inference-only callers) while the
# backward pass sees the underlying linear op.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_matmul(y_quant: jax.Array, drive: jax.Array,
                weights: jax.Array) -> jax.Array:
    return y_quant


def _ste_matmul_fwd(y_quant, drive, weights):
    return y_quant, (drive, weights)


def _ste_matmul_bwd(res, g):
    drive, weights = res
    d2 = drive.reshape(-1, drive.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    return (jnp.zeros_like(g), g @ weights.T,
            (d2.T @ g2).astype(weights.dtype))


_ste_matmul.defvjp(_ste_matmul_fwd, _ste_matmul_bwd)


@jax.custom_vjp
def _ste_identity(y_quant: jax.Array, x: jax.Array) -> jax.Array:
    return y_quant


def _ste_identity_fwd(y_quant, x):
    return y_quant, None


def _ste_identity_bwd(_res, g):
    return jnp.zeros_like(g), g


_ste_identity.defvjp(_ste_identity_fwd, _ste_identity_bwd)


@register_backend("wbs")
class WBSBackend(DeviceBackend):
    name = "wbs"

    def __init__(self, spec: Optional[DeviceSpec] = None,
                 use_kernel: Optional[bool] = None):
        super().__init__(spec)
        # None = auto: Pallas kernel when compiled (non-CPU), jnp reference
        # in interpret-mode environments.
        self.use_kernel = use_kernel

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        return DeviceSpec(input_bits=8, adc_bits=8, adc_range=4.0,
                          weight_clip=1.5)

    # ------------------------------------------------------------------
    def _weight_scale(self) -> float:
        return self.spec.weight_clip if self.spec.weight_clip else 1.0

    def _sample_gains(self, key: Optional[jax.Array]) -> jax.Array:
        n_bits = self.spec.input_bits or 8
        gains = ideal_gains(n_bits)
        if key is not None and self.spec.gain_sigma > 0:
            gains = gains * (1.0 + self.spec.gain_sigma
                             * jax.random.normal(key, gains.shape))
        return gains

    def vmm(self, drive: jax.Array, weights: jax.Array,
            key: Optional[jax.Array] = None,
            read_sigma: float = 0.0,
            read_key: Optional[jax.Array] = None) -> jax.Array:
        """WBS crossbar product. ``read_sigma``/``read_key`` carry
        per-access conductance read noise (the analog backend's
        ``crossbar.read_sigma``): on the Pallas path the noise is drawn
        *inside* the kernel from the on-chip PRNG; the jnp reference path
        perturbs the weight matrix up front — same statistics, one draw
        per call instead of per access."""
        n_bits = self.spec.input_bits or 8
        scale = self._weight_scale()
        use_kernel = self.use_kernel if self.use_kernel is not None \
            else jax.default_backend() != "cpu"
        if not use_kernel and read_sigma > 0 and read_key is not None:
            weights = weights * (1.0 + read_sigma
                                 * jax.random.normal(read_key,
                                                     weights.shape))
        w = weights / scale
        if use_kernel:
            from repro.kernels import ops as kops
            y = kops.wbs_dense(drive, w.astype(jnp.float32), n_bits=n_bits,
                               adc_bits=None, gains=self._sample_gains(key),
                               read_sigma=read_sigma, read_key=read_key)
        else:
            wspec = WBSSpec(n_bits=n_bits, gain_sigma=self.spec.gain_sigma,
                            adc_bits=None)
            y = wbs_vmm(drive, w, wspec,
                        key=key if self.spec.gain_sigma > 0 else None)
        return _ste_matmul(jax.lax.stop_gradient(y * scale), drive, weights)

    def quantize_readout(self, pre: jax.Array) -> jax.Array:
        if self.spec.adc_bits is None:
            return pre
        from repro.analog.adc import adc_quantize
        q = adc_quantize(pre, self.spec.adc_bits, self.spec.adc_range)
        return _ste_identity(jax.lax.stop_gradient(q), pre)

    # ------------------------------------------------------------------
    def apply_update(self, params: PyTree, updates: PyTree,
                     key: Optional[jax.Array] = None
                     ) -> tuple[PyTree, PyTree]:
        """Exact digital write, clipped to the logical dynamic range."""
        clip = self.spec.weight_clip
        new_params, applied = {}, {}
        for name, p in sorted(params.items()):
            w = p + updates[name]
            if clip is not None:
                w = jnp.clip(w, -clip, clip)
            new_params[name] = w
            applied[name] = w - p
        return new_params, applied
