"""Device-backend protocol — the seam between *algorithm* and *substrate*.

The paper's core claim is that one recurrence (MiRU + DFA-through-time)
runs on very different substrates: an ideal software model, a WBS-quantized
digital path, and the full mixed-signal crossbar with write variability and
endurance limits. A :class:`DeviceBackend` captures everything a substrate
contributes to training and inference:

  vmm(drive, weights, key)        forward matrix–vector product — where
                                  input quantization, bit-streaming, gain
                                  variability and read noise live.
  quantize_readout(pre)           the fused output ADC, applied after the
                                  bias add (identity for digital paths).
  apply_update(params, dw, key)   the weight write — write noise, finite
                                  programming levels, dynamic-range clip.
  record_endurance(applied)       host-side per-device write counting.
  spec                            the :class:`DeviceSpec` describing the
                                  substrate's knobs.

Training algorithms (BPTT+Adam, DFA+SGD, …) never branch on a device name;
they call these hooks.  New substrates register themselves with
:func:`repro.backends.register_backend` — see ``docs/backends.md``.

Two orthogonal layers sit on top of the raw hooks (both optional for
substrate authors — the base class provides them):

  telemetry     every backend carries a ``repro.telemetry.Telemetry``
                accumulator (disabled by default). The ``device_*``
                wrappers meter ADC conversions, bit pulses, crossbar
                reads and MACs; ``record_endurance`` meters write pulses
                from the concrete applied updates.
  device state  substrates whose physical state is *not* the logical
                weight matrix (the conductance-domain ``analog_state``
                backend) thread an opaque pytree through the train loop:
                ``init_device_state`` creates it, ``device_vmm`` reads
                through it, ``device_apply_update`` advances it.
                Stateless substrates return/ignore ``None``.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analog.crossbar import CrossbarSpec
from repro.analog.endurance import EnduranceTracker
from repro.faults.model import (FaultSpec, advance_wear, apply_cell_faults,
                                apply_read_upsets, fault_state,
                                mask_updates, sample_fault_state)
from repro.telemetry.meters import Telemetry

PyTree = dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Substrate description consumed by a :class:`DeviceBackend`.

    Forward-path knobs:
      input_bits    sign-magnitude drive precision (None = full precision).
      adc_bits      fused readout ADC precision (None = no quantization).
      adc_range     symmetric ADC full scale, logical units.
      gain_sigma    WBS per-plane memristor-ratio variability (§V-A).

    Write-path knobs:
      weight_clip   logical dynamic range of a stored weight (None = ∞).
      crossbar      device physics for the write path — write_sigma,
                    write_levels — used by the analog backend.

    Bookkeeping:
      track_endurance  attach an :class:`EnduranceTracker` to the backend.

    Fault injection:
      faults        a :class:`repro.faults.FaultSpec` — stuck cells, dead
                    lines, read upsets, endurance wear-out. None (the
                    default) keeps every traced program bitwise identical
                    to a fault-free build; see ``docs/faults.md``.
    """
    input_bits: Optional[int] = None
    adc_bits: Optional[int] = None
    adc_range: float = 4.0
    gain_sigma: float = 0.0
    weight_clip: Optional[float] = None
    crossbar: Optional[CrossbarSpec] = None
    track_endurance: bool = False
    faults: Optional[FaultSpec] = None


class DeviceBackend(abc.ABC):
    """Abstract substrate. Subclasses implement ``vmm`` and ``apply_update``;
    both must be jit-traceable (stochasticity explicit via PRNG keys)."""

    name: str = "abstract"

    def __init__(self, spec: Optional[DeviceSpec] = None):
        self.spec = spec if spec is not None else self.default_spec()
        self.tracker: Optional[EnduranceTracker] = \
            EnduranceTracker() if self.spec.track_endurance else None
        self.telemetry = Telemetry(enabled=False)

    @classmethod
    def default_spec(cls) -> DeviceSpec:
        return DeviceSpec()

    # ------------------------------------------------------------------
    # Forward path
    # ------------------------------------------------------------------
    @abc.abstractmethod
    def vmm(self, drive: jax.Array, weights: jax.Array,
            key: Optional[jax.Array] = None) -> jax.Array:
        """y = drive @ weights on this substrate. drive (..., n_in),
        weights (n_in, n_out). ``key`` feeds per-access noise; backends
        must be deterministic when it is None."""

    def quantize_readout(self, pre: jax.Array) -> jax.Array:
        """Fused output ADC, applied to the integrator output after the
        bias add. Identity by default (digital/ideal paths)."""
        return pre

    # ------------------------------------------------------------------
    # Write path
    # ------------------------------------------------------------------
    @abc.abstractmethod
    def apply_update(self, params: PyTree, updates: PyTree,
                     key: Optional[jax.Array] = None
                     ) -> tuple[PyTree, PyTree]:
        """Write ``updates`` (already lr-scaled and sparsified by the
        trainer) into ``params``. Returns (new_params, applied) where
        ``applied`` records the deltas that actually landed on devices
        (post noise/levels/clip) for endurance accounting."""

    def record_endurance(self, applied: PyTree) -> None:
        """Host-side write counting (endurance tracker + telemetry write
        pulses); no-op unless either was asked for."""
        if self.tracker is None and not self.telemetry.enabled:
            return
        masks = {k: np.asarray(v != 0) for k, v in applied.items()
                 if np.ndim(v) >= 2}
        self.telemetry.meter_writes(masks)
        if self.tracker is not None:
            self.tracker.record_update(masks)

    # ------------------------------------------------------------------
    # Device state (opaque pytree threaded through the train loop)
    # ------------------------------------------------------------------
    def init_device_state(self, params: PyTree,
                          key: Optional[jax.Array] = None
                          ) -> Optional[Any]:
        """Build the substrate's physical state for ``params`` (e.g.
        programmed conductance pairs). Stateless substrates return None —
        unless the spec carries a :class:`FaultSpec`, in which case the
        sampled fault masks ride the state under ``"_faults"``."""
        if self.spec.faults is None:
            return None
        fkey = key if key is not None else jax.random.PRNGKey(0)
        return {"_faults": sample_fault_state(
            params, fkey, self.spec.faults,
            sa1_value=self._fault_value_scale())}

    def _fault_value_scale(self) -> float:
        """Logical magnitude a stuck-at-G_on (SA1) cell reads as."""
        return self.spec.weight_clip or 1.0

    # ------------------------------------------------------------------
    # Metered entry points (what the trainers/forwards call)
    # ------------------------------------------------------------------
    def prepare_weights(self, params: PyTree, *,
                        state: Optional[Any] = None
                        ) -> Optional[dict[str, Any]]:
        """Per-forward weight preparation, keyed by crossbar tag.

        Substrates whose ``vmm`` derives a transformed view of the weight
        matrix on every call (the WBS family divides by the logical scale;
        the Pallas path additionally pads to tile multiples) override this
        to hoist that work out of the per-timestep scan: the default
        per-step :meth:`device_recurrence` calls it once before the scan
        and threads the result into each ``device_vmm`` via ``prepared``.
        Entries are keyed by tile tag (``w_h``/``u_h``/``w_o``); a tag
        with no entry (or ``None`` overall — the default) falls back to
        the per-call derivation, bit-identically."""
        del params, state
        return None

    def device_vmm(self, drive: jax.Array, weights: jax.Array,
                   key: Optional[jax.Array] = None, *,
                   state: Optional[Any] = None,
                   tag: str = "",
                   prepared: Optional[dict[str, Any]] = None) -> jax.Array:
        """``vmm`` + activity metering + optional device-state read.
        ``tag`` names the crossbar tile (``w_h``/``u_h``/``w_o``) so the
        energy model can apply the chip's concurrency structure.
        ``prepared`` is a :meth:`prepare_weights` result hoisted by the
        caller (same forward, same params) — substrates consume their own
        entries and must stay bit-identical without them.

        When the device state carries fault masks (``"_faults"``), the
        logical weights are read through their stuck-cell mask here —
        one masked tensor feeds both the compute and the STE gradient
        path, so gradients at stuck cells vanish automatically. Masking
        is a projection (idempotent), so substrates that also mask in
        :meth:`prepare_weights` stay bit-identical."""
        fstate = fault_state(state)
        if fstate is not None and tag in fstate:
            weights = apply_cell_faults(weights, fstate[tag])
        y = self._vmm_impl(drive, weights, key, state, tag, prepared)
        self.telemetry.meter_vmm(drive, weights, self.spec.input_bits, tag)
        return y

    def _vmm_impl(self, drive, weights, key, state, tag,
                  prepared=None) -> jax.Array:
        return self.vmm(drive, weights, key)

    def device_readout(self, pre: jax.Array,
                       tag: str = "hidden") -> jax.Array:
        """``quantize_readout`` + ADC-conversion metering."""
        q = self.quantize_readout(pre)
        if self.spec.adc_bits is not None:
            self.telemetry.meter_adc(pre, tag)
        return q

    def device_recurrence(self, params: PyTree, cfg, x_seq: jax.Array,
                          key: jax.Array, *, state: Optional[Any] = None,
                          fused: Optional[bool] = None,
                          h0: Optional[jax.Array] = None
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Run the full MiRU hidden recurrence (eqs. 1-2) on this
        substrate over ``x_seq`` (B, T, n_x). ``cfg`` is a
        :class:`repro.core.miru.MiRUConfig`-shaped record (beta, lam,
        n_h, dtype). Returns (h_all, h_prev, pre), each (B, T, n_h).
        ``h0`` (B, n_h) resumes the recurrence from a carried hidden
        state (the serve engine's state slab); None starts from zeros —
        the training forward's convention.

        The default is the per-timestep scan: two ``device_vmm`` calls
        and one ``device_readout`` per step, PRNG key split 3-way per
        step. Substrates with a fused one-kernel path (WBS/analog)
        override this hook; ``fused`` lets the trainer force the
        per-step path (False) or defer to the backend (None/True —
        ignored here, the default *is* the per-step path). All metering
        happens through the ``device_*`` hooks inside a ``scaled(T)``
        scope, so counters are identical across implementations.
        """
        del fused
        B, T, _ = x_seq.shape
        # Hoist the once-per-forward weight preparation (scale division,
        # kernel padding) out of the scan body — the per-step path
        # otherwise re-derives it T times per forward.
        prepared = self.prepare_weights(params, state=state)
        # Transient read upsets (per-access ADC corruption) need one
        # extra key per step. The split widens to 4-way only when upsets
        # are actually active, so zero-fault programs keep the exact
        # 3-way chain — the bitwise zero-fault contract.
        upset_rate = self.spec.faults.upset_rate \
            if (self.spec.faults is not None
                and fault_state(state) is not None) else 0.0

        def step(carry, x_t):
            h, k = carry
            if upset_rate > 0:
                k, k1, k2, k3 = jax.random.split(k, 4)
            else:
                k, k1, k2 = jax.random.split(k, 3)
            pre = self.device_vmm(x_t, params["w_h"], k1,
                                  state=state, tag="w_h",
                                  prepared=prepared) \
                + self.device_vmm(cfg.beta * h, params["u_h"], k2,
                                  state=state, tag="u_h",
                                  prepared=prepared) \
                + params["b_h"]
            pre = self.device_readout(pre)
            if upset_rate > 0:
                pre = apply_read_upsets(pre, k3, upset_rate,
                                        self.spec.adc_range)
            h_tilde = jnp.tanh(pre)
            h_new = cfg.lam * h + (1.0 - cfg.lam) * h_tilde
            return (h_new, k), (h_new, h, pre)

        if h0 is None:
            h0 = jnp.zeros((B, cfg.n_h), cfg.dtype)
        with self.telemetry.scaled(T):
            (_, _), (h_all, h_prev, pre) = jax.lax.scan(
                step, (h0, key), jnp.swapaxes(x_seq, 0, 1))
        return (jnp.swapaxes(h_all, 0, 1), jnp.swapaxes(h_prev, 0, 1),
                jnp.swapaxes(pre, 0, 1))

    def device_apply_update(self, params: PyTree, updates: PyTree,
                            key: Optional[jax.Array] = None,
                            state: Optional[Any] = None
                            ) -> tuple[PyTree, PyTree, Optional[Any]]:
        """``apply_update`` that also advances the device state. Write
        pulses are metered later, host-side, in :meth:`record_endurance`
        (only nonzero applied updates cost pulses — a data-dependent
        count that cannot be derived at trace time).

        Under fault masks, write pulses aimed at stuck cells are zeroed
        before they reach the substrate (a stuck device rejects
        programming — it must not cost pulses or endurance either), and
        with wear-out enabled the per-cell write counters advance on the
        applied updates, converting exhausted cells into stuck cells for
        every subsequent read."""
        fspec = self.spec.faults
        fstate = fault_state(state)
        if fstate is not None:
            updates = mask_updates(updates, fstate)
        new_params, applied, state = self._apply_update_impl(
            params, updates, key, state)
        if fstate is not None and fspec is not None and fspec.wearout:
            state = dict(state)
            state["_faults"] = advance_wear(
                fstate, applied, fspec, new_params,
                sa1_value=self._fault_value_scale())
        return new_params, applied, state

    def _apply_update_impl(self, params, updates, key, state):
        new_params, applied = self.apply_update(params, updates, key)
        return new_params, applied, state

    # ------------------------------------------------------------------
    def __repr__(self) -> str:
        return f"<{type(self).__name__} name={self.name!r} spec={self.spec}>"
