"""Ideal software substrate — full-precision matmuls, exact writes.

This is the paper's software baseline: ``vmm`` is a plain matrix product
and ``apply_update`` is the exact ``params + updates`` used by the Adam and
DFA software trainers. Guaranteed bit-identical to the pre-backend
``miru_forward``/``apply_updates`` paths (asserted in tests/test_backends).

Recurrences use the base per-timestep scan (``device_recurrence``
default): the quantized fused WBS×MiRU kernel does not apply to a
full-precision substrate, and XLA already fuses the plain-matmul scan
body well (the ideal *float* fused path lives in ``kernels/miru_scan``
behind ``miru_forward(use_fused=True)``).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.backends.base import DeviceBackend, PyTree
from repro.backends.registry import register_backend
from repro.optim import apply_updates


@register_backend("ideal")
class IdealBackend(DeviceBackend):
    name = "ideal"

    def vmm(self, drive: jax.Array, weights: jax.Array,
            key: Optional[jax.Array] = None) -> jax.Array:
        return drive @ weights

    def apply_update(self, params: PyTree, updates: PyTree,
                     key: Optional[jax.Array] = None
                     ) -> tuple[PyTree, PyTree]:
        return apply_updates(params, updates), updates
