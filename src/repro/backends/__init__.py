"""Pluggable device backends — one algorithm, many substrates.

- base:     the DeviceBackend protocol (vmm / quantize_readout /
            apply_update / endurance hooks) and the DeviceSpec record.
- registry: name-keyed factory registry (register_backend / get_backend).
- ideal:    full-precision software substrate (the paper's baseline).
- wbs:      WBS-quantized digital path — input quantization + ADC, no
            device noise (isolates fixed-point error).
- analog:   the mixed-signal M2RU crossbar — WBS + gain/read variability,
            noisy finite-level writes, endurance accounting.
- analog_state: conductance-domain crossbar — carries programmed G⁺/G⁻
            pairs between steps (programming noise, drift, saturation)
            instead of re-deriving conductances from logical weights.
- cmos:     digital 65 nm baseline — exact fixed-point datapath whose
            metered energy anchors the paper's 29× comparison.

Every hardware-aware entry point (the continual trainer, model
``quant_mode``, kernels dispatch, the serve engine, benchmarks) resolves
substrates through this registry; adding device physics means registering
a backend, not adding an ``elif``. See docs/backends.md.
"""
from repro.backends.base import DeviceBackend, DeviceSpec
from repro.backends.registry import (available_backends, get_backend,
                                     inference_backend, register_backend,
                                     unregister_backend)
from repro.backends.ideal import IdealBackend
from repro.backends.wbs import WBSBackend
from repro.backends.analog import AnalogBackend
from repro.backends.analog_state import AnalogStateBackend
from repro.backends.cmos import CMOSBackend

__all__ = [
    "DeviceBackend", "DeviceSpec",
    "available_backends", "get_backend", "inference_backend",
    "register_backend", "unregister_backend",
    "IdealBackend", "WBSBackend", "AnalogBackend", "AnalogStateBackend",
    "CMOSBackend",
]
