"""Metered lifetime projection — §VI-B / Fig. 5b from live write maps.

``EnduranceTracker`` records which devices were actually written each
update during a run; this module folds that map into the paper's lifetime
figures. The bridge between *selected devices* and *endurance cycles* is
the Ziksa programming pulse train: reprogramming one selected synapse costs
``HardwareConstants.ziksa_pulse_rate`` endurance cycles in expectation
(calibrated from the paper's own dense-run statistics: a 6.9-year lifetime
at 10⁹ endurance and a 1 ms update cadence with every device selected
implies ≈4.59e-3 pulses per device-update — Ziksa fires a pulse only when
the accumulated conductance move exceeds a programming quantum). K-WTA
sparsification reduces the selected fraction to ζ ≈ 0.57, and the
projection lands at ≈12.2 years — both ends reproduced here from the
metered write counts alone.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.analog.costmodel import HardwareConstants
from repro.analog.endurance import EnduranceTracker, lifespan_years


@dataclasses.dataclass(frozen=True)
class LifetimeProjection:
    """Lifetime figures derived from a metered write map."""
    updates_observed: int
    writes_per_device_update: float    # mean selected fraction
    pulses_per_device_update: float    # × Ziksa expected pulse rate
    years_mean: float                  # average device reaches endurance
    years_hot_tail: float              # 99th-percentile device (Fig. 5b tail)
    endurance_cycles: float
    update_period_s: float
    #: Per-cell ζ write-rate percentiles (writes per device-update at
    #: p50/p90/p99 across the write map) — the within-chip wear spread
    #: behind the mean/hot-tail pair above.
    rate_percentiles: Optional[dict[str, float]] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def project_lifetime(tracker: EnduranceTracker,
                     hw: Optional[HardwareConstants] = None,
                     update_period_s: float = 1e-3) -> LifetimeProjection:
    """Fold a tracker's per-device write counts into a lifetime projection
    at the paper's update cadence."""
    hw = hw if hw is not None else HardwareConstants()
    updates = tracker.updates_applied
    if updates == 0:
        raise ValueError("tracker has observed no updates; run training "
                         "with track_endurance=True first")
    counts = tracker.all_counts()
    rate_mean = float(counts.mean()) / updates if counts.size else 0.0
    rate_hot = (float(np.percentile(counts, 99)) / updates
                if counts.size else 0.0)
    rate_pcts = ({f"p{p}": float(np.percentile(counts, p)) / updates
                  for p in (50, 90, 99)} if counts.size else None)
    pulses = rate_mean * hw.ziksa_pulse_rate
    return LifetimeProjection(
        updates_observed=updates,
        writes_per_device_update=rate_mean,
        pulses_per_device_update=pulses,
        years_mean=lifespan_years(pulses, hw.endurance_cycles,
                                  update_period_s),
        years_hot_tail=lifespan_years(
            rate_hot * hw.ziksa_pulse_rate, hw.endurance_cycles,
            update_period_s),
        endurance_cycles=hw.endurance_cycles,
        update_period_s=update_period_s,
        rate_percentiles=rate_pcts)
