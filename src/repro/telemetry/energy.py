"""Counters → joules / seconds / GOPS via :class:`HardwareConstants`.

The analytical model (``analog/costmodel.py``) computes chip time and ops
per step from the architecture; here the same per-event costs are applied
to *metered* counters from a live run, so latency, throughput, power and
efficiency are derived from what the backend actually executed:

  chip cycles  = WBS streaming phases (hidden tile; the U_h tile shares
                 wordlines and runs concurrently) + ADC channel scans
                 (hidden + readout, ceil'd per scan to whole cycles)
                 + λ-interpolation cycles + readout streaming phases
  chip time    = cycles × T_clk
  energy       = Σ_component  P_component × chip time   (the mixed-signal
                 budget is dominated by always-on analog front-end blocks;
                 their energy accrues over busy time)
  ops          = 2 × MACs + 3 × interpolations

The digital-CMOS baseline charges the paper-calibrated per-op energy of a
65 nm 8-bit digital MAC datapath at iso-throughput
(``M2RUCostModel.digital_pj_per_op``) against the same metered op counts.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.analog.costmodel import DenseCostModel, M2RUCostModel
from repro.telemetry import meters as M

#: Off-chip DRAM access energy for the replay buffer, pJ per byte
#: (edge-class LPDDR4x, ≈5 pJ/bit incl. I/O + activation amortization).
#: Replay traffic is *off-chip*: it is reported alongside the chip
#: numbers (``telemetry_report``'s ``replay`` section) but deliberately
#: not folded into the chip power/efficiency that the analytical-model
#: 5 % agreement gates check.
DRAM_PJ_PER_BYTE = 40.0


def replay_traffic(counters: Mapping[str, int]) -> Optional[dict]:
    """Replay-buffer DRAM traffic summary from metered counters, or None
    when the run metered no replay activity."""
    reads = float(counters.get(M.REPLAY_READS, 0))
    writes = float(counters.get(M.REPLAY_WRITES, 0))
    if reads == 0 and writes == 0:
        return None
    nbytes = float(counters.get(M.REPLAY_READ_BYTES, 0)
                   + counters.get(M.REPLAY_WRITE_BYTES, 0))
    return {
        "rows_read": reads,
        "rows_written": writes,
        "bytes": nbytes,
        "dram_pj_per_byte": DRAM_PJ_PER_BYTE,
        "dram_energy_j": nbytes * DRAM_PJ_PER_BYTE * 1e-12,
    }


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Metered energy/latency summary of one workload on one substrate."""
    kind: str                       # "analog" | "cmos"
    cycles: float                   # chip clock cycles (analog) / equiv
    time_s: float                   # chip busy time
    ops: float                      # arithmetic ops (MAC = 2)
    energy_j: float
    breakdown_j: dict[str, float]   # per-component energy
    power_w: float
    power_training_w: float         # + projection/write-control when writes
    gops: float
    gops_per_w: float
    pj_per_op: float
    sample_steps: float             # recurrence rows metered
    write_pulses: float             # programmed synapses metered

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _meter(counters: Mapping[str, int], name: str, tag: str = "") -> float:
    if tag:
        return float(counters.get(f"{name}/{tag}", 0))
    prefix = name + "/"
    return float(sum(v for k, v in counters.items()
                     if k == name or k.startswith(prefix)))


class MeteredEnergy:
    """Fold a :class:`Telemetry` counter snapshot into an
    :class:`EnergyReport` for the M2RU chip geometry in ``model``."""

    def __init__(self, model: "Optional[M2RUCostModel | DenseCostModel]"
                 = None):
        self.model = model if model is not None else M2RUCostModel()

    # ------------------------------------------------------------------
    def _ops(self, counters: Mapping[str, int]) -> float:
        return 2.0 * _meter(counters, M.MACS) + 3.0 * _meter(counters,
                                                             M.INTERP)

    def ops(self, counters: Mapping[str, int]) -> float:
        """Metered arithmetic ops (MAC = 2) across all tags — workload-
        agnostic, unlike the M2RU-geometry cycle model the full reports
        use. The serve engine's pJ/request falls back to this when the
        workload's tags don't map onto the chip geometry."""
        return self._ops(counters)

    def _chip_cycles(self, counters: Mapping[str, int]) -> float:
        m = self.model
        # Hidden crossbar: [W_h; U_h] share wordlines (Fig. 2) and stream
        # the concatenated drive concurrently — one set of phases, keyed
        # off the W_h tile.
        cycles = _meter(counters, M.WBS_PHASES, "w_h")
        hidden_scans = _meter(counters, M.ADC_CONVERSIONS, "hidden") / m.n_h
        cycles += hidden_scans * m.adc_scan_cycles(m.n_h)
        interp_scans = _meter(counters, M.INTERP, "h") / m.n_h
        cycles += interp_scans * m.interp_cycles()
        cycles += _meter(counters, M.WBS_PHASES, "w_o")
        out_scans = _meter(counters, M.ADC_CONVERSIONS, "out") / m.n_y
        cycles += out_scans * m.adc_scan_cycles(m.n_y)
        return cycles

    # ------------------------------------------------------------------
    def analog_report(self, counters: Mapping[str, int]) -> EnergyReport:
        """Mixed-signal M2RU: component powers over metered busy time."""
        m = self.model
        cycles = self._chip_cycles(counters)
        if cycles <= 0:
            raise ValueError(
                "telemetry has no metered forward activity; enable the "
                "backend's telemetry before the first step is traced")
        time_s = cycles * m.cycle_s
        brk_w = m.power_breakdown_w(training=False)
        breakdown_j = {k: p * time_s for k, p in brk_w.items()}
        energy_j = sum(breakdown_j.values())
        ops = self._ops(counters)
        power_w = energy_j / time_s
        p_train = power_w + (m.hw.p_train_extra_w
                             if _meter(counters, M.WRITE_EVENTS) > 0
                             else 0.0)
        gops = ops / time_s / 1e9
        return EnergyReport(
            kind="analog", cycles=cycles, time_s=time_s, ops=ops,
            energy_j=energy_j, breakdown_j=breakdown_j, power_w=power_w,
            power_training_w=p_train, gops=gops,
            gops_per_w=gops / power_w,
            pj_per_op=energy_j / ops * 1e12,
            sample_steps=_meter(counters, M.SAMPLE_STEPS),
            write_pulses=_meter(counters, M.WRITE_PULSES))

    # ------------------------------------------------------------------
    def cmos_report(self, counters: Mapping[str, int]) -> EnergyReport:
        """Digital 65 nm baseline at iso-throughput: the paper-calibrated
        per-op energy (MAC + memory traffic) charged per metered op."""
        m = self.model
        ops = self._ops(counters)
        if ops <= 0:
            raise ValueError("telemetry has no metered forward activity")
        e_op = m.digital_pj_per_op() * 1e-12
        energy_j = ops * e_op
        time_s = ops / (m.gops() * 1e9)        # iso-throughput comparison
        power_w = energy_j / time_s
        return EnergyReport(
            kind="cmos", cycles=time_s / m.cycle_s, time_s=time_s, ops=ops,
            energy_j=energy_j, breakdown_j={"digital_mac": energy_j},
            power_w=power_w, power_training_w=power_w,
            gops=ops / time_s / 1e9, gops_per_w=(ops / time_s / 1e9)
            / power_w, pj_per_op=e_op * 1e12,
            sample_steps=_meter(counters, M.SAMPLE_STEPS),
            write_pulses=_meter(counters, M.WRITE_PULSES))

    # ------------------------------------------------------------------
    def dense_report(self, counters: Mapping[str, int],
                     model: Optional[DenseCostModel] = None,
                     tag: str = "dense") -> EnergyReport:
        """Transformer-shape serving energy: the metered ``dense``-tag
        activity (every quantized projection in the model zoo's LM
        layers) charged through a :class:`DenseCostModel` of the served
        architecture. Iso-throughput like :meth:`cmos_report`: busy time
        is metered ops over the stack's analytical GOPS, so power,
        GOPS/W and pJ/op are the model's figures while total energy and
        time scale with what the engine actually dispatched."""
        m = model if model is not None else self.model
        if not isinstance(m, DenseCostModel):
            raise ValueError(
                "dense_report needs a DenseCostModel (pass one, or "
                "construct MeteredEnergy with it); got "
                f"{type(m).__name__}")
        ops = 2.0 * _meter(counters, M.MACS, tag)
        if ops <= 0:
            raise ValueError(
                f"telemetry has no metered {tag!r} activity; enable the "
                "substrate's telemetry before the first step is traced")
        time_s = ops / (m.gops() * 1e9)
        brk_w = m.power_breakdown_w()
        breakdown_j = {k: p * time_s for k, p in brk_w.items()}
        energy_j = sum(breakdown_j.values())
        power_w = energy_j / time_s
        gops = ops / time_s / 1e9
        token_rows = _meter(counters, M.VMM_ROWS, tag) / m.n_projections
        return EnergyReport(
            kind="dense", cycles=token_rows * m.row_cycles(),
            time_s=time_s, ops=ops, energy_j=energy_j,
            breakdown_j=breakdown_j, power_w=power_w,
            power_training_w=power_w, gops=gops,
            gops_per_w=gops / power_w,
            pj_per_op=energy_j / ops * 1e12,
            sample_steps=token_rows,
            write_pulses=_meter(counters, M.WRITE_PULSES))

    def report(self, counters: Mapping[str, int],
               kind: str = "analog") -> EnergyReport:
        if kind == "analog":
            return self.analog_report(counters)
        if kind == "cmos":
            return self.cmos_report(counters)
        if kind == "dense":
            return self.dense_report(counters)
        raise ValueError(f"unknown substrate kind {kind!r}; "
                         "expected 'analog', 'cmos' or 'dense'")


def efficiency_ratio(analog: EnergyReport, cmos: EnergyReport) -> float:
    """Per-op energy ratio (the paper's 29× claim), robust to the two runs
    metering slightly different numbers of steps."""
    return cmos.pj_per_op / analog.pj_per_op
