"""Measured device telemetry — counters → energy/latency → paper claims.

The analytical circuit model (``analog/costmodel.py``) derives Table I and
Fig. 5b/5d from closed-form expressions; this package derives the same
numbers from *metered* backend activity: every ``DeviceBackend`` carries a
:class:`Telemetry` accumulator whose counters are incremented by the
protocol hooks (``device_vmm`` / ``device_readout`` / ``record_endurance``)
during actual training runs, and the energy/lifetime models fold those
counters into watts, GOPS/W, the 29×-vs-CMOS comparison, and the
12.2-year lifetime projection.

- meters:   the Telemetry accumulator (ADC-conversion, bit-pulse,
            crossbar-read/write, MAC counters) with jit-safe accounting.
- energy:   counters → joules / seconds / GOPS via HardwareConstants.
- lifetime: EnduranceTracker write maps → lifetime projection (§VI-B).
- report:   GOPS/W and 29×-vs-CMOS summaries for examples/benchmarks.
"""
from repro.telemetry.meters import Telemetry
from repro.telemetry.energy import EnergyReport, MeteredEnergy
from repro.telemetry.lifetime import LifetimeProjection, project_lifetime
from repro.telemetry.report import (cmos_comparison, format_report,
                                    format_timeline, telemetry_report)

__all__ = [
    "Telemetry",
    "EnergyReport", "MeteredEnergy",
    "LifetimeProjection", "project_lifetime",
    "telemetry_report", "cmos_comparison", "format_report",
    "format_timeline",
]
