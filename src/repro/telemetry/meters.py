"""The :class:`Telemetry` accumulator — device activity counters.

Counters are host-side Python integers keyed ``"<meter>/<tag>"`` (e.g.
``"macs/w_h"``, ``"adc_conversions/hidden"``). The tricky part is metering
code that runs inside ``jit``: Python executes only at *trace* time, once,
while the compiled program executes many times — and a ``lax.scan`` body is
traced once but runs T times. Naive host-side increments would undercount,
and per-op ``io_callback``s are hoisted out of scans under autodiff.

The accounting protocol that is exact under jit/scan/grad:

  * Meter hooks called with **concrete** inputs increment counters
    immediately.
  * Meter hooks called during **tracing** accumulate static deltas into a
    pending buffer, multiplied by the active :meth:`scaled` scopes (the
    forward wraps its time scan in ``scaled(T)``, so per-step deltas are
    recorded ×T).
  * :meth:`emit_pending` — called at a jit-safe point (top level of the
    traced function, outside any scan) — drains the pending buffer into a
    single ``io_callback`` that fires once per *execution* of the compiled
    program. ``core/continual.py`` places these flush points in the
    forward and in every train/eval step.

Data-dependent counts (write pulses — only nonzero updates cost pulses)
cannot be static; they are metered host-side from the concrete ``applied``
arrays in ``DeviceBackend.record_endurance``, which runs outside jit.
"""
from __future__ import annotations

import contextlib
from collections import Counter
from typing import Mapping, Optional

import jax
import numpy as np

# Canonical meter names (energy.py keys off these).
MACS = "macs"                        # multiply-accumulates per tile
VMM_ROWS = "vmm_rows"                # row-vector crossbar accesses
BIT_PULSES = "bit_pulses"            # WBS input drive pulses (rows·n_in·n_b)
WBS_PHASES = "wbs_phases"            # bit-streaming phases (rows·n_b)
ADC_CONVERSIONS = "adc_conversions"  # per-channel ADC conversions
INTERP = "interp"                    # λ-interpolated candidate states
SAMPLE_STEPS = "sample_steps"        # (sample × time-step) recurrence rows
SEQUENCES = "sequences"              # sequences fully processed
WRITE_PULSES = "write_pulses"        # nonzero programmed synapses
WRITE_EVENTS = "write_events"        # weight-update rounds
DRIFT_TICKS = "drift_ticks"          # retention-drift relaxation ticks
# Replay-buffer DRAM traffic (§IV-A: the rehearsal store lives in
# off-chip DRAM, not on the crossbar). Rows moved + the byte volume the
# energy model charges at DRAM access cost (telemetry/report.py); kept
# out of the *chip* power budget the analytical 5 % gates check.
REPLAY_READS = "replay_reads"                # rehearsal rows fetched
REPLAY_WRITES = "replay_writes"              # rows programmed into DRAM
REPLAY_READ_BYTES = "replay_read_bytes"      # quantized codes + label
REPLAY_WRITE_BYTES = "replay_write_bytes"


def _is_tracing(x) -> bool:
    return isinstance(x, jax.core.Tracer)


class Telemetry:
    """Per-backend activity accumulator. Disabled by default (zero cost:
    no callbacks are embedded and no counters touched)."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.counters: Counter = Counter()
        self._pending: dict[str, int] = {}
        self._scale = 1
        self._deferred = False

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def enable(self) -> "Telemetry":
        """Enable *before* the first train/eval step is traced — the flag
        is read at trace time and baked into the compiled program."""
        self.enabled = True
        return self

    def disable(self) -> "Telemetry":
        self.enabled = False
        return self

    def reset(self) -> None:
        self.counters.clear()
        self._pending.clear()

    def snapshot(self) -> dict[str, int]:
        """Counters with all dispatched callbacks drained."""
        jax.effects_barrier()
        return dict(self.counters)

    def total(self, meter: str) -> int:
        """Sum of one meter across all tags."""
        jax.effects_barrier()
        prefix = meter + "/"
        return sum(v for k, v in self.counters.items()
                   if k == meter or k.startswith(prefix))

    # ------------------------------------------------------------------
    # Accounting core
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def scaled(self, n: int):
        """Multiply deltas recorded inside the scope by ``n`` — wrap the
        trace of a scan body whose compiled form runs ``n`` times."""
        prev, self._scale = self._scale, self._scale * int(n)
        try:
            yield self
        finally:
            self._scale = prev

    @contextlib.contextmanager
    def deferred(self):
        """Suppress :meth:`emit_pending` inside the scope so pending deltas
        survive until a single flush point. Needed when a metered forward
        (which flushes itself) is traced *inside* a ``lax.scan`` body: its
        interior flush would embed an io_callback that fires once per scan
        iteration while the deltas already carry the scan's ``scaled``
        multiplier — double counting. The compiled scenario sweep wraps its
        scan-over-tasks in ``deferred()`` and flushes once at the top level
        of the jitted run.

        Exception-safe: a trace aborted inside the scope (shape error,
        interrupt) rolls the pending buffer back to its entry state —
        otherwise the partial trace's deltas would leak into the next
        successful trace's flush and overcount."""
        prev, self._deferred = self._deferred, True
        entry = dict(self._pending)
        try:
            yield self
        except BaseException:
            self._pending = entry
            raise
        finally:
            self._deferred = prev

    def _add(self, deltas: Mapping[str, int]) -> None:
        for k, v in deltas.items():
            self.counters[k] += v

    def record(self, deltas: Mapping[str, int], anchor=None) -> None:
        """Record static deltas. ``anchor`` is any value from the metered
        computation: a tracer routes the deltas to the pending buffer (to
        be flushed by :meth:`emit_pending`), a concrete array or None
        counts immediately. Scale scopes apply either way."""
        if not self.enabled or not deltas:
            return
        scaled = {k: v * self._scale for k, v in deltas.items()}
        if _is_tracing(anchor):
            for k, v in scaled.items():
                self._pending[k] = self._pending.get(k, 0) + v
        else:
            self._add(scaled)

    def emit_pending(self) -> None:
        """Drain the pending buffer into one ``io_callback`` that fires per
        execution of the enclosing compiled function. Call at the top level
        of a jitted step (outside any scan); safe under value_and_grad.
        No-op when nothing is pending or inside a :meth:`deferred` scope."""
        if not self.enabled or self._deferred or not self._pending:
            return
        snap = dict(self._pending)
        self._pending.clear()

        def _cb():
            self._add(snap)

        from jax.experimental import io_callback
        io_callback(_cb, None)

    # ------------------------------------------------------------------
    # Meter hooks (static, shape-derived)
    # ------------------------------------------------------------------
    def meter_vmm(self, drive, weights, input_bits: Optional[int],
                  tag: str = "") -> None:
        """One backend VMM: rows = every leading element of ``drive``
        streams through the (n_in × n_out) tile."""
        if not self.enabled:
            return
        rows = int(np.prod(drive.shape[:-1])) if drive.ndim > 1 else 1
        n_in, n_out = weights.shape[-2], weights.shape[-1]
        sfx = f"/{tag}" if tag else ""
        deltas = {f"{VMM_ROWS}{sfx}": rows,
                  f"{MACS}{sfx}": rows * n_in * n_out}
        if input_bits:
            deltas[f"{BIT_PULSES}{sfx}"] = rows * n_in * input_bits
            deltas[f"{WBS_PHASES}{sfx}"] = rows * input_bits
        self.record(deltas, anchor=drive)

    def meter_adc(self, x, tag: str = "") -> None:
        """Fused-readout ADC: one conversion per element."""
        if not self.enabled:
            return
        sfx = f"/{tag}" if tag else ""
        self.record({f"{ADC_CONVERSIONS}{sfx}": int(np.prod(x.shape))},
                    anchor=x)

    def meter_writes(self, masks: Mapping[str, np.ndarray]) -> None:
        """Host-side write metering from concrete nonzero-update masks
        (only written devices cost pulses — §VI-B)."""
        if not self.enabled:
            return
        deltas = {f"{WRITE_PULSES}/{k}": int(np.asarray(m).sum())
                  for k, m in masks.items()}
        deltas[WRITE_EVENTS] = 1
        self._add(deltas)

    def meter_write_counts(self, counts: Mapping[str, np.ndarray],
                           events: int) -> None:
        """Host-side write metering from accumulated per-device write-count
        maps (the compiled sweep sums its nonzero-update masks across the
        whole scan and flushes once, instead of once per step)."""
        if not self.enabled:
            return
        deltas = {f"{WRITE_PULSES}/{k}": int(np.asarray(c).sum())
                  for k, c in counts.items()}
        deltas[WRITE_EVENTS] = int(events)
        self._add(deltas)

    # ------------------------------------------------------------------
    def __repr__(self) -> str:
        state = "enabled" if self.enabled else "disabled"
        return f"<Telemetry {state} counters={len(self.counters)}>"
