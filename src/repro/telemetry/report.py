"""Run-level summaries: Table I / Fig. 5d / 29×-vs-CMOS from a live run.

``telemetry_report`` assembles the metered numbers next to the analytical
cost model's so benchmarks and examples can assert agreement;
``cmos_comparison`` reproduces the 29× efficiency claim from two metered
runs of the same workload (analog + cmos backends); ``format_report``
renders a human-readable block for the example drivers.
"""
from __future__ import annotations

from typing import Optional

from repro.analog.costmodel import M2RUCostModel
from repro.analog.endurance import EnduranceTracker
from repro.telemetry.energy import (MeteredEnergy, efficiency_ratio,
                                    replay_traffic)
from repro.telemetry.lifetime import project_lifetime
from repro.telemetry.meters import Telemetry


def telemetry_report(telemetry: Telemetry,
                     model: Optional[M2RUCostModel] = None,
                     kind: str = "analog",
                     tracker: Optional[EnduranceTracker] = None,
                     update_period_s: float = 1e-3,
                     fleet: Optional[dict] = None,
                     runlog: Optional[object] = None) -> dict:
    """Metered Table I numbers (+ lifetime when a tracker is given), side
    by side with the closed-form cost model for the same geometry.
    ``fleet`` (a :func:`repro.fleet.fleet_aggregate` dict) attaches the
    population-distribution section ``format_report`` renders; ``runlog``
    (a :class:`repro.obs.RunLog`) attaches the ``timeline`` section —
    write-rate-over-time next to the lifetime projection, per-task
    forgetting next to the final scalar."""
    model = model if model is not None else M2RUCostModel()
    energy = MeteredEnergy(model)
    counters = telemetry.snapshot()
    rep = energy.report(counters, kind=kind)
    out = {
        "kind": kind,
        "metered": {
            "cycles": rep.cycles,
            "chip_time_s": rep.time_s,
            "ops": rep.ops,
            "power_mw": rep.power_w * 1e3,
            "power_training_mw": rep.power_training_w * 1e3,
            "gops": rep.gops,
            "gops_per_w": rep.gops_per_w,
            "pj_per_op": rep.pj_per_op,
            "breakdown_mw": {k: v / rep.time_s * 1e3
                             for k, v in rep.breakdown_j.items()},
            "sample_steps": rep.sample_steps,
            "write_pulses": rep.write_pulses,
        },
        "analytical": {
            "power_mw": model.power_w() * 1e3,
            "gops": model.gops(),
            "gops_per_w": model.gops_per_watt(),
            "pj_per_op": model.pj_per_op(),
            "step_latency_us": model.step_latency_s() * 1e6,
        },
    }
    if rep.sample_steps > 0:
        out["metered"]["step_latency_us"] = rep.time_s / rep.sample_steps \
            * 1e6
    # Off-chip replay-buffer DRAM traffic (repro.replay): reported next
    # to — not inside — the chip power budget (see energy.replay_traffic).
    replay = replay_traffic(counters)
    if replay is not None:
        out["replay"] = replay
    if tracker is not None and tracker.updates_applied:
        out["lifetime"] = project_lifetime(
            tracker, model.hw, update_period_s).as_dict()
    if fleet is not None:
        out["fleet"] = fleet
    if runlog is not None:
        from repro.obs.runlog import timeline
        out["timeline"] = timeline(runlog)
    return out


def cmos_comparison(telemetry_analog: Telemetry, telemetry_cmos: Telemetry,
                    model: Optional[M2RUCostModel] = None) -> dict:
    """The 29× claim from two metered runs of the same workload."""
    model = model if model is not None else M2RUCostModel()
    energy = MeteredEnergy(model)
    a = energy.analog_report(telemetry_analog.snapshot())
    c = energy.cmos_report(telemetry_cmos.snapshot())
    return {
        "analog_pj_per_op": a.pj_per_op,
        "cmos_pj_per_op": c.pj_per_op,
        "cmos_power_mw": c.power_w * 1e3,
        "efficiency_gain": efficiency_ratio(a, c),
        "paper_gain": 29.0,
    }


def format_report(rep: dict) -> str:
    """Printable telemetry block for the example drivers."""
    m, a = rep["metered"], rep["analytical"]
    lines = [
        f"substrate: {rep['kind']}  "
        f"(metered {m['sample_steps']:.0f} sample-steps, "
        f"{m['ops']:.3g} ops)",
        f"  chip time          {m['chip_time_s']*1e3:9.3f} ms  "
        f"({m.get('step_latency_us', float('nan')):.2f} µs/step; "
        f"model {a['step_latency_us']:.2f})",
        f"  power              {m['power_mw']:9.2f} mW  "
        f"(model {a['power_mw']:.2f}; training "
        f"{m['power_training_mw']:.2f})",
        f"  throughput         {m['gops']:9.2f} GOPS (model {a['gops']:.2f})",
        f"  efficiency         {m['gops_per_w']:9.0f} GOPS/W "
        f"(model {a['gops_per_w']:.0f})",
        f"  energy/op          {m['pj_per_op']:9.2f} pJ "
        f"(model {a['pj_per_op']:.2f})",
    ]
    if m["write_pulses"]:
        lines.append(f"  write pulses       {m['write_pulses']:9.0f}")
    if "replay" in rep:
        r = rep["replay"]
        lines.append(
            f"  replay DRAM        {r['bytes']/1024:9.1f} KiB  "
            f"({r['rows_read']:.0f} reads / {r['rows_written']:.0f} "
            f"writes; ≈{r['dram_energy_j']*1e6:.1f} µJ off-chip @ "
            f"{r['dram_pj_per_byte']:.0f} pJ/B)")
    if "lifetime" in rep:
        lt = rep["lifetime"]
        lines.append(
            f"  projected lifetime {lt['years_mean']:9.1f} years @"
            f"{lt['update_period_s']*1e3:.0f} ms updates "
            f"(hot-tail {lt['years_hot_tail']:.1f}; "
            f"{lt['writes_per_device_update']:.2f} writes/device/update)")
        if lt.get("rate_percentiles"):
            rp = lt["rate_percentiles"]
            lines.append(
                "  ζ write-rate       "
                + "  ".join(f"{k} {v:.3f}" for k, v in rp.items())
                + "  writes/device/update")
    if "fleet" in rep:
        lines.append(format_fleet(rep["fleet"]))
    if "timeline" in rep:
        lines.append(format_timeline(rep["timeline"]))
    return "\n".join(lines)


#: Timeline streams rendered by :func:`format_timeline`, in display
#: order: (timeline key, label, formatter for the aggregate column).
_TIMELINE_ROWS = (
    ("loss", "loss", lambda v: f"last {v[-1]:.4f}"),
    ("write_pulses", "write pulses", lambda v: f"Σ {sum(v):.0f}"),
    ("dg_mag", "Σ|ΔG|", lambda v: f"Σ {sum(v):.3g}"),
    ("replay_occupancy", "replay fill", lambda v: f"max {max(v):.0f}"),
    ("drift_ticks", "drift ticks", lambda v: f"Σ {sum(v):.0f}"),
)


def format_timeline(tl: dict) -> str:
    """Printable timeline block (from :func:`repro.obs.timeline`):
    sparkline per stream — the *when* next to the report's lifetime
    aggregates — plus the per-task forgetting trajectory."""
    from repro.obs.runlog import sparkline
    lines = [f"timeline: {tl['n_steps']} steps @ cadence "
             f"{tl['cadence']} ({len(tl['steps'])} windows)"]
    for key, label, agg in _TIMELINE_ROWS:
        v = tl.get(key)
        if not v:
            continue
        if key == "drift_ticks" and not any(v):
            continue
        lines.append(f"  {label:<18} {sparkline(v):<48} {agg(v)}")
    fg = tl.get("forgetting_after_task")
    if fg is not None and len(fg) > 1:
        lines.append("  forgetting/task    "
                     + " ".join(f"{v:.3f}" for v in fg))
    return "\n".join(lines)


#: Fleet distributions rendered by :func:`format_fleet`, in display
#: order: (result key, label, unit).
_FLEET_ROWS = (
    ("average_accuracy", "accuracy", ""),
    ("forgetting", "forgetting", ""),
    ("power_mw", "power", " mW"),
    ("gops_per_w", "efficiency", " GOPS/W"),
    ("pj_per_op", "energy/op", " pJ"),
    ("lifetime_years", "lifetime", " years"),
    ("lifetime_hot_tail_years", "lifetime hot-tail", " years"),
    ("writes_per_device_update", "ζ write rate", ""),
)


def format_fleet(agg: dict) -> str:
    """Printable fleet-distribution block (from
    :func:`repro.fleet.fleet_aggregate`): one row per figure with the
    population p50/p95/p99 — the deployment question is the tail chip,
    not the mean."""
    prof = agg.get("het_profile") or "none"
    lines = [f"fleet: {agg['n_devices']} devices over "
             f"{agg.get('n_shards', 1)} shard(s), heterogeneity "
             f"'{prof}'"]
    for key, label, unit in _FLEET_ROWS:
        if key not in agg:
            continue
        d = agg[key]
        lines.append(
            f"  {label:<18} p50 {d['p50']:10.4g}  p95 {d['p95']:10.4g}  "
            f"p99 {d['p99']:10.4g}{unit}")
    hot = agg.get("hot_tail") or {}
    if hot:
        lines.append("  worst chips        "
                     + "  ".join(f"{k.replace('_device', '')}: #{v}"
                                 for k, v in sorted(hot.items())))
    return "\n".join(lines)
