"""Memristive crossbar model (§IV-B-1).

Each synaptic weight is the conductance difference between a tunable device
and a fixed reference device biased at the midpoint of the resistance window
(R_on = 2 MΩ, R_off = 20 MΩ, §V-B):

    w_ji ∝ 1/M_ji − 1/M_ri                                   (eq. 7)

Non-idealities modeled (per §V-B): 10 % cycle-to-cycle (read) variability,
10 % device-to-device write variation, conductance clipping to the physical
window, and optional finite write resolution (Ziksa pulse quantization).

All functions are jit-able; stochasticity is explicit via PRNG keys.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    r_on: float = 2e6            # Ω  (fully-SET resistance)
    r_off: float = 20e6          # Ω  (fully-RESET resistance)
    write_sigma: float = 0.10    # device-to-device write variability
    read_sigma: float = 0.10     # cycle-to-cycle read variability
    w_clip: float = 1.0          # |logical weight| mapped to full window
    write_levels: Optional[int] = None  # finite programming resolution
    prog_sigma: float = 0.0      # initial-programming variability (pairs)
    drift_rate: float = 0.0      # per-tick conductance relaxation → g_off
    # Retention-drift cadence: apply drift every ``drift_cadence`` updates,
    # with ``drift_cadence`` ticks per application — total relaxation over
    # a run is cadence-invariant ((1−rate)^N after N updates), but the
    # per-update modeling cost amortizes. 1 = the original per-update tick.
    drift_cadence: int = 1

    @property
    def g_on(self) -> float:
        return 1.0 / self.r_on

    @property
    def g_off(self) -> float:
        return 1.0 / self.r_off

    @property
    def g_ref(self) -> float:
        """Reference device at the midpoint of the conductance window."""
        return 0.5 * (self.g_on + self.g_off)

    @property
    def g_half_range(self) -> float:
        return 0.5 * (self.g_on - self.g_off)


@dataclasses.dataclass
class CrossbarState:
    """Programmed conductances (same shape as the logical weight matrix)."""
    g: jax.Array          # tunable device conductances (S)
    spec: CrossbarSpec

    def to_weights(self) -> jax.Array:
        """Ideal read-back of logical weights."""
        return (self.g - self.spec.g_ref) / self.spec.g_half_range \
            * self.spec.w_clip


def _target_conductance(w: jax.Array, spec: CrossbarSpec) -> jax.Array:
    wn = jnp.clip(w / spec.w_clip, -1.0, 1.0)
    return spec.g_ref + wn * spec.g_half_range


def program(key: jax.Array, w: jax.Array, spec: CrossbarSpec
            ) -> CrossbarState:
    """Program logical weights into the crossbar (Ziksa write scheme).

    Applies write variability and optional level quantization, then clips to
    the physical conductance window.
    """
    g_t = _target_conductance(w, spec)
    if spec.write_levels is not None:
        lo, hi = spec.g_off, spec.g_on
        step = (hi - lo) / (spec.write_levels - 1)
        g_t = jnp.round((g_t - lo) / step) * step + lo
    noise = 1.0 + spec.write_sigma * jax.random.normal(key, w.shape)
    g = jnp.clip(g_t * noise, spec.g_off, spec.g_on)
    return CrossbarState(g=g, spec=spec)


def update(key: jax.Array, state: CrossbarState, dw: jax.Array
           ) -> CrossbarState:
    """Incremental conductance update (in-situ training write).

    Only nonzero dw entries receive write pulses — the K-WTA sparsifier
    upstream decides which; the endurance tracker counts them.
    """
    spec = state.spec
    dg = dw / spec.w_clip * spec.g_half_range
    noise = 1.0 + spec.write_sigma * jax.random.normal(key, dw.shape)
    g = jnp.where(dw != 0, state.g + dg * noise, state.g)
    g = jnp.clip(g, spec.g_off, spec.g_on)
    return CrossbarState(g=g, spec=spec)


# ---------------------------------------------------------------------------
# Differential G⁺/G⁻ pairs — the conductance-domain state carried between
# steps by the ``analog_state`` backend. A logical weight is the scaled
# conductance difference of two tunable devices:
#
#     w = (G⁺ − G⁻) / (G_on − G_off) · w_clip
#
# Positive weights live on G⁺ (G⁻ parked at G_off), negative on G⁻. Pairs
# are plain ``{"g_pos", "g_neg"}`` dicts so they thread through jit as
# ordinary pytrees.
# ---------------------------------------------------------------------------

Pair = dict[str, jax.Array]


def pair_weights(pair: Pair, spec: CrossbarSpec) -> jax.Array:
    """Ideal (noiseless) read-back of logical weights from a pair."""
    g_range = spec.g_on - spec.g_off
    return (pair["g_pos"] - pair["g_neg"]) * (spec.w_clip / g_range)


def program_pair(key: Optional[jax.Array], w: jax.Array,
                 spec: CrossbarSpec, *,
                 prog_sigma: Optional[jax.Array] = None) -> Pair:
    """Initial programming of logical weights onto G⁺/G⁻ pairs, with
    ``prog_sigma`` device-to-device programming variability.

    ``prog_sigma`` overrides the spec's (static) value with a possibly
    *traced* scalar — the fleet heterogeneity path, where each simulated
    chip draws its own programming variability and the per-chip value
    rides the device-state pytree through vmap/shard_map. With an
    override the noise branch is always taken structurally (a traced
    sigma cannot gate a Python branch); zero just multiplies through.
    """
    wn = jnp.clip(w / spec.w_clip, -1.0, 1.0)
    g_range = spec.g_on - spec.g_off
    g_pos = spec.g_off + jnp.maximum(wn, 0.0) * g_range
    g_neg = spec.g_off + jnp.maximum(-wn, 0.0) * g_range
    sigma = prog_sigma if prog_sigma is not None else spec.prog_sigma
    if key is not None and (prog_sigma is not None or spec.prog_sigma > 0):
        kp, kn = jax.random.split(key)
        g_pos = g_pos * (1.0 + sigma
                         * jax.random.normal(kp, g_pos.shape))
        g_neg = g_neg * (1.0 + sigma
                         * jax.random.normal(kn, g_neg.shape))
    return {"g_pos": jnp.clip(g_pos, spec.g_off, spec.g_on),
            "g_neg": jnp.clip(g_neg, spec.g_off, spec.g_on)}


def update_pair(key: jax.Array, pair: Pair, dw: jax.Array,
                spec: CrossbarSpec, *,
                write_sigma: Optional[jax.Array] = None) -> Pair:
    """In-situ training write in the conductance domain.

    A positive logical delta potentiates G⁺, a negative one potentiates
    G⁻ (raising G⁻ lowers the weight); only nonzero deltas cost pulses.
    Each landed delta carries multiplicative write noise, optionally snaps
    to the finite programming grid, and saturates at the physical window —
    so repeated one-sided updates *lose* magnitude at the rails, a
    conductance-domain effect the logical-weight model cannot express.

    ``write_sigma`` overrides the spec's static value with a possibly
    traced per-chip scalar (fleet heterogeneity).
    """
    g_range = spec.g_on - spec.g_off
    dg = jnp.abs(dw) / spec.w_clip * g_range
    sigma = write_sigma if write_sigma is not None else spec.write_sigma
    noise = 1.0 + sigma * jax.random.normal(key, dw.shape)
    dg = dg * noise
    g_pos = jnp.where(dw > 0, pair["g_pos"] + dg, pair["g_pos"])
    g_neg = jnp.where(dw < 0, pair["g_neg"] + dg, pair["g_neg"])
    if spec.write_levels is not None:
        lo, hi = spec.g_off, spec.g_on
        step = (hi - lo) / (spec.write_levels - 1)
        snap = lambda g: jnp.round((g - lo) / step) * step + lo
        g_pos = jnp.where(dw > 0, snap(g_pos), g_pos)
        g_neg = jnp.where(dw < 0, snap(g_neg), g_neg)
    return {"g_pos": jnp.clip(g_pos, spec.g_off, spec.g_on),
            "g_neg": jnp.clip(g_neg, spec.g_off, spec.g_on)}


def drift_pair(pair: Pair, spec: CrossbarSpec, n_ticks: int = 1, *,
               drift_rate: Optional[jax.Array] = None) -> Pair:
    """Conductance relaxation toward G_off between updates: each tick
    shrinks the programmed excess by ``drift_rate`` (retention loss).

    The ``drift_rate`` override (a possibly traced per-chip scalar, fleet
    heterogeneity) bypasses the static zero-rate short-circuit — the
    relaxation is computed structurally and a zero rate multiplies
    through as keep == 1."""
    if drift_rate is None:
        if spec.drift_rate <= 0:
            return pair
        rate = spec.drift_rate
    else:
        rate = drift_rate
    keep = (1.0 - rate) ** n_ticks
    return {k: spec.g_off + (g - spec.g_off) * keep
            for k, g in pair.items()}


def vmm(key: Optional[jax.Array], x: jax.Array, state: CrossbarState
        ) -> jax.Array:
    """Analog vector-matrix multiply on the crossbar (eq. 7).

    x (…, n_in) dimensionless drive (the WBS layer handles bit streaming and
    voltage scaling); returns (…, n_out) in logical-weight units. With
    ``key`` None the read is noiseless (used for oracles/tests).
    """
    w_eff = state.to_weights()
    if key is not None and state.spec.read_sigma > 0:
        # Read noise perturbs each device conductance per access.
        g_noisy = state.g * (1.0 + state.spec.read_sigma
                             * jax.random.normal(key, state.g.shape))
        w_eff = (g_noisy - state.spec.g_ref) / state.spec.g_half_range \
            * state.spec.w_clip
    return x @ w_eff
