"""Memristive crossbar model (§IV-B-1).

Each synaptic weight is the conductance difference between a tunable device
and a fixed reference device biased at the midpoint of the resistance window
(R_on = 2 MΩ, R_off = 20 MΩ, §V-B):

    w_ji ∝ 1/M_ji − 1/M_ri                                   (eq. 7)

Non-idealities modeled (per §V-B): 10 % cycle-to-cycle (read) variability,
10 % device-to-device write variation, conductance clipping to the physical
window, and optional finite write resolution (Ziksa pulse quantization).

All functions are jit-able; stochasticity is explicit via PRNG keys.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    r_on: float = 2e6            # Ω  (fully-SET resistance)
    r_off: float = 20e6          # Ω  (fully-RESET resistance)
    write_sigma: float = 0.10    # device-to-device write variability
    read_sigma: float = 0.10     # cycle-to-cycle read variability
    w_clip: float = 1.0          # |logical weight| mapped to full window
    write_levels: Optional[int] = None  # finite programming resolution

    @property
    def g_on(self) -> float:
        return 1.0 / self.r_on

    @property
    def g_off(self) -> float:
        return 1.0 / self.r_off

    @property
    def g_ref(self) -> float:
        """Reference device at the midpoint of the conductance window."""
        return 0.5 * (self.g_on + self.g_off)

    @property
    def g_half_range(self) -> float:
        return 0.5 * (self.g_on - self.g_off)


@dataclasses.dataclass
class CrossbarState:
    """Programmed conductances (same shape as the logical weight matrix)."""
    g: jax.Array          # tunable device conductances (S)
    spec: CrossbarSpec

    def to_weights(self) -> jax.Array:
        """Ideal read-back of logical weights."""
        return (self.g - self.spec.g_ref) / self.spec.g_half_range \
            * self.spec.w_clip


def _target_conductance(w: jax.Array, spec: CrossbarSpec) -> jax.Array:
    wn = jnp.clip(w / spec.w_clip, -1.0, 1.0)
    return spec.g_ref + wn * spec.g_half_range


def program(key: jax.Array, w: jax.Array, spec: CrossbarSpec
            ) -> CrossbarState:
    """Program logical weights into the crossbar (Ziksa write scheme).

    Applies write variability and optional level quantization, then clips to
    the physical conductance window.
    """
    g_t = _target_conductance(w, spec)
    if spec.write_levels is not None:
        lo, hi = spec.g_off, spec.g_on
        step = (hi - lo) / (spec.write_levels - 1)
        g_t = jnp.round((g_t - lo) / step) * step + lo
    noise = 1.0 + spec.write_sigma * jax.random.normal(key, w.shape)
    g = jnp.clip(g_t * noise, spec.g_off, spec.g_on)
    return CrossbarState(g=g, spec=spec)


def update(key: jax.Array, state: CrossbarState, dw: jax.Array
           ) -> CrossbarState:
    """Incremental conductance update (in-situ training write).

    Only nonzero dw entries receive write pulses — the K-WTA sparsifier
    upstream decides which; the endurance tracker counts them.
    """
    spec = state.spec
    dg = dw / spec.w_clip * spec.g_half_range
    noise = 1.0 + spec.write_sigma * jax.random.normal(key, dw.shape)
    g = jnp.where(dw != 0, state.g + dg * noise, state.g)
    g = jnp.clip(g, spec.g_off, spec.g_on)
    return CrossbarState(g=g, spec=spec)


def vmm(key: Optional[jax.Array], x: jax.Array, state: CrossbarState
        ) -> jax.Array:
    """Analog vector-matrix multiply on the crossbar (eq. 7).

    x (…, n_in) dimensionless drive (the WBS layer handles bit streaming and
    voltage scaling); returns (…, n_out) in logical-weight units. With
    ``key`` None the read is noiseless (used for oracles/tests).
    """
    w_eff = state.to_weights()
    if key is not None and state.spec.read_sigma > 0:
        # Read noise perturbs each device conductance per access.
        g_noisy = state.g * (1.0 + state.spec.read_sigma
                             * jax.random.normal(key, state.g.shape))
        w_eff = (g_noisy - state.spec.g_ref) / state.spec.g_half_range \
            * state.spec.w_clip
    return x @ w_eff
