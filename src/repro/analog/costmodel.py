"""Analytical circuit cost model of M2RU (Fig. 5c, Fig. 5d, Table I).

This is the hardware gate of the reproduction (repro band 4): the paper's
numbers come from Cadence mixed-signal simulation of a 65 nm design; here
they are reproduced from first principles with the paper's own constants:

  clock 20 MHz (cycle = T_s = 50 ns), shared 1.28 GSps ADC (~2 ns/channel),
  WBS: one cycle per input bit, tiled interpolation ≤ 16 cycles,
  network 28×100×10, n_b = 8 bits, n_T = 28 steps.

Derived (validated in tests/test_costmodel.py against Table I):
  step latency  = 37 cycles = 1.85 µs
  throughput    = 1/(n_T·1.85 µs) = 19,305 seq/s ;  27,900 op/step ⇒ 15.1 GOPS
  efficiency    = 15.1 GOPS / 48.62 mW ≈ 310 GOPS/W ≈ 3.2 pJ/op
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareConstants:
    clock_hz: float = 20e6           # system clock (cycle = 50 ns = T_s)
    adc_rate_hz: float = 1.28e9      # shared high-speed ADC sample rate
    adc_s_per_channel: float = 2e-9  # paper: "T_conv per channel is ~2 ns"
    max_interp_cycles: int = 16      # tiling guarantee (§VI-C)
    v_bit: float = 0.1               # level-shifted bit amplitude (V)
    g_ref: float = 0.275e-6          # midpoint conductance (S)
    # Calibrated component powers (sum reproduces 48.62 mW @ 28×100×10):
    p_adc_w: float = 12e-3           # per shared high-speed ADC
    p_opamp_w: float = 0.15e-3       # per bitline neuron circuit (Op-Amp+int)
    p_digital_base_w: float = 7.13e-3  # control, FIFOs, buffers, sampler
    p_tanh_w: float = 3.74e-6        # shared PWL tanh (paper: ~3.74 µW)
    p_digital_per_unit_w: float = 9.5e-6  # interp/shift-reg per hidden unit
    p_train_extra_w: float = 8.35e-3 # projection + write-control (training)
    endurance_cycles: float = 1e9
    # Expected endurance-consuming SET/RESET events per *selected* synapse
    # per update. Ziksa programs in discrete conductance quanta, and a
    # typical in-situ update moves a device by far less than one quantum,
    # so most selected synapses don't fire a pulse on a given update.
    # Calibrated from the paper's dense-run lifetime: 6.9 years at 10^9
    # endurance and a 1 ms cadence with every device selected implies
    # 10^9 · 1 ms / 6.9 yr ≈ 4.59e-3 pulses per device-update; K-WTA's
    # ζ ≈ 0.57 selection then lands the 12.2-year figure. The telemetry
    # lifetime projection (repro.telemetry.lifetime) multiplies metered
    # write fractions by this rate.
    ziksa_pulse_rate: float = 4.59e-3


@dataclasses.dataclass(frozen=True)
class M2RUCostModel:
    """Latency / throughput / power model for an n_x × n_h × n_y MiRU chip."""
    n_x: int = 28
    n_h: int = 100
    n_y: int = 10
    n_bits: int = 8
    n_tiles: int = 6           # paper uses 4–16 depending on topology
    tiled: bool = True
    hw: HardwareConstants = HardwareConstants()

    # ------------------------------------------------------------------
    # Latency (Fig. 5c)
    # ------------------------------------------------------------------
    @property
    def cycle_s(self) -> float:
        return 1.0 / self.hw.clock_hz

    def adc_scan_cycles(self, n_channels: int) -> int:
        t = n_channels * self.hw.adc_s_per_channel
        return max(1, math.ceil(t / self.cycle_s - 1e-9))

    def interp_cycles(self) -> int:
        """Serialized λ-interpolation of candidate states within each tile;
        tiles run concurrently (§IV-B-1)."""
        if self.tiled:
            return min(self.hw.max_interp_cycles,
                       math.ceil(self.n_h / self.n_tiles))
        return self.n_h  # fully serialized without tiling

    def step_cycles(self) -> int:
        """Cycles to process one feature set (one time step)."""
        hidden_vmm = self.n_bits                       # 1 bit / cycle (WBS)
        hidden_adc = self.adc_scan_cycles(self.n_h)
        interp = self.interp_cycles()
        out_vmm = self.n_bits
        out_adc = self.adc_scan_cycles(self.n_y)
        return hidden_vmm + hidden_adc + interp + out_vmm + out_adc

    def step_latency_s(self) -> float:
        return self.step_cycles() * self.cycle_s

    def seq_latency_s(self, n_t: int = 28) -> float:
        return n_t * self.step_latency_s()

    def throughput_seq_per_s(self, n_t: int = 28) -> float:
        return 1.0 / self.seq_latency_s(n_t)

    # ------------------------------------------------------------------
    # Ops / GOPS (Table I)
    # ------------------------------------------------------------------
    def ops_per_step(self) -> int:
        vmm_h = 2 * (self.n_x + self.n_h) * self.n_h   # MAC = 2 ops
        vmm_o = 2 * self.n_h * self.n_y
        interp = 3 * self.n_h                          # 2 mul + 1 add
        return vmm_h + vmm_o + interp

    def gops(self) -> float:
        return self.ops_per_step() / self.step_latency_s() / 1e9

    # ------------------------------------------------------------------
    # Power (Fig. 5d, Table I)
    # ------------------------------------------------------------------
    def power_breakdown_w(self, training: bool = False) -> dict[str, float]:
        hw = self.hw
        n_bitlines = self.n_h + self.n_y
        # Crossbar static drive: V² G over all devices, ~50 % bit activity.
        n_devices = 2 * ((self.n_x + self.n_h) * self.n_h
                         + self.n_h * self.n_y)
        p_xbar = 0.5 * n_devices * hw.v_bit ** 2 * hw.g_ref
        # One shared high-speed ADC per crossbar (hidden + readout).
        n_adc = 2 if max(self.n_h, self.n_y) < 128 else \
            2 + (self.n_h // 128)
        brk = {
            "adc": n_adc * hw.p_adc_w,
            "opamp": n_bitlines * hw.p_opamp_w,
            "crossbar": p_xbar,
            "digital": (hw.p_digital_base_w + hw.p_tanh_w
                        + self.n_h * hw.p_digital_per_unit_w),
        }
        if training:
            brk["training"] = hw.p_train_extra_w
        return brk

    def power_w(self, training: bool = False) -> float:
        return sum(self.power_breakdown_w(training).values())

    def gops_per_watt(self, training: bool = False) -> float:
        return self.gops() / self.power_w(training)

    def pj_per_op(self, training: bool = False) -> float:
        return self.power_w(training) / (self.gops() * 1e9) * 1e12

    # ------------------------------------------------------------------
    # Digital-CMOS comparison (the 29× claim)
    # ------------------------------------------------------------------
    def digital_pj_per_op(self) -> float:
        """Digital 65 nm MiRU at the same throughput. The paper reports the
        mixed-signal design is 29× more energy-efficient; a 65 nm 8-bit MAC
        at ~0.2 V_dd-scaled costs ≈ 90-100 pJ with memory traffic — we use
        29 × our pJ/op as the calibrated digital reference and validate the
        ratio, not the absolute."""
        return 29.0 * self.pj_per_op()

    def efficiency_gain_vs_digital(self) -> float:
        return self.digital_pj_per_op() / self.pj_per_op()

    # ------------------------------------------------------------------
    # Lifespan (§VI-B) — ties into analog.endurance
    # ------------------------------------------------------------------
    def lifespan_years(self, writes_per_update_mean_rate: float,
                       update_period_s: float = 1e-3) -> float:
        from repro.analog.endurance import lifespan_years
        return lifespan_years(writes_per_update_mean_rate,
                              self.hw.endurance_cycles, update_period_s)


@dataclasses.dataclass(frozen=True)
class DenseCostModel:
    """Crossbar-mapped dense projection stack — the transformer-shape
    energy model for the model zoo's quantized serving path.

    The zoo's LM layers route every quantized projection through the WBS
    crossbar (``models/layers.dense``, tag ``dense``); this model maps
    that projection stack onto the same 65 nm mixed-signal circuit
    vocabulary as :class:`M2RUCostModel` — weights stationary in
    differential memristor pairs, WBS drive at one input bit per cycle,
    shared high-speed ADCs scanning the bitlines — so model-zoo serving
    runs report GOPS/W and pJ/op on the same footing as the M2RU chip.

    ``shapes`` lists the (K, N) of each quantized projection one token
    row traverses per decode step: attention/SSM in/out projections, the
    active FFN or expert stack, and the untied LM head. Unquantized ops
    (router logits, embeddings, norms, attention itself) are outside the
    crossbar and excluded — consistent with what the ``dense`` meter tag
    actually counts. Build it from a ModelConfig via
    :meth:`from_model_config`; feed it metered counters through
    :meth:`repro.telemetry.energy.MeteredEnergy.dense_report`.
    """
    shapes: tuple[tuple[int, int], ...]
    n_bits: int = 8
    #: Bitline channels per shared high-speed ADC (one extra bank per
    #: 128 outputs — the M2RU sizing rule applied to wide projections).
    adc_bank_channels: int = 128
    hw: HardwareConstants = HardwareConstants()

    def __post_init__(self):
        if not self.shapes:
            raise ValueError("DenseCostModel needs at least one "
                             "(K, N) projection shape")

    # ------------------------------------------------------------------
    @property
    def cycle_s(self) -> float:
        return 1.0 / self.hw.clock_hz

    @property
    def n_projections(self) -> int:
        return len(self.shapes)

    def adc_banks(self, n_out: int) -> int:
        return max(1, math.ceil(n_out / self.adc_bank_channels))

    def adc_scan_cycles(self, n_out: int) -> int:
        """Banks scan their channel groups concurrently."""
        t = math.ceil(n_out / self.adc_banks(n_out)) \
            * self.hw.adc_s_per_channel
        return max(1, math.ceil(t / self.cycle_s - 1e-9))

    def row_cycles(self) -> int:
        """Cycles for one token row through the full stack: the
        projections are sequentially dependent, each streams ``n_bits``
        WBS phases then scans its output bitlines."""
        return sum(self.n_bits + self.adc_scan_cycles(n)
                   for _, n in self.shapes)

    def row_latency_s(self) -> float:
        return self.row_cycles() * self.cycle_s

    def ops_per_row(self) -> int:
        return sum(2 * k * n for k, n in self.shapes)

    def gops(self) -> float:
        return self.ops_per_row() / self.row_latency_s() / 1e9

    # ------------------------------------------------------------------
    def power_breakdown_w(self) -> dict[str, float]:
        hw = self.hw
        n_devices = 2 * sum(k * n for k, n in self.shapes)
        n_bitlines = sum(n for _, n in self.shapes)
        n_adc = sum(self.adc_banks(n) for _, n in self.shapes)
        return {
            "adc": n_adc * hw.p_adc_w,
            "opamp": n_bitlines * hw.p_opamp_w,
            "crossbar": 0.5 * n_devices * hw.v_bit ** 2 * hw.g_ref,
            "digital": (hw.p_digital_base_w
                        + n_bitlines * hw.p_digital_per_unit_w),
        }

    def power_w(self) -> float:
        return sum(self.power_breakdown_w().values())

    def gops_per_watt(self) -> float:
        return self.gops() / self.power_w()

    def pj_per_op(self) -> float:
        return self.power_w() / (self.gops() * 1e9) * 1e12

    def digital_pj_per_op(self) -> float:
        """Digital 65 nm baseline at iso-throughput — same calibrated
        29× mixed-signal advantage as :meth:`M2RUCostModel.digital_pj_per_op`."""
        return 29.0 * self.pj_per_op()

    # ------------------------------------------------------------------
    @classmethod
    def from_model_config(cls, cfg, n_bits: int = 8) -> "DenseCostModel":
        """The quantized (K, N) stack one decode token traverses, per
        architecture family — mirrors exactly which projections
        ``models/*`` route through ``layers.dense`` with a quant mode
        (the counters' ``dense`` tag): GQA or MLA attention, dense FFN or
        the active expert set (router is fp32), Mamba in/out projections,
        the untied LM head. Per-layer composition follows
        ``ModelConfig.is_ssm_layer`` / ``is_moe_layer``."""
        D, hd = cfg.d_model, cfg.hd()
        q, kv = cfg.n_heads * hd, cfg.n_kv_heads * hd
        if cfg.use_mla:
            attn = [(D, cfg.q_lora_rank),
                    (cfg.q_lora_rank, cfg.n_heads
                     * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)),
                    (D, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                    (cfg.kv_lora_rank, cfg.n_heads
                     * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
                    (cfg.n_heads * cfg.v_head_dim, D)]
        else:
            attn = [(D, q), (D, kv), (D, kv), (q, D)]
        ffn = [(D, cfg.d_ff), (D, cfg.d_ff), (cfg.d_ff, D)]
        moe_one = [(D, cfg.moe_d_ff), (D, cfg.moe_d_ff), (cfg.moe_d_ff, D)]
        d_in = cfg.ssm_expand * D
        ssm = [(D, 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state
                + (d_in // cfg.ssm_head_dim if cfg.ssm_head_dim else 0)),
               (d_in, D)] if cfg.ssm_state else []
        shapes: list[tuple[int, int]] = []
        for i in range(cfg.n_layers):
            shapes += ssm if cfg.is_ssm_layer(i) else attn
            if cfg.is_moe_layer(i):
                shapes += (cfg.top_k + cfg.n_shared_experts) * moe_one
            elif cfg.d_ff:
                shapes += ffn
        if not cfg.tie_embeddings:
            shapes.append((D, cfg.vocab))
        return cls(shapes=tuple(shapes), n_bits=n_bits)
