"""Mixed-signal hardware-like model of the M2RU accelerator.

- crossbar:   conductance-pair weight mapping + device non-idealities.
- wbs:        weighted-bit-streaming numerical model (eqs. 11-19).
- adc:        ADC quantization + integrator leakage model (eqs. 8-10).
- endurance:  per-device write counting, CDF, lifespan projection (Fig. 5b).
- costmodel:  cycle/power analytical model (Fig. 5c/5d, Table I).
"""
from repro.analog.crossbar import CrossbarSpec, CrossbarState, program, vmm
from repro.analog.wbs import WBSSpec, wbs_vmm, quantize_signed
from repro.analog.adc import adc_quantize, integrator_droop
from repro.analog.endurance import EnduranceTracker, lifespan_years
from repro.analog.costmodel import M2RUCostModel, HardwareConstants

__all__ = [
    "CrossbarSpec", "CrossbarState", "program", "vmm",
    "WBSSpec", "wbs_vmm", "quantize_signed",
    "adc_quantize", "integrator_droop",
    "EnduranceTracker", "lifespan_years",
    "M2RUCostModel", "HardwareConstants",
]
