"""ADC quantization and integrator retention model (§IV-B-1, eqs. 8-10).

The shared high-speed ADC (1.28 GSps, ~2 ns per channel) scans all bitlines
of a crossbar; transmission gates isolate the integrator during the hold
phase so droop is limited to Op-Amp bias current and capacitor dielectric
leakage. The droop functions reproduce the paper's < 0.1 LSB budget check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_quantize(v: jax.Array, bits: int, full_scale: float) -> jax.Array:
    """Mid-rise uniform quantizer over [-full_scale, +full_scale]."""
    levels = 2 ** bits
    step = 2.0 * full_scale / levels
    q = jnp.round(v / step)
    q = jnp.clip(q, -(levels // 2), levels // 2 - 1)
    return q * step


def integrator_droop(v_int: float, t_conv: float, tau: float) -> float:
    """ΔV = V_int · exp(−T_conv/τ)   (eq. 8) — returns the *droop* V−V'."""
    import math
    return v_int * (1.0 - math.exp(-t_conv / tau))


def droop_leakage(v_int: float, t_conv: float, r_leak: float,
                  c_f: float) -> float:
    """ΔV_l ≈ V_int · T_conv / (R_leak · C_f)   (eq. 9, hold phase)."""
    return v_int * t_conv / (r_leak * c_f)


def droop_bias(i_b: float, t_conv: float, c_f: float) -> float:
    """ΔV_b = I_b · T_conv / C_f   (eq. 10, Op-Amp input bias)."""
    return i_b * t_conv / c_f


def total_hold_droop(v_int: float = 0.5, t_conv: float = 200e-9,
                     c_f: float = 2e-12, i_b: float = 50e-12,
                     r_leak: float = 10e9) -> float:
    """Worst-case droop over an ADC scan with the paper's constants.

    Paper: < 10.5 µV (< 0.1 LSB) over 200 ns with C_f = 2 pF, I_b < 50 pA,
    R_leak > 10 GΩ.
    """
    return droop_leakage(v_int, t_conv, r_leak, c_f) \
        + droop_bias(i_b, t_conv, c_f)
