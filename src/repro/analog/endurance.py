"""Memristor endurance tracking and lifespan projection (§VI-B, Fig. 5b).

Devices tolerate 10^6–10^12 SET/RESET cycles; the paper assumes 10^9.
Training writes are counted per device; K-WTA gradient sparsification cuts
write traffic ~47 %, moving the projected lifetime from ~6.9 to ~12.2 years
at a 1 ms update cadence.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class EnduranceTracker:
    """Per-device write counters for a set of named weight arrays."""
    endurance: float = 1e9

    def __post_init__(self):
        self._counts: dict[str, np.ndarray] = {}
        self.updates_applied = 0

    def register(self, name: str, shape: tuple[int, ...]) -> None:
        self._counts[name] = np.zeros(shape, dtype=np.int64)

    def record(self, name: str, mask: np.ndarray) -> None:
        if name not in self._counts:
            self.register(name, mask.shape)
        self._counts[name] += mask.astype(np.int64)

    def record_update(self, masks: dict[str, np.ndarray]) -> None:
        for name, m in masks.items():
            self.record(name, np.asarray(m))
        self.updates_applied += 1

    def record_counts(self, counts: dict[str, np.ndarray],
                      updates: int) -> None:
        """Fold in per-device write-count maps accumulated over ``updates``
        weight-update rounds (the compiled sweep sums its write masks
        inside the scan and records once per run). Equivalent to
        ``updates`` calls to :meth:`record_update` with the same totals."""
        for name, c in counts.items():
            self.record(name, np.asarray(c))
        self.updates_applied += int(updates)

    # ------------------------------------------------------------------
    # Serialization (checkpoints) — lifetime projections survive restarts
    # ------------------------------------------------------------------
    TYPE_TAG = "endurance_tracker"

    def state_dict(self) -> dict:
        """Array-leaved tree for ``train.checkpoint.CheckpointManager``
        (which persists any pytree of arrays)."""
        return {
            "_tree_type_": np.asarray(self.TYPE_TAG),
            "endurance": np.asarray(self.endurance),
            "updates_applied": np.asarray(self.updates_applied,
                                          dtype=np.int64),
            "counts": {name: c.copy()
                       for name, c in self._counts.items()},
        }

    @classmethod
    def from_state_dict(cls, state: dict) -> "EnduranceTracker":
        tracker = cls(endurance=float(np.asarray(state["endurance"])))
        tracker.updates_applied = int(np.asarray(state["updates_applied"]))
        for name, c in state.get("counts", {}).items():
            tracker._counts[name] = np.asarray(c, dtype=np.int64).copy()
        return tracker

    # ------------------------------------------------------------------
    # Analysis
    # ------------------------------------------------------------------
    def all_counts(self) -> np.ndarray:
        if not self._counts:
            return np.zeros((0,), dtype=np.int64)
        return np.concatenate([c.reshape(-1) for c in self._counts.values()])

    def mean_writes(self) -> float:
        c = self.all_counts()
        return float(c.mean()) if c.size else 0.0

    def write_cdf(self, n_points: int = 256
                  ) -> tuple[np.ndarray, np.ndarray]:
        """(write_counts, CDF) — Fig. 5b's x/y."""
        c = np.sort(self.all_counts())
        if c.size == 0:
            return np.zeros(1), np.zeros(1)
        idx = np.linspace(0, c.size - 1, n_points).astype(int)
        return c[idx].astype(float), (idx + 1) / c.size

    def overstressed_fraction(self, projected_total_updates: float) -> float:
        """Fraction of devices whose *projected* writes exceed endurance if
        the observed per-update write rates continue for
        ``projected_total_updates`` updates (the shaded region in Fig. 5b)."""
        c = self.all_counts()
        if c.size == 0 or self.updates_applied == 0:
            return 0.0
        rate = c / self.updates_applied           # writes per update
        projected = rate * projected_total_updates
        return float((projected > self.endurance).mean())


def lifespan_years(mean_writes_per_update: float, endurance: float = 1e9,
                   update_period_s: float = 1e-3) -> float:
    """Years until the average device reaches its endurance limit.

    Paper calibration: uniform writes (rate=1) @1 ms, 10^9 endurance
    → 10^9 ms ≈ 31.7 yr *per device*, but the paper reports the network
    lifespan limited by the hot tail: with pre-sparsification write stats
    (mean 1.6e5 writes over the run) it reports 6.9 yr, post-sparsification
    (8.5e4) 12.2 yr — i.e. lifespan scales inversely with write rate. We
    reproduce that scaling: years = endurance / writes_per_second / seconds
    per year, with writes_per_second = mean_rate / update_period.
    """
    if mean_writes_per_update <= 0:
        return float("inf")
    writes_per_s = mean_writes_per_update / update_period_s
    seconds = endurance / writes_per_s
    return seconds / (365.25 * 24 * 3600)


def paper_lifespan_check() -> dict[str, float]:
    """The paper's own numbers: write-rate ratio 8.5e4/1.6e5 ≈ 0.53 maps
    6.9 yr → ~12.2 yr (they quote 12.2; ratio gives 12.99 — the paper's
    sparsified run also shifts the tail, absorbed here in the rate)."""
    dense_rate = 1.0 / 6.9
    sparse_years = 6.9 * (1.6e5 / 8.5e4)
    return {"dense_years": 6.9, "sparse_years_scaling": sparse_years,
            "paper_sparse_years": 12.2,
            "write_reduction": 1.0 - 8.5e4 / 1.6e5,
            "dense_rate": dense_rate}
