"""Weighted-Bit Streaming (WBS) numerical model — §V-A, eqs. (11)-(19).

Digital inputs are decomposed sign-magnitude into n_b bit planes. On the
chip each plane is streamed for a fixed pulse width T_s and weighted by the
memristor-ratio analog gain (M_f/M_i)_k = 2^{-k}; the integrator accumulates

    V_int ∝ Σ_k 2^{-k} · (bitplane_k ⊙ sign) · W                 (eq. 15-18)

which equals the fixed-point product (x / 2^{n_b}) · W when the ratios are
ideal. TPU adaptation (DESIGN.md §2): all bit planes are evaluated as
parallel matmuls — same math, throughput-oriented; the per-plane *ratio
variability* ε_k (one more memristor pair per plane) is retained as the
model's distinguishing non-ideality.

This module is the reference/simulation path; ``kernels/wbs_matmul.py`` is
the fused Pallas implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WBSSpec:
    n_bits: int = 8              # input precision streamed bit-by-bit
    gain_sigma: float = 0.0      # per-plane (M_f/M_i) ratio variability
    adc_bits: Optional[int] = 8  # fused output ADC; None = no quantization
    adc_range: float = 4.0       # symmetric ADC full-scale (logical units)


def quantize_signed(x: jax.Array, n_bits: int) -> tuple[jax.Array, jax.Array]:
    """Sign-magnitude quantization of x∈[-1,1] to (sign, magnitude-code).

    A digital '1' is streamed as ±0.1 V by the level shifter (Fig. 3-Left);
    '0' as 0 V — i.e. the hardware natively computes sign-magnitude.
    Returns (sign ∈ {-1,0,+1} int8, code ∈ [0, 2^n−1] uint8).
    """
    top = 2 ** n_bits - 1
    mag = jnp.clip(jnp.round(jnp.abs(x) * top), 0, top)
    sign = jnp.sign(x).astype(jnp.int8)
    return sign, mag.astype(jnp.uint8)


def bit_planes(code: jax.Array, n_bits: int) -> jax.Array:
    """(…,) uint → (n_bits, …) float bit planes, MSB first (k=1 ⇒ 2^{-1})."""
    ks = jnp.arange(n_bits - 1, -1, -1, dtype=jnp.uint8)  # MSB..LSB
    planes = (code[None, ...] >> ks.reshape(-1, *([1] * code.ndim))) & 1
    return planes.astype(jnp.float32)


def ideal_gains(n_bits: int) -> jax.Array:
    """(M_f/M_i)_k = 2^{-k}, k = 1..n_b (eq. 17), MSB first."""
    return 2.0 ** (-jnp.arange(1, n_bits + 1, dtype=jnp.float32))


def wbs_vmm(x: jax.Array, w: jax.Array, spec: WBSSpec,
            key: Optional[jax.Array] = None) -> jax.Array:
    """WBS crossbar VMM: y = Σ_k g_k · (B_k ⊙ s) @ W, then fused ADC.

    Args:
      x: (..., n_in) real inputs in [-1, 1].
      w: (n_in, n_out) logical weights (crossbar-programmed upstream).
      key: PRNG for gain variability (None ⇒ ideal ratios).

    With ideal ratios and adc_bits=None this equals a fixed-point matmul:
    max-abs error vs x@w bounded by the input quantization step.
    """
    sign, code = quantize_signed(x, spec.n_bits)
    planes = bit_planes(code, spec.n_bits)                 # (nb, ..., n_in)
    signed_planes = planes * sign.astype(jnp.float32)[None]

    gains = ideal_gains(spec.n_bits)
    if key is not None and spec.gain_sigma > 0:
        gains = gains * (1.0 + spec.gain_sigma
                         * jax.random.normal(key, gains.shape))
    # Scale per plane then single contraction: Σ_k g_k B_k is the exact
    # dequantized input, so one matmul suffices mathematically — but we keep
    # the per-plane contraction to model per-plane gain noise faithfully.
    y = jnp.einsum("k,k...i,io->...o", gains, signed_planes, w)
    # 2^{-1}..2^{-nb} weighting reconstructs x/ (1 - 2^{-nb})-ish scale;
    # normalize so ideal path returns x̂ @ w with x̂ the quantized x.
    y = y * (2.0 ** spec.n_bits / (2.0 ** spec.n_bits - 1.0))

    if spec.adc_bits is not None:
        from repro.analog.adc import adc_quantize
        y = adc_quantize(y, spec.adc_bits, spec.adc_range)
    return y
