"""repro — M2RU: Memristive Minion Recurrent Unit, as a production JAX framework.

Layers:
  core/        the paper's contribution (MiRU, DFA-through-time, K-WTA, replay)
  replay/      pluggable rehearsal policies (reservoir | ring |
               class_balanced | task_stratified | in-graph loss_aware)
  backends/    pluggable device substrates (ideal | wbs | analog + registry)
  analog/      mixed-signal hardware-like model + circuit cost model
  kernels/     Pallas TPU kernels (wbs_matmul, miru_scan, kwta)
  models/      LM architecture zoo (GQA/MLA/MoE/SSD/enc-dec/hybrid)
  configs/     assigned architecture configs + the paper's own
  data/        synthetic data pipeline + continual task streams
  optim/       optimizers, quantized state, sparsification, compression
  train/       training loop, checkpointing, fault tolerance
  serve/       batched decode engine
  distributed/ sharding rules and collective helpers
  launch/      mesh / dryrun / train / serve CLIs, roofline
"""

__version__ = "1.0.0"
