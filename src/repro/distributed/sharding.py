"""Per-parameter PartitionSpec rules (DP/FSDP/TP/EP) for every arch.

Scheme (DESIGN.md §6), on mesh axes (data, model) [+ replicated pod]:
  * 2-D projections (d_in, d_out): P('data','model') — FSDP × TP. The
    residual-side dim shards over 'data' (gathered per-layer under FSDP),
    the hidden/head dim over 'model' (tensor parallel).
  * back-projections to the residual (wo / w_down / out_proj):
    P('model','data') — keeps the contracting dim on 'model' so the TP
    pair (up-proj, down-proj) needs a single all-reduce.
  * MoE expert banks (E, D, F): experts over 'model' (EP) when E divides;
    otherwise fall back to TP over F. FSDP over D either way.
  * embeddings / lm_head: vocab over 'model'.
  * vectors (norms, biases, scalars): replicated.
Leading layer-stack (scan) dims are never sharded.

Divisibility is checked per-dim; anything non-divisible degrades to
replicated on that dim rather than relying on GSPMD padding (predictable
memory accounting in the dry-run).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

_BACK_PROJ = ("wo", "w_down", "out_proj")
_VOCAB = ("embed", "lm_head")
# Per-layer vectors (norm scales, biases, SSM scalars): replicated even
# when stacked into (L, dim) — sharding them buys nothing and costs a
# gather per layer.
_VECTOR_NAMES = frozenset({
    "norm", "norm1", "norm2", "norm_x", "final_norm", "enc_norm",
    "q_norm", "k_norm", "kv_norm", "b", "bias", "bq", "bk", "bv", "b_h",
    "b_o", "conv_b", "a_log", "dt_bias", "d_skip",
})


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name]


def _ok(dim: int, mesh, axis: Optional[str]) -> Optional[str]:
    if axis is None:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(path: tuple[str, ...], leaf, cfg: ModelConfig, mesh,
               fsdp_axis: str = "data", tp_axis: str = "model",
               replicate_small_banks: bool = False) -> P:
    name = path[-1]
    shape = leaf.shape
    nd = len(shape)
    if name in _VECTOR_NAMES:
        return P(*([None] * nd))
    # How many leading dims are layer-stack dims: treat every dim before
    # the last-2 (matrices) / last-1 (vectors) as stack/e dims, except MoE
    # expert banks handled explicitly.
    if name in ("router",):
        d = _ok(shape[-2], mesh, fsdp_axis)
        return P(*([None] * (nd - 2)), d, None)
    if name in ("w_gate", "w_up", "w_down") and nd >= 3 \
            and path[-2] != "shared" and cfg.n_experts > 0 \
            and shape[-3] == cfg.n_experts:
        E = shape[-3]
        ep = _ok(E, mesh, tp_axis)
        bank_bytes = E * shape[-2] * shape[-1] * 2   # bf16
        replicate = replicate_small_banks and bank_bytes <= 2.5e8
        if name == "w_down":                     # (…, E, F, D)
            if ep:
                return P(*([None] * (nd - 3)), ep, None,
                         _ok(shape[-1], mesh, fsdp_axis))
            if replicate:                        # small bank + EP-local
                return P(*([None] * nd))         # dispatch: replicate
            return P(*([None] * (nd - 3)), None,
                     _ok(shape[-2], mesh, tp_axis),
                     _ok(shape[-1], mesh, fsdp_axis))
        # (…, E, D, F)
        if ep:
            return P(*([None] * (nd - 3)), ep,
                     _ok(shape[-2], mesh, fsdp_axis), None)
        if replicate:
            return P(*([None] * nd))
        return P(*([None] * (nd - 3)), None,
                 _ok(shape[-2], mesh, fsdp_axis),
                 _ok(shape[-1], mesh, tp_axis))
    if name in _VOCAB:
        if name == "embed":                      # (V, D)
            return P(_ok(shape[0], mesh, tp_axis), None)
        return P(_ok(shape[-2], mesh, fsdp_axis),
                 _ok(shape[-1], mesh, tp_axis))  # lm_head (D, V)
    if nd >= 2 and shape[-1] > 1 and shape[-2] > 1:
        lead = [None] * (nd - 2)
        if name in _BACK_PROJ:
            return P(*lead, _ok(shape[-2], mesh, tp_axis),
                     _ok(shape[-1], mesh, fsdp_axis))
        return P(*lead, _ok(shape[-2], mesh, fsdp_axis),
                 _ok(shape[-1], mesh, tp_axis))
    return P(*([None] * nd))                     # vectors / scalars


def param_specs(cfg: ModelConfig, shapes: PyTree, mesh,
                fsdp: bool = True,
                replicate_small_banks: bool = False) -> PyTree:
    """PartitionSpec tree matching a param (or ShapeDtypeStruct) tree.

    ``fsdp=False`` (serving mode): drop the 'data' axis from weights —
    pure TP, no per-layer weight all-gathers at decode.
    ``replicate_small_banks``: with EP-local MoE dispatch (moe_mode=ep),
    sub-256 MB expert banks replicate per device (zero MoE collectives);
    under global dispatch they stay TP-sharded."""
    def leaf(path, x):
        spec = _leaf_spec(path, x, cfg, mesh,
                          replicate_small_banks=replicate_small_banks)
        if fsdp:
            return spec
        return P(*[None if a == "data" else a for a in spec])

    return _map_with_path(leaf, shapes)


def _map_with_path(fn, tree: PyTree) -> PyTree:
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            return type(node)(*(walk(path + (f,), v)
                                for f, v in zip(node._fields, node)))
        if isinstance(node, (list, tuple)):
            return type(node)(walk(path + (str(i),), v)
                              for i, v in enumerate(node))
        return fn(path, node)
    return walk((), tree)


def batch_specs(batch_shapes: PyTree, mesh, multi_pod: bool) -> PyTree:
    b = ("pod", "data") if multi_pod else "data"

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if leaf.shape[0] % np.prod([mesh.shape[a] for a in
                                    (b if isinstance(b, tuple) else (b,))]
                                   ) != 0:
            return P(*([None] * nd))
        return P(b, *([None] * (nd - 1)))

    return _map_with_path(spec, batch_shapes)


def cache_specs(cache_shapes: PyTree, mesh, multi_pod: bool) -> PyTree:
    """Decode caches: stacked (L, B, S, …) — shard batch over all DP axes
    (and the model axis too when it divides: decode batches are the only
    tensors big enough to need 256-way sharding)."""
    axes = (["pod"] if multi_pod else []) + ["data", "model"]

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd < 2:
            return P(*([None] * nd))
        B = leaf.shape[1] if nd >= 3 else leaf.shape[0]
        bdim = 1 if nd >= 3 else 0
        use = []
        rem = B
        for a in axes:
            if rem % mesh.shape[a] == 0:
                use.append(a)
                rem //= mesh.shape[a]
        out = [None] * nd
        if use:
            out[bdim] = tuple(use) if len(use) > 1 else use[0]
        # Long-context/small-batch caches: put unused axes on the widest
        # trailing dim that divides (TP over kv-channels / heads).
        unused = [a for a in axes if a not in use]
        for a in unused:
            for dim in range(nd - 1, bdim, -1):
                if out[dim] is None and dim != bdim \
                        and leaf.shape[dim] % mesh.shape[a] == 0 \
                        and leaf.shape[dim] >= mesh.shape[a]:
                    out[dim] = a
                    break
        return P(*out)

    return _map_with_path(spec, cache_shapes)


def opt_state_specs(opt_shapes: PyTree, pspecs: PyTree, mesh) -> PyTree:
    """Optimizer state sharding: moments inherit their parameter's spec;
    flattened 8-bit moments shard over (data, model); scalars replicate."""
    flat_specs = {tuple(p): s for p, s in _flatten(pspecs)}

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        # 8-bit moments are shape-preserving: codes inherit the param's
        # spec; block scales inherit it with the last dim unsharded.
        mpath = path[:-1] if path and path[-1] in ("codes", "scales") \
            else path
        for plen in range(len(mpath), 0, -1):
            cand = tuple(mpath[-plen:])
            if cand in flat_specs:
                s = flat_specs[cand]
                if len(s) == nd:
                    if path[-1] == "scales":
                        return P(*s[:-1], None)
                    if path[-1] == "codes":
                        # padded last dim may break divisibility
                        last = s[-1]
                        if last is not None and leaf.shape[-1] % \
                                mesh.shape[last] != 0:
                            last = None
                        return P(*s[:-1], last)
                    return s
        if nd == 1 and leaf.shape[0] % (mesh.shape["data"]
                                        * mesh.shape["model"]) == 0:
            return P(("data", "model"))
        return P(*([None] * nd))

    return _map_with_path(spec, opt_shapes)


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, path + (k,))
    elif isinstance(tree, (list, tuple)) and not isinstance(tree, P):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree
