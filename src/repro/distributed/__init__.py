"""Distribution: mesh axes, per-parameter PartitionSpecs, activation
sharding context, collective helpers."""
from repro.distributed.context import (ShardingContext, sharding_scope,
                                       current_context, act_constraint)
from repro.distributed.sharding import (param_specs, batch_specs,
                                        opt_state_specs, cache_specs)

__all__ = ["ShardingContext", "sharding_scope", "current_context",
           "act_constraint", "param_specs", "batch_specs",
           "opt_state_specs", "cache_specs"]
