"""Activation-sharding context.

Model code is mesh-agnostic; the launcher installs a ShardingContext and
the model calls ``act_constraint(x, kind)`` at a few strategic points
(post-embedding, residual stream, attention output). Without a context
(unit tests, single-device smoke runs) the helpers are no-ops, so the
same model code runs everywhere.

Kinds:
  "btd"  — (batch, seq, d_model) residual stream. Batch over the DP axes;
           seq over the TP axis when sequence parallelism is enabled
           (what lets 61-layer × 1M-token remat fit HBM).
  "bt"   — (batch, seq) token arrays.
  "btv"  — logits: batch over DP, vocab over TP.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingContext:
    mesh: jax.sharding.Mesh
    batch_axes: tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    sequence_parallel: bool = True
    # Attention context exchange: "gather" lets GSPMD all-gather K/V per
    # chunk (P× the tensor volume); "ulysses" reshards seq→heads with
    # all-to-alls (1× volume) around the attention op. §Perf iteration 2.
    attn_mode: str = "gather"
    # MoE dispatch: "global" sort-based capacity dispatch (GSPMD resolves
    # the data-dependent gathers — collective-catastrophic at deepseek
    # scale); "ep" shard_map expert parallelism with explicit all-to-all
    # (k·D bytes/token, the physical minimum). §Perf iteration 5.
    moe_mode: str = "global"

    def spec(self, kind: str) -> P:
        b = self.batch_axes if len(self.batch_axes) > 1 \
            else self.batch_axes[0]
        if kind == "btd":
            seq = self.model_axis if self.sequence_parallel else None
            return P(b, seq, None)
        if kind == "bt":
            return P(b, None)
        if kind == "btv":
            return P(b, None, self.model_axis)
        if kind == "bshd":       # ulysses: heads sharded, seq gathered
            return P(b, None, self.model_axis, None)
        if kind == "bshd_full":  # K/V explicitly gathered while still
            return P(b, None, None, None)   # bf16 (anchors the all-gather
            # before any f32 convert the backend might hoist)
        if kind == "bsh":        # (B, S, heads): heads over model (SSM dt)
            return P(b, None, self.model_axis)
        if kind == "bshd_seq":   # (B, S, H, d) with seq kept sharded —
            seq = self.model_axis if self.sequence_parallel else None
            return P(b, seq, None, None)    # anchor before an a2a reshard
        if kind == "bs__":       # (B, S, groups, state): seq gathered,
            return P(b, None, None, None)   # small B/C tensors replicated
        raise ValueError(kind)


_tls = threading.local()


def current_context() -> Optional[ShardingContext]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def sharding_scope(ctx: ShardingContext):
    prev = current_context()
    _tls.ctx = ctx
    try:
        yield
    finally:
        _tls.ctx = prev


def act_constraint(x: jax.Array, kind: str) -> jax.Array:
    ctx = current_context()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.spec(kind)))


def ulysses_enabled(n_heads: int) -> bool:
    """True when the context requests all-to-all attention and the head
    count divides the model axis."""
    ctx = current_context()
    if ctx is None or ctx.attn_mode != "ulysses":
        return False
    return n_heads % ctx.mesh.shape[ctx.model_axis] == 0
