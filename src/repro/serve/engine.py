"""Batched decode engine with slot-based continuous batching.

A fixed pool of B slots shares one cache allocation. Requests occupy free
slots; each engine step decodes one token for every active slot; finished
sequences (EOS or max_len) free their slot for the next queued request.
This is the slot/page-lite serving pattern (vLLM-style without paging —
the cache is contiguous per slot, sized to max_len).

The decode step is a single jit'd function (params, caches, tokens, pos)
so the same compiled executable serves every batch composition.

The quantized execution substrate resolves through ``repro.backends``:
``ServeConfig.device`` (any registered backend name) overrides the
model's ``quant_mode``, and either way the engine holds the shared
per-name inference backend instance — validated at construction, metering
decode activity on its telemetry when ``ServeConfig.meter`` is set.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import DeviceBackend, inference_backend
from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256
    eos_token: int = 0
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # Device substrate for the quantized projections: a repro.backends
    # registry *name*. None keeps the model config's quant_mode. The
    # model layers resolve one shared inference instance per name, so a
    # pre-built DeviceBackend instance cannot be honored here — register
    # a configured backend under its own name instead (engine raises on
    # instances rather than silently substituting the default spec).
    device: Union[str, DeviceBackend, None] = None
    # Enable telemetry on the substrate. Counters accumulate on the
    # process-wide shared inference instance for this name: engines
    # serving the same backend name share one accumulator (and once any
    # engine enables it, later-compiled steps on that name meter too).
    # Use distinct registered names for isolated metering.
    meter: bool = False
    # A repro.obs.Tracer: the engine opens a span per engine step (and
    # per prefill) so the serve loop shows up in trace.json next to the
    # training runners' compile/execute spans. None is free.
    tracer: Optional[Any] = None
    # Injectable wall clock (seconds). Every latency-relevant timestamp
    # (t_submit / t_admit / t_done) and all three histograms read only
    # this — tests script it and assert exact percentiles.
    clock: Callable[[], float] = time.perf_counter
    # Per-request queue deadline (seconds, on the same clock): a queued
    # request whose age exceeds this at admission is dropped with
    # ``timed_out=True`` instead of decoded. None = wait forever. The
    # check reads the clock once per admission pass and only when a
    # deadline is set, so deadline-free runs keep their exact
    # clock-read sequence.
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Dropped at admission: queue wait exceeded ServeConfig.deadline_s
    # (set together with ``done``; the request never decoded a token).
    timed_out: bool = False
    # Observability: submit/admit/finish wall-clock (per ServeConfig's
    # injectable clock) and the number of decode dispatches this request
    # consumed (prefill + generated tokens) — the per-request share of
    # the metered energy.
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0
    steps: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig,
                 params: Any):
        # Resolve the execution substrate through the backend registry —
        # unknown names fail here, at engine construction, not mid-decode.
        if scfg.device is not None:
            if isinstance(scfg.device, DeviceBackend):
                raise TypeError(
                    "ServeConfig.device takes a registry name, not a "
                    "DeviceBackend instance: the model layers resolve a "
                    "shared per-name inference instance, so a pre-built "
                    "instance's spec would be silently ignored. Register "
                    "your configured backend (register_backend) and pass "
                    "its name.")
            name = scfg.device
            self.backend: Optional[DeviceBackend] = inference_backend(name)
            cfg = dataclasses.replace(cfg, quant_mode=name)
        elif cfg.quant_mode != "none":
            self.backend = inference_backend(cfg.quant_mode)
        else:
            self.backend = None
        if scfg.meter:
            if self.backend is None:
                raise ValueError("ServeConfig.meter requires a quantized "
                                 "substrate (device= or quant_mode)")
            self.backend.telemetry.enable()
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.caches = lm.init_cache(cfg, scfg.batch_slots, scfg.max_len)
        self.slot_req: list[Optional[Request]] = \
            [None] * scfg.batch_slots
        self.slot_pos = np.zeros(scfg.batch_slots, dtype=np.int64)
        self.queue: deque[Request] = deque()
        self._rng = jax.random.PRNGKey(scfg.seed)

        cfg_ = cfg
        backend_ = self.backend

        def step_fn(params, caches, tokens, pos):
            logits, caches = lm.decode_step(params, cfg_, caches, tokens,
                                            pos)
            if backend_ is not None:
                backend_.telemetry.emit_pending()
            return logits[:, -1, :], caches

        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self.steps_run = 0
        self.timed_out = 0
        # Per-request observability (repro.obs): end-to-end latency
        # (submit → done), its queue-wait (submit → admit) / decode
        # (admit → done) split, all in ms, and the finished requests'
        # decode-step shares for pJ/request attribution.
        from repro.obs import Histogram
        self.latency = Histogram()
        self.queue_wait = Histogram()
        self.decode = Histogram()
        self._finished: list[Request] = []
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    @property
    def telemetry(self):
        """The substrate's activity accumulator (None when unquantized)."""
        return self.backend.telemetry if self.backend is not None else None

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 32) -> Request:
        req = Request(rid=len(self.queue) + 1000 * self.steps_run,
                      prompt=list(prompt), max_new=max_new)
        req.t_submit = self.scfg.clock()
        if self._t_first_submit is None:
            self._t_first_submit = req.t_submit
        self.queue.append(req)
        return req

    def _tracer_span(self, name: str, **args):
        tracer = self.scfg.tracer
        return tracer.span(name, **args) if tracer is not None \
            else contextlib.nullcontext()

    def _finish(self, req: Request) -> None:
        req.done = True
        req.t_done = self.scfg.clock()
        self._t_last_done = req.t_done
        self.latency.add((req.t_done - req.t_submit) * 1e3)
        self.decode.add((req.t_done - req.t_admit) * 1e3)
        self._finished.append(req)

    def _admit(self) -> None:
        if self.scfg.deadline_s is not None and self.queue:
            now = self.scfg.clock()
            kept: deque[Request] = deque()
            while self.queue:
                req = self.queue.popleft()
                if now - req.t_submit > self.scfg.deadline_s:
                    req.timed_out = True
                    req.done = True
                    req.t_done = now
                    self.timed_out += 1
                else:
                    kept.append(req)
            self.queue = kept
        for slot in range(self.scfg.batch_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                req.t_admit = self.scfg.clock()
                self.queue_wait.add((req.t_admit - req.t_submit) * 1e3)
                # Prefill the prompt token-by-token through the decode
                # path (single compiled executable; a production engine
                # adds a chunked-prefill fast path).
                with self._tracer_span("serve.prefill", rid=req.rid,
                                       prompt_len=len(req.prompt)):
                    for t in req.prompt[:-1]:
                        self._advance_slot(slot, t, sample=False)
                req.tokens = []
                req.pending_token = req.prompt[-1]

    def _advance_slot(self, slot: int, token: int, sample: bool) -> int:
        toks = np.zeros((self.scfg.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        pos = jnp.int32(int(self.slot_pos[slot]))
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(toks), pos)
        self.slot_pos[slot] += 1
        req = self.slot_req[slot]
        if req is not None:
            req.steps += 1
        if not sample:
            return -1
        return self._pick(logits[slot])

    def _pick(self, logits: jax.Array) -> int:
        if self.scfg.greedy:
            return int(jnp.argmax(logits))
        self._rng, sub = jax.random.split(self._rng)
        return int(jax.random.categorical(
            sub, logits / self.scfg.temperature))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Advance every active slot one token. Returns #active slots."""
        with self._tracer_span("serve.step", step=self.steps_run):
            return self._step_inner()

    def _step_inner(self) -> int:
        self._admit()
        active = [s for s in range(self.scfg.batch_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        # One batched decode for all active slots (idle slots get pad).
        toks = np.zeros((self.scfg.batch_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            toks[s, 0] = req.pending_token if not req.tokens \
                else req.tokens[-1]
        # All slots in the dry-run share pos; per-slot pos differs here,
        # so step slots grouped by position.
        by_pos: dict[int, list[int]] = {}
        for s in active:
            by_pos.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos_val, slots in by_pos.items():
            t = np.zeros((self.scfg.batch_slots, 1), np.int32)
            for s in slots:
                t[s, 0] = toks[s, 0]
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(t),
                jnp.int32(pos_val))
            for s in slots:
                req = self.slot_req[s]
                nxt = self._pick(logits[s])
                req.tokens.append(nxt)
                req.steps += 1
                self.slot_pos[s] += 1
                if (nxt == self.scfg.eos_token
                        or len(req.tokens) >= req.max_new
                        or self.slot_pos[s] >= self.scfg.max_len - 1):
                    self._finish(req)
                    self.slot_req[s] = None
        self.steps_run += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return

    # ------------------------------------------------------------------
    def request_stats(self, model: Optional[Any] = None) -> dict:
        """Per-request serving figures over the finished requests.

          requests         completed count
          latency_ms       end-to-end (submit → done) p50/p95/p99/mean
          sequences_per_s  completed / (last done − first submit)
          tokens_per_s     generated tokens over the same window

        On a metered substrate, adds ``energy``: the run's metered
        joules and a pJ/request distribution — each finished request is
        charged its share of the total by decode-dispatch count
        (prefill + generated tokens), the allocation unit the batched
        engine actually dispatches. ``model`` picks the energy model:
        None defaults to a transformer-shape
        :class:`repro.analog.costmodel.DenseCostModel` of the served
        architecture (adding metered power and GOPS/W); an
        :class:`~repro.analog.costmodel.M2RUCostModel` charges the M2RU
        chip geometry (falling back to per-op energy where the LM
        workload's tags don't map onto it).
        """
        out: dict[str, Any] = {
            "requests": len(self._finished),
            "timed_out": self.timed_out,
            "steps_run": self.steps_run,
            "latency_ms": self.latency.summary(),
            "queue_wait_ms": self.queue_wait.summary(),
            "decode_ms": self.decode.summary(),
        }
        if self._finished and self._t_last_done is not None:
            span = self._t_last_done - self._t_first_submit
            n_tok = sum(len(r.tokens) for r in self._finished)
            out["sequences_per_s"] = len(self._finished) / span \
                if span > 0 else float("inf")
            out["tokens_per_s"] = n_tok / span if span > 0 \
                else float("inf")
            out["tokens_generated"] = n_tok
        tele = self.telemetry
        if tele is not None and tele.enabled and self._finished:
            from repro.analog.costmodel import DenseCostModel
            from repro.obs import Histogram
            from repro.telemetry.energy import MeteredEnergy
            kind = "cmos" if self.cfg.quant_mode == "cmos" else "analog"
            en = MeteredEnergy() if model is None else MeteredEnergy(model)
            counters = tele.snapshot()
            extra: dict[str, Any] = {}
            if model is None or isinstance(model, DenseCostModel):
                # Transformer-shape energy model: the metered dense-tag
                # activity through the served architecture's crossbar-
                # mapped projection stack — this is where the model-zoo
                # serving GOPS/W figure comes from.
                dm = model if model is not None \
                    else DenseCostModel.from_model_config(self.cfg)
                rep = en.dense_report(counters, dm)
                total_j = rep.energy_j
                extra = {"power_mw": rep.power_w * 1e3,
                         "gops_per_w": rep.gops_per_w,
                         "pj_per_op": rep.pj_per_op}
            else:
                try:
                    total_j = en.report(counters, kind=kind).energy_j
                except ValueError:
                    # The workload's meter tags don't map onto the M2RU
                    # chip-geometry cycle model (e.g. LM decode): charge
                    # the metered ops at the model's per-op energy.
                    pj_op = model.digital_pj_per_op() if kind == "cmos" \
                        else model.pj_per_op()
                    total_j = en.ops(counters) * pj_op * 1e-12
            total_steps = sum(r.steps for r in self._finished)
            if total_j > 0 and total_steps > 0:
                pj = Histogram()
                for r in self._finished:
                    pj.add(total_j * r.steps / total_steps * 1e12)
                out["energy"] = {"total_j": total_j,
                                 "pj_per_request": pj.summary(), **extra}
        return out
