"""Batched decode engine with slot-based continuous batching.

A fixed pool of B slots shares one cache allocation. Requests occupy free
slots; each engine step decodes one token for every active slot; finished
sequences (EOS or max_len) free their slot for the next queued request.
This is the slot/page-lite serving pattern (vLLM-style without paging —
the cache is contiguous per slot, sized to max_len).

The decode step is a single jit'd function (params, caches, tokens, pos)
so the same compiled executable serves every batch composition.

The quantized execution substrate resolves through ``repro.backends``:
``ServeConfig.device`` (any registered backend name) overrides the
model's ``quant_mode``, and either way the engine holds the shared
per-name inference backend instance — validated at construction, metering
decode activity on its telemetry when ``ServeConfig.meter`` is set.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import DeviceBackend, inference_backend
from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256
    eos_token: int = 0
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # Device substrate for the quantized projections: a repro.backends
    # registry *name*. None keeps the model config's quant_mode. The
    # model layers resolve one shared inference instance per name, so a
    # pre-built DeviceBackend instance cannot be honored here — register
    # a configured backend under its own name instead (engine raises on
    # instances rather than silently substituting the default spec).
    device: Union[str, DeviceBackend, None] = None
    # Enable telemetry on the substrate. Counters accumulate on the
    # process-wide shared inference instance for this name: engines
    # serving the same backend name share one accumulator (and once any
    # engine enables it, later-compiled steps on that name meter too).
    # Use distinct registered names for isolated metering.
    meter: bool = False


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig,
                 params: Any):
        # Resolve the execution substrate through the backend registry —
        # unknown names fail here, at engine construction, not mid-decode.
        if scfg.device is not None:
            if isinstance(scfg.device, DeviceBackend):
                raise TypeError(
                    "ServeConfig.device takes a registry name, not a "
                    "DeviceBackend instance: the model layers resolve a "
                    "shared per-name inference instance, so a pre-built "
                    "instance's spec would be silently ignored. Register "
                    "your configured backend (register_backend) and pass "
                    "its name.")
            name = scfg.device
            self.backend: Optional[DeviceBackend] = inference_backend(name)
            cfg = dataclasses.replace(cfg, quant_mode=name)
        elif cfg.quant_mode != "none":
            self.backend = inference_backend(cfg.quant_mode)
        else:
            self.backend = None
        if scfg.meter:
            if self.backend is None:
                raise ValueError("ServeConfig.meter requires a quantized "
                                 "substrate (device= or quant_mode)")
            self.backend.telemetry.enable()
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.caches = lm.init_cache(cfg, scfg.batch_slots, scfg.max_len)
        self.slot_req: list[Optional[Request]] = \
            [None] * scfg.batch_slots
        self.slot_pos = np.zeros(scfg.batch_slots, dtype=np.int64)
        self.queue: deque[Request] = deque()
        self._rng = jax.random.PRNGKey(scfg.seed)

        cfg_ = cfg
        backend_ = self.backend

        def step_fn(params, caches, tokens, pos):
            logits, caches = lm.decode_step(params, cfg_, caches, tokens,
                                            pos)
            if backend_ is not None:
                backend_.telemetry.emit_pending()
            return logits[:, -1, :], caches

        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self.steps_run = 0

    @property
    def telemetry(self):
        """The substrate's activity accumulator (None when unquantized)."""
        return self.backend.telemetry if self.backend is not None else None

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 32) -> Request:
        req = Request(rid=len(self.queue) + 1000 * self.steps_run,
                      prompt=list(prompt), max_new=max_new)
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.scfg.batch_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                # Prefill the prompt token-by-token through the decode
                # path (single compiled executable; a production engine
                # adds a chunked-prefill fast path).
                for t in req.prompt[:-1]:
                    self._advance_slot(slot, t, sample=False)
                req.tokens = []
                req.pending_token = req.prompt[-1]

    def _advance_slot(self, slot: int, token: int, sample: bool) -> int:
        toks = np.zeros((self.scfg.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        pos = jnp.int32(int(self.slot_pos[slot]))
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(toks), pos)
        self.slot_pos[slot] += 1
        if not sample:
            return -1
        return self._pick(logits[slot])

    def _pick(self, logits: jax.Array) -> int:
        if self.scfg.greedy:
            return int(jnp.argmax(logits))
        self._rng, sub = jax.random.split(self._rng)
        return int(jax.random.categorical(
            sub, logits / self.scfg.temperature))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Advance every active slot one token. Returns #active slots."""
        self._admit()
        active = [s for s in range(self.scfg.batch_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        # One batched decode for all active slots (idle slots get pad).
        toks = np.zeros((self.scfg.batch_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            toks[s, 0] = req.pending_token if not req.tokens \
                else req.tokens[-1]
        # All slots in the dry-run share pos; per-slot pos differs here,
        # so step slots grouped by position.
        by_pos: dict[int, list[int]] = {}
        for s in active:
            by_pos.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos_val, slots in by_pos.items():
            t = np.zeros((self.scfg.batch_slots, 1), np.int32)
            for s in slots:
                t[s, 0] = toks[s, 0]
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(t),
                jnp.int32(pos_val))
            for s in slots:
                req = self.slot_req[s]
                nxt = self._pick(logits[s])
                req.tokens.append(nxt)
                self.slot_pos[s] += 1
                if (nxt == self.scfg.eos_token
                        or len(req.tokens) >= req.max_new
                        or self.slot_pos[s] >= self.scfg.max_len - 1):
                    req.done = True
                    self.slot_req[s] = None
        self.steps_run += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
