"""Serving: batched LM decode + continuous-batching recurrent streams.

Two engines share the slot/continuous-batching pattern:

  * :class:`ServeEngine` — token-by-token LM decode over a KV-cache
    slab (the model-zoo serving path).
  * :class:`RecurrentServeEngine` — stateful MiRU streams over a
    :class:`StateSlab` of per-user hidden vectors with LRU host spill,
    driven by the deterministic traffic in :mod:`repro.serve.loadgen`.

See ``docs/serving.md``.
"""
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.loadgen import (Arrival, TrafficSpec, make_arrivals,
                                 replay, request_frames)
from repro.serve.recurrent import (RecurrentServeConfig,
                                   RecurrentServeEngine, StreamRequest,
                                   serve_backend)
from repro.serve.slab import SlabFullError, StateSlab

__all__ = [
    "ServeEngine", "ServeConfig",
    "RecurrentServeEngine", "RecurrentServeConfig", "StreamRequest",
    "serve_backend",
    "StateSlab", "SlabFullError",
    "TrafficSpec", "Arrival", "make_arrivals", "request_frames", "replay",
]
