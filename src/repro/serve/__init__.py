"""Serving: batched decode engine."""
from repro.serve.engine import ServeEngine, ServeConfig

__all__ = ["ServeEngine", "ServeConfig"]
