"""Continuous-batching serve engine for recurrent (MiRU) streams.

The paper's deployment shape (ROADMAP item 2): always-on temporal
intelligence serving many short, bursty, *stateful* user streams. For a
recurrent model the per-user serving cache is not a growing KV history —
it is one fixed-size hidden vector, so:

  * state lives in a :class:`~repro.serve.slab.StateSlab` — a single
    (batch_slots, n_h) device array; users beyond the slab LRU-spill to
    host and reload bit-identically on their next burst;
  * every engine step advances *all* scheduled streams together through
    one compiled step: the backend's ``device_recurrence`` hook (the
    PR-4 fused WBS×MiRU kernel where the substrate supports it) resumed
    from the slab via ``h0``, followed by the per-frame readout;
  * unlike attention serving there is no position coupling — any set of
    streams co-batches at any offsets, and because every lane of the
    batch is computed row-independently, a request's output stream is
    **bitwise identical** regardless of which requests ride along or
    which slot it lands in (the determinism contract; gated in
    benchmarks/serve_bench.py, see docs/serving.md);
  * admission control: a bounded request queue (``max_queue``) with
    per-user FIFO ordering — concurrent bursts from one user serialize,
    different users may overtake a busy user's queued burst;
  * host↔device pipelining: the engine dispatches step k+1 while step
    k's logits are still on device, so host-side gather/scatter and
    bookkeeping overlap the compiled step (``pipeline=False`` forces
    synchronous dispatch — used by the latency-attribution tests).

Wall-clock reads go through an injectable ``clock`` so the latency
histograms (queue-wait / decode / end-to-end) are testable against
hand-computed values under a scripted clock.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable, Hashable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import DeviceBackend, get_backend
from repro.core.continual import _meter_chip_step
from repro.core.miru import MiRUConfig, miru_apply_readout
from repro.serve.slab import StateSlab
from repro.telemetry.meters import SEQUENCES

__all__ = ["RecurrentServeConfig", "RecurrentServeEngine", "StreamRequest",
           "serve_backend"]


@functools.lru_cache(maxsize=None)
def serve_backend(name: str) -> DeviceBackend:
    """Shared per-name backend instance for recurrent serving.

    Unlike :func:`repro.backends.inference_backend` (which strips the
    readout ADC for the LM layers), serving a MiRU stream uses the
    substrate's *native* spec so served steps run the same fixed-point
    path — and the same fused kernel — as the training forward.

    Sharing one instance per name means two engines serving the same
    backend name share one telemetry accumulator (documented behavior,
    pinned in tests/test_serve_recurrent.py);
    ``RecurrentServeConfig.fresh_meter`` is the per-run isolation escape
    hatch.
    """
    return get_backend(name)


@dataclasses.dataclass
class RecurrentServeConfig:
    #: Slab slots == compiled batch width. Users beyond this spill.
    batch_slots: int = 8
    #: Frames consumed per stream per engine step (the decode "chunk").
    #: Chunking is bitwise-invariant: the recurrence is causal, so any
    #: chunk split produces the same stream (asserted in tests).
    chunk: int = 8
    #: Admission control: queued requests beyond this are rejected at
    #: submit (``StreamRequest.rejected``). None = unbounded.
    max_queue: Optional[int] = None
    #: Substrate: a repro.backends registry name (resolved through the
    #: shared per-name :func:`serve_backend` instance) or a pre-built
    #: DeviceBackend (the caller owns its telemetry isolation).
    device: Union[str, DeviceBackend] = "wbs"
    #: Enable telemetry on the substrate (before the step is traced).
    meter: bool = False
    #: Give this engine a private backend instance instead of the shared
    #: per-name one, so its metered counters — and the pJ/request derived
    #: from them — are not polluted by other engines in-process (the
    #: serve bench runs every measurement with ``fresh_meter=True``).
    #: Only meaningful when ``device`` is a registry name.
    fresh_meter: bool = False
    #: None defers to the backend's fused_recurrence flag (fused where
    #: supported); False forces the per-step device_vmm scan.
    fused: Optional[bool] = None
    #: Dispatch depth-1 ahead of retirement (host/device overlap).
    pipeline: bool = True
    #: Per-request queue deadline (seconds, on the injectable clock):
    #: a request whose queue age exceeds this at admission time is
    #: dropped with ``timed_out=True`` instead of served (counted in
    #: ``request_stats()["timed_out"]``). None = wait forever. The
    #: deadline check reads the clock once per admission pass, and only
    #: when a deadline is set — deadline-free configs see the exact
    #: clock-read sequence they always did.
    deadline_s: Optional[float] = None
    #: Fault injection (repro.faults): 0-based dispatch-attempt indices
    #: at which the serving chip "fails" mid-step. The dispatch aborts
    #: before any RNG is consumed, the slab's rows migrate to a
    #: replacement chip through the host-spill path, and the affected
    #: streams retry from their pre-dispatch cursors — so the output
    #: streams stay bitwise identical to a failure-free run (gated in
    #: benchmarks/fault_bench.py).
    fail_at_steps: tuple = ()
    seed: int = 0
    #: Injectable wall clock (seconds). Latency/queue-wait/decode
    #: histograms read only this — tests drive it with a script.
    clock: Callable[[], float] = time.perf_counter
    #: Optional repro.obs.Tracer: a span per engine step.
    tracer: Optional[Any] = None


@dataclasses.dataclass
class StreamRequest:
    """One burst of frames from one user session."""
    rid: int
    uid: Hashable
    frames: np.ndarray              # (T, n_x) float32
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0
    cursor: int = 0                 # frames consumed so far
    emitted: int = 0                # frames whose logits materialized
    done: bool = False
    rejected: bool = False
    #: Dropped at admission because queue wait exceeded ``deadline_s``
    #: (set together with ``done``; the request was never served).
    timed_out: bool = False
    _logits: Optional[np.ndarray] = None

    @property
    def n_frames(self) -> int:
        return int(self.frames.shape[0])

    @property
    def steps(self) -> int:
        """Decode dispatches consumed — the pJ/request allocation unit."""
        return self.emitted

    @property
    def logits(self) -> np.ndarray:
        """(T, n_y) per-frame readout logits (filled as frames retire)."""
        assert self._logits is not None, "no frames served yet"
        return self._logits

    @property
    def predictions(self) -> np.ndarray:
        """(T,) per-frame argmax class stream."""
        return np.argmax(self.logits, axis=-1)


class RecurrentServeEngine:
    """Continuous batching of recurrent state over a device slab."""

    def __init__(self, cfg: MiRUConfig, scfg: RecurrentServeConfig,
                 params: dict):
        if isinstance(scfg.device, DeviceBackend):
            self.backend = scfg.device
        elif scfg.fresh_meter:
            self.backend = get_backend(scfg.device)
        else:
            self.backend = serve_backend(scfg.device)
        if scfg.meter:
            self.backend.telemetry.enable()
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.slab = StateSlab(scfg.batch_slots, cfg.n_h, cfg.dtype)
        self._waiting: deque[StreamRequest] = deque()
        self._active: dict[Hashable, StreamRequest] = {}   # uid → request
        self._inflight: deque[tuple[jax.Array, list]] = deque()
        self._rng = jax.random.PRNGKey(scfg.seed)
        self._next_rid = 0
        self._anon = 0
        self.steps_run = 0
        self.rejected = 0
        self.timed_out = 0
        self.chip_failures = 0
        self.retried = 0
        self._dispatch_attempts = 0
        self._step = self._make_step()

        from repro.obs import Histogram
        self.latency = Histogram()       # submit → done, ms
        self.queue_wait = Histogram()    # submit → admit, ms
        self.decode = Histogram()        # admit → done, ms
        self._finished: list[StreamRequest] = []
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # ------------------------------------------------------------------
    @property
    def telemetry(self):
        return self.backend.telemetry

    def _make_step(self):
        backend, rcfg, scfg = self.backend, self.cfg, self.scfg

        def step_fn(params, h_slab, x_chunk, n_steps, key):
            S, C, _ = x_chunk.shape
            h_all, _, _ = backend.device_recurrence(
                params, rcfg, x_chunk, key, fused=scfg.fused, h0=h_slab)
            # State writeback: slot i advances by its own n_steps[i]
            # frames; idle lanes (n_steps == 0) keep their state bit-
            # exactly. The recurrence is causal, so h_all[i, c-1] equals
            # a c-step solo run regardless of the chunk width.
            idx = jnp.maximum(n_steps - 1, 0).astype(jnp.int32)
            h_sel = h_all[jnp.arange(S), idx]
            h_new = jnp.where((n_steps > 0)[:, None], h_sel, h_slab)
            # Per-frame readout (eq. 3) — digital, like the training
            # forward; the streamed readout-crossbar activity is metered
            # per chip step below.
            logits = miru_apply_readout(params, rcfg,
                                        h_all.reshape(S * C, rcfg.n_h))
            tele = backend.telemetry
            with tele.scaled(C):
                _meter_chip_step(backend, rcfg, S, anchor=x_chunk)
            tele.emit_pending()
            return h_new, logits.reshape(S, C, -1)

        return jax.jit(step_fn, donate_argnums=(1,))

    def _span(self, name: str, **args):
        tracer = self.scfg.tracer
        return tracer.span(name, **args) if tracer is not None \
            else contextlib.nullcontext()

    # ------------------------------------------------------------------
    # Submission / admission
    # ------------------------------------------------------------------
    def submit(self, frames: np.ndarray,
               uid: Optional[Hashable] = None) -> StreamRequest:
        """Queue one burst. ``uid`` names the user session whose slab
        state the burst continues; None serves it as a fresh anonymous
        session. Rejected requests (queue full) return immediately with
        ``rejected=True`` and never consume a slot."""
        frames = np.asarray(frames, np.float32)
        if frames.ndim != 2 or frames.shape[0] < 1 \
                or frames.shape[1] != self.cfg.n_x:
            raise ValueError(f"frames must be (T>=1, n_x={self.cfg.n_x}), "
                             f"got {frames.shape}")
        if uid is None:
            uid = f"_anon{self._anon}"
            self._anon += 1
        req = StreamRequest(rid=self._next_rid, uid=uid, frames=frames)
        self._next_rid += 1
        req.t_submit = self.scfg.clock()
        if self._t_first_submit is None:
            self._t_first_submit = req.t_submit
        if self.scfg.max_queue is not None \
                and len(self._waiting) >= self.scfg.max_queue:
            req.rejected = True
            self.rejected += 1
            return req
        req._logits = np.zeros((req.n_frames, self.cfg.n_y), np.float32)
        self._waiting.append(req)
        return req

    def end_session(self, uid: Hashable) -> None:
        """Drop a user's slab state (resident or spilled)."""
        if uid in self._active:
            raise ValueError(f"uid {uid!r} has an active stream")
        self.slab.release(uid)

    def _admit(self) -> None:
        """Move waiting requests into the slab. Per-user FIFO: a burst
        whose user is mid-stream stays queued (later users may overtake
        it); otherwise requests admit in submit order while a slot can
        be acquired without evicting a pinned stream."""
        now = self.scfg.clock() if self.scfg.deadline_s is not None \
            else None
        kept: deque[StreamRequest] = deque()
        while self._waiting:
            req = self._waiting.popleft()
            if now is not None \
                    and now - req.t_submit > self.scfg.deadline_s:
                req.timed_out = True
                req.done = True
                req.t_done = now
                self.timed_out += 1
                continue
            if req.uid in self._active:
                kept.append(req)
                continue
            if len(self._active) >= self.scfg.batch_slots \
                    or not self.slab.can_acquire(req.uid):
                kept.appendleft(req)
                # Everything behind a capacity-blocked head stays in
                # order; only user-busy requests were bypassed.
                kept.extend(self._waiting)
                self._waiting.clear()
                break
            self.slab.acquire(req.uid)
            self.slab.pin(req.uid)
            self._active[req.uid] = req
            req.t_admit = self.scfg.clock()
            self.queue_wait.add((req.t_admit - req.t_submit) * 1e3)
        self._waiting = kept

    # ------------------------------------------------------------------
    # The engine step
    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit, advance every scheduled stream by up to ``chunk``
        frames, retire materialized output. Returns the number of
        streams scheduled into this step's batch."""
        with self._span("serve.step", step=self.steps_run):
            return self._step_inner()

    def _step_inner(self) -> int:
        self._admit()
        S, C = self.scfg.batch_slots, self.scfg.chunk
        entries = []
        x = np.zeros((S, C, self.cfg.n_x), np.float32)
        n_steps = np.zeros((S,), np.int32)
        for uid, req in self._active.items():
            if req.cursor >= req.n_frames:
                continue                     # retiring via the pipeline
            slot = self.slab.slot(uid)
            c = min(C, req.n_frames - req.cursor)
            x[slot, :c] = req.frames[req.cursor:req.cursor + c]
            n_steps[slot] = c
            entries.append((req, slot, req.cursor, c))
            req.cursor += c
            self.slab.touch(uid)
        if entries:
            # Fault-injection point: the chip dies mid-step, before this
            # dispatch consumed any RNG — so the retry on the replacement
            # chip draws the exact key the lost dispatch would have, and
            # every output stream stays bitwise identical.
            attempt = self._dispatch_attempts
            self._dispatch_attempts += 1
            if attempt in self.scfg.fail_at_steps:
                self._chip_failure(entries)
                return len(entries)
            self._rng, sub = jax.random.split(self._rng)
            self.slab.h, logits = self._step(
                self.params, self.slab.h, jnp.asarray(x),
                jnp.asarray(n_steps), sub)
            self._inflight.append((logits, entries))
            self.steps_run += 1
        # Retire: with pipelining keep one dispatch in flight so the
        # host-side gather above overlapped the device step; without it
        # (or when nothing was dispatched) drain immediately.
        depth = 1 if (self.scfg.pipeline and entries) else 0
        while len(self._inflight) > depth:
            self._retire(*self._inflight.popleft())
        return len(entries)

    def _chip_failure(self, entries: list) -> None:
        """Recover from a simulated chip death mid-dispatch.

        The aborted streams roll back to their pre-dispatch cursors and
        retry; results already in flight were computed before the
        failure and retire normally. Every surviving state row —
        resident and spilled — migrates to a fresh slab (the
        replacement chip) through the host-spill path, whose reload is
        bit-exact; rows are lane-independent, so the new slot
        assignment leaves every stream's output unchanged.
        """
        for req, _slot, start, _c in entries:
            req.cursor = start
        self.chip_failures += 1
        self.retried += len(entries)
        self.flush()
        old = self.slab
        rows = {uid: old.read(uid)
                for uid in set(old.resident) | set(old.spilled)}
        self.slab = StateSlab(self.scfg.batch_slots, self.cfg.n_h,
                              self.cfg.dtype)
        for uid, row in rows.items():
            self.slab.preload(uid, row)
        for uid in self._active:
            self.slab.acquire(uid)
            self.slab.pin(uid)

    def _retire(self, logits: jax.Array, entries: list) -> None:
        arr = np.asarray(logits)             # blocks until step done
        for req, slot, start, c in entries:
            req._logits[start:start + c] = arr[slot, :c]
            req.emitted += c
            if req.emitted >= req.n_frames:
                self._finish(req)

    def _finish(self, req: StreamRequest) -> None:
        req.done = True
        req.t_done = self.scfg.clock()
        self._t_last_done = req.t_done
        self.latency.add((req.t_done - req.t_submit) * 1e3)
        self.decode.add((req.t_done - req.t_admit) * 1e3)
        self._finished.append(req)
        del self._active[req.uid]
        self.slab.unpin(req.uid)             # state stays resident (LRU)
        if self.telemetry.enabled:
            self.telemetry.record({SEQUENCES: 1})

    @property
    def pending(self) -> int:
        """Requests somewhere in the pipe: queued, active, or with
        output still in flight (0 = drained)."""
        return (len(self._waiting) + len(self._active)
                + sum(len(e) for _, e in self._inflight))

    def flush(self) -> None:
        """Materialize every in-flight dispatch."""
        while self._inflight:
            self._retire(*self._inflight.popleft())

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self._waiting \
                    and not self._inflight:
                return
        raise RuntimeError(f"not drained after {max_steps} engine steps")

    # ------------------------------------------------------------------
    def request_stats(self, model: Optional[Any] = None) -> dict:
        """Serving figures over the finished requests: end-to-end /
        queue-wait / decode latency percentiles, sequences/s, frames/s,
        slab spill counters — and, on a metered substrate, the metered
        power (mW) plus a pJ/request distribution (each request charged
        its frame share of the metered energy). ``model`` defaults to an
        :class:`~repro.analog.costmodel.M2RUCostModel` of this engine's
        network geometry."""
        out: dict[str, Any] = {
            "requests": len(self._finished),
            "rejected": self.rejected,
            "timed_out": self.timed_out,
            "steps_run": self.steps_run,
            "chip_failures": self.chip_failures,
            "retried": self.retried,
            "latency_ms": self.latency.summary(),
            "queue_wait_ms": self.queue_wait.summary(),
            "decode_ms": self.decode.summary(),
            "slab": self.slab.stats(),
        }
        if self._finished and self._t_last_done is not None:
            span = self._t_last_done - self._t_first_submit
            n_frames = sum(r.emitted for r in self._finished)
            out["sequences_per_s"] = len(self._finished) / span \
                if span > 0 else float("inf")
            out["frames_per_s"] = n_frames / span if span > 0 \
                else float("inf")
            out["frames_served"] = n_frames
        tele = self.telemetry
        if tele is not None and tele.enabled and self._finished:
            from repro.analog.costmodel import M2RUCostModel
            from repro.obs import Histogram
            from repro.telemetry.energy import MeteredEnergy
            if model is None:
                model = M2RUCostModel(n_x=self.cfg.n_x, n_h=self.cfg.n_h,
                                      n_y=self.cfg.n_y)
            kind = "cmos" if self.backend.name == "cmos" else "analog"
            rep = MeteredEnergy(model).report(tele.snapshot(), kind=kind)
            total_steps = sum(r.steps for r in self._finished)
            pj = Histogram()
            if rep.energy_j > 0 and total_steps > 0:
                for r in self._finished:
                    pj.add(rep.energy_j * r.steps / total_steps * 1e12)
            out["energy"] = {
                "total_j": rep.energy_j,
                "power_mw": rep.power_w * 1e3,
                "gops_per_w": rep.gops_per_w,
                "pj_per_op": rep.pj_per_op,
                "pj_per_request": pj.summary(),
            }
        return out
