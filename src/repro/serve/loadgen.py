"""Deterministic synthetic traffic for the recurrent serve engine.

The traffic pattern ReckOn/Chameleon (PAPERS.md) anchor on: many short,
bursty, *stateful* streams — each request is a burst of feature frames
from one user session, arrivals are Poisson, and a fraction of requests
come from returning users (whose slab state must be reloaded).

Everything is derived from a seeded ``numpy`` PCG64 generator, so two
runs of the same :class:`TrafficSpec` produce bit-identical frames and
arrival times on every platform — the serve bench's bitwise invariance
gate replays the same traffic through differently-composed batches.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["TrafficSpec", "Arrival", "make_arrivals", "request_frames"]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """One reproducible traffic trace.

    rate_hz        mean Poisson arrival rate (requests/s). ``None``
                   collapses every arrival to t=0 (a burst — the
                   saturation/throughput measurement mode).
    n_requests     total requests in the trace.
    n_users        distinct user sessions the requests are drawn from;
                   fewer users than requests means returning users whose
                   spilled slab state gets reloaded.
    frames_min/max uniform range of frames per request burst.
    n_x            feature width of each frame.
    seed           master seed for arrivals, user draws and frames.
    """
    n_requests: int = 64
    rate_hz: Optional[float] = None
    n_users: Optional[int] = None
    frames_min: int = 8
    frames_max: int = 28
    n_x: int = 28
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: who, when, and how many frames."""
    rid: int
    uid: int
    t: float            # seconds from trace start
    n_frames: int


def make_arrivals(spec: TrafficSpec) -> list[Arrival]:
    """The full trace, sorted by arrival time (stable in rid)."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, 0]))
    n_users = spec.n_users or spec.n_requests
    if spec.rate_hz is None:
        times = np.zeros(spec.n_requests)
    else:
        gaps = rng.exponential(1.0 / spec.rate_hz, size=spec.n_requests)
        times = np.cumsum(gaps)
    uids = rng.integers(0, n_users, size=spec.n_requests)
    lens = rng.integers(spec.frames_min, spec.frames_max + 1,
                        size=spec.n_requests)
    return [Arrival(rid=i, uid=int(uids[i]), t=float(times[i]),
                    n_frames=int(lens[i]))
            for i in range(spec.n_requests)]


def request_frames(spec: TrafficSpec, rid: int,
                   n_frames: Optional[int] = None) -> np.ndarray:
    """The (n_frames, n_x) float32 feature burst of request ``rid`` —
    a pure function of (seed, rid), independent of arrival order, so the
    same request replays bit-identically in any serving schedule."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, 1, rid]))
    if n_frames is None:
        n_frames = int(rng.integers(spec.frames_min, spec.frames_max + 1))
    # Bounded drive: the sign-magnitude quantizer saturates past ±1.
    x = rng.uniform(-1.0, 1.0, size=(n_frames, spec.n_x))
    return x.astype(np.float32)


def replay(spec: TrafficSpec) -> Iterator[tuple[Arrival, np.ndarray]]:
    """(arrival, frames) pairs in arrival order."""
    for a in make_arrivals(spec):
        yield a, request_frames(spec, a.rid, a.n_frames)
