"""Device-resident slab of per-user recurrent state — the MiRU "KV cache".

A served user's entire conversation state is one (n_h,) hidden vector, so
the serving cache is a single (n_slots, n_h) device array: slot i holds
user i's ``h`` and the engine's compiled step advances every row at once.
:class:`StateSlab` owns that array plus the slot bookkeeping:

  acquire(uid)   make ``uid`` resident and return its slot — reusing its
                 existing slot, taking a free one (zero state for a new
                 user, reloading spilled state bit-identically for a
                 returning one), or evicting the least-recently-used
                 unpinned resident when the slab is full.
  pin/unpin      streams currently scheduled into the batch are pinned:
                 the evictor never takes their slot mid-flight.
  release(uid)   drop the user's state entirely (session over).
  evict(uid)     spill the row to host memory and free the slot — the
                 engine never calls this directly; ``acquire`` does under
                 slot pressure (the LRU spill of ROADMAP item 2).

Spill/reload is bit-exact: a float32 row round-trips device → host numpy
→ device unchanged, so an evicted-and-reloaded user continues their
stream bitwise as if they had stayed resident (asserted in
tests/test_serve_slab.py, gated in benchmarks/serve_bench.py).

Invariants (checked by :meth:`check`, driven by the property suite):

  * every slot is either on the free list or mapped to exactly one uid
    (free-list conservation, no double occupancy);
  * the LRU book tracks exactly the resident uids;
  * no uid is both resident and spilled.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StateSlab", "SlabFullError"]


# Row reads/writes go through jitted helpers: an eager scatter/gather on
# the slab dispatches an untraced primitive per call (~ms on CPU), which
# under admission churn — 64 evict+reload pairs in one engine step —
# costs more than the compiled step itself. The slot index is a traced
# scalar, so each helper compiles once per slab shape.
@jax.jit
def _row_set(h: jax.Array, slot, row: jax.Array) -> jax.Array:
    return h.at[slot].set(row)


@jax.jit
def _row_get(h: jax.Array, slot) -> jax.Array:
    return h[slot]


class SlabFullError(RuntimeError):
    """Every slot is occupied by a pinned (mid-batch) stream."""


class StateSlab:
    def __init__(self, n_slots: int, n_h: int, dtype: Any = jnp.float32):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.n_h = int(n_h)
        self.dtype = dtype
        #: The device-resident state array. The engine reads it as the
        #: compiled step's h0 and assigns the step's masked-writeback
        #: output straight back (the buffer is donated to the jit step).
        self.h = jnp.zeros((self.n_slots, self.n_h), dtype)
        self._zero_row = jnp.zeros((self.n_h,), dtype)
        self._slot_of: dict[Hashable, int] = {}
        self._uid_of: list[Optional[Hashable]] = [None] * self.n_slots
        # Free slots as a stack, lowest index on top — allocation order
        # is deterministic, which the batch-composition invariance tests
        # rely on to *construct* adversarial slot permutations.
        self._free: list[int] = list(range(self.n_slots))[::-1]
        self._lru: OrderedDict[Hashable, None] = OrderedDict()
        self._pinned: set[Hashable] = set()
        self._spill: dict[Hashable, np.ndarray] = {}
        self.evictions = 0
        self.reloads = 0

    # ------------------------------------------------------------------
    # Queries
    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def resident(self) -> tuple[Hashable, ...]:
        """Resident uids in LRU → MRU order."""
        return tuple(self._lru)

    @property
    def spilled(self) -> tuple[Hashable, ...]:
        return tuple(self._spill)

    def slot(self, uid: Hashable) -> Optional[int]:
        return self._slot_of.get(uid)

    def is_resident(self, uid: Hashable) -> bool:
        return uid in self._slot_of

    def can_acquire(self, uid: Hashable) -> bool:
        """Would :meth:`acquire` succeed without raising SlabFullError?"""
        return (uid in self._slot_of or self._free
                or any(u not in self._pinned for u in self._lru))

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def acquire(self, uid: Hashable) -> int:
        """Make ``uid`` resident and MRU; return its slot."""
        slot = self._slot_of.get(uid)
        if slot is not None:
            self.touch(uid)
            return slot
        if not self._free:
            self._evict_lru()
        slot = self._free.pop()
        self._slot_of[uid] = slot
        self._uid_of[slot] = uid
        self._lru[uid] = None
        if uid in self._spill:
            # Returning user: reload the spilled row bit-identically.
            self.h = _row_set(self.h, slot,
                              jnp.asarray(self._spill.pop(uid), self.dtype))
            self.reloads += 1
        else:
            # New user: fresh zero state (the slot may hold a departed
            # user's stale h).
            self.h = _row_set(self.h, slot, self._zero_row)
        return slot

    def touch(self, uid: Hashable) -> None:
        """Mark ``uid`` most-recently-used."""
        self._lru.move_to_end(uid)

    def pin(self, uid: Hashable) -> None:
        """Exclude a resident uid from eviction (it is in the batch)."""
        if uid not in self._slot_of:
            raise KeyError(f"cannot pin non-resident uid {uid!r}")
        self._pinned.add(uid)

    def unpin(self, uid: Hashable) -> None:
        self._pinned.discard(uid)

    def release(self, uid: Hashable) -> None:
        """Forget ``uid`` entirely — resident or spilled. No-op if
        unknown (a rejected request never acquired a slot)."""
        slot = self._slot_of.pop(uid, None)
        if slot is not None:
            self._uid_of[slot] = None
            self._free.append(slot)
            del self._lru[uid]
        self._pinned.discard(uid)
        self._spill.pop(uid, None)

    def evict(self, uid: Hashable) -> None:
        """Spill ``uid``'s row to host memory and free its slot."""
        if uid in self._pinned:
            raise ValueError(f"cannot evict pinned uid {uid!r}")
        slot = self._slot_of.pop(uid)
        self._spill[uid] = np.asarray(_row_get(self.h, slot))
        self._uid_of[slot] = None
        self._free.append(slot)
        del self._lru[uid]
        self.evictions += 1

    def _evict_lru(self) -> None:
        for uid in self._lru:                 # LRU → MRU order
            if uid not in self._pinned:
                self.evict(uid)
                return
        raise SlabFullError(
            f"all {self.n_slots} slots are pinned mid-batch; "
            "hold the request in the queue until a stream completes")

    def preload(self, uid: Hashable, row: np.ndarray) -> None:
        """Seed ``uid``'s state as a host-spilled row. The chip-failure
        migration path: a failed chip's rows enter the replacement slab
        through the same spill dict the LRU evictor uses, so the next
        ``acquire`` reloads them with the bit-exact round-trip the spill
        path already guarantees."""
        if uid in self._slot_of:
            raise ValueError(f"uid {uid!r} is already resident")
        row = np.asarray(row)
        if row.shape != (self.n_h,):
            raise ValueError(f"row must be ({self.n_h},), got {row.shape}")
        self._spill[uid] = row

    # ------------------------------------------------------------------
    def read(self, uid: Hashable) -> np.ndarray:
        """Host copy of ``uid``'s current state (resident or spilled)."""
        slot = self._slot_of.get(uid)
        if slot is not None:
            return np.asarray(_row_get(self.h, slot))
        return np.array(self._spill[uid])

    def stats(self) -> dict:
        return {"n_slots": self.n_slots, "resident": len(self._slot_of),
                "free": len(self._free), "spilled": len(self._spill),
                "evictions": self.evictions, "reloads": self.reloads}

    def check(self) -> None:
        """Assert the structural invariants (test hook)."""
        occupied = {s for s, u in enumerate(self._uid_of) if u is not None}
        free = set(self._free)
        assert len(self._free) == len(free), "duplicate free slots"
        assert not (occupied & free), "slot both free and occupied"
        assert occupied | free == set(range(self.n_slots)), \
            "free-list conservation violated"
        assert len(self._slot_of) == len(occupied), "double occupancy"
        for uid, slot in self._slot_of.items():
            assert self._uid_of[slot] == uid, "slot_of/uid_of disagree"
        assert set(self._lru) == set(self._slot_of), \
            "LRU book != resident set"
        assert not (set(self._spill) & set(self._slot_of)), \
            "uid both resident and spilled"
        assert self._pinned <= set(self._slot_of), "pinned non-resident"

    def __repr__(self) -> str:
        return (f"<StateSlab {len(self._slot_of)}/{self.n_slots} resident, "
                f"{len(self._spill)} spilled, {self.evictions} evictions>")
