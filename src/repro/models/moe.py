"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Dispatch algorithm (GShard-style capacity, sort-based grouping — no
(T, E, C) one-hot, which is infeasible at deepseek scale):

  1. router logits → softmax → top-k (weights, expert ids) per token
  2. flatten (token, k) slots; stable-sort slots by expert id
  3. position-in-expert via group starts (searchsorted on the sorted ids)
  4. scatter surviving slots (pos < capacity) into an (E·C, D) buffer
  5. batched per-expert SwiGLU on (E, C, D) — experts shard over the EP
     axis of the mesh (see distributed/sharding.py)
  6. scatter-add expert outputs back to tokens, weighted by router probs

Overflow beyond capacity is dropped (standard GShard semantics); shared
experts (deepseek) bypass routing entirely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense
from repro.utils import ceil_div, truncated_normal_init as tn


def init_moe_params(key: jax.Array, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": tn(ks[0], (D, E), D ** -0.5, jnp.float32),
        "w_gate": tn(ks[1], (E, D, F), D ** -0.5, cfg.dtype),
        "w_up": tn(ks[2], (E, D, F), D ** -0.5, cfg.dtype),
        "w_down": tn(ks[3], (E, F, D), F ** -0.5, cfg.dtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": tn(k1, (D, Fs), D ** -0.5, cfg.dtype),
            "w_up": tn(k2, (D, Fs), D ** -0.5, cfg.dtype),
            "w_down": tn(k3, (Fs, D), Fs ** -0.5, cfg.dtype),
        }
    return p


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x (B, S, D) → (B, S, D).

    Two dispatch paths:
      * EP/shard_map (production): when a sharding context is installed
        and n_experts divides the model axis — local routing per shard,
        all-to-all exchange to expert owners, local expert FFN, reverse
        all-to-all. Dispatch volume = k·D per token (the physical
        minimum) instead of the global-sort gather. §Perf iteration.
      * global sort-based (fallback/single-device): GShard-style
        capacity dispatch over the full token set.
    """
    from repro.distributed.context import current_context
    ctx = current_context()
    if ctx is not None and ctx.moe_mode == "ep" \
            and _ep_eligible(p, cfg, x, ctx) \
            and _ep_divisible(x, ctx):
        y = _moe_ffn_ep(p, cfg, x, ctx)
        if cfg.n_shared_experts:
            y = y + _shared_expert(p, cfg, x.reshape(-1, x.shape[-1])
                                   ).reshape(x.shape).astype(y.dtype)
        return y.astype(x.dtype)
    return _moe_ffn_global(p, cfg, x)


# Expert banks smaller than this are replicated per device (granite:
# 40 experts × 63 MB/bank) — dispatch becomes fully local, zero MoE
# collectives. Larger banks require E % model_axis == 0 for the
# all-to-all exchange path.
_REPLICATE_BANK_BYTES = 2.5e8


def _bank_bytes(p: dict) -> int:
    w = p["w_gate"]
    return int(w.size) * w.dtype.itemsize


def _ep_eligible(p: dict, cfg: ModelConfig, x: jax.Array, ctx) -> bool:
    if cfg.n_experts % ctx.mesh.shape[ctx.model_axis] == 0:
        return True
    return _bank_bytes(p) <= _REPLICATE_BANK_BYTES


def _ep_divisible(x: jax.Array, ctx) -> bool:
    """EP shard_map needs the token block dims to divide the mesh axes,
    and enough tokens per step to amortize the expert-weight gathers +
    all-to-alls — one-token decode steps measured 4.5–10× WORSE under EP
    (§Perf iteration 13), so they use the global path."""
    if x.shape[0] * x.shape[1] < 16 * ctx.mesh.devices.size:
        return False                      # decode / tiny steps
    n_b = 1
    for a in ctx.batch_axes:
        n_b *= ctx.mesh.shape[a]
    if x.shape[0] % n_b != 0:
        return False
    if ctx.sequence_parallel and \
            x.shape[1] % ctx.mesh.shape[ctx.model_axis] != 0:
        return False
    return True


def _shared_expert(p: dict, cfg: ModelConfig, xt: jax.Array) -> jax.Array:
    sp = p["shared"]
    return (jax.nn.silu(dense(xt, sp["w_gate"], quant_mode=cfg.quant_mode))
            * dense(xt, sp["w_up"], quant_mode=cfg.quant_mode)
            ) @ sp["w_down"].astype(xt.dtype)


def _local_dispatch(xt, probs, E: int, K: int, C: int):
    """Route T local tokens into an (E, C, D) buffer. Returns
    (buf, slot-token ids, slot weights, keep mask, slot index)."""
    T, D = xt.shape
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - group_start[se]
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)
    buf = jnp.zeros((E * C, D), xt.dtype)
    gathered = jnp.where(keep[:, None], xt[st], 0)
    buf = buf.at[slot].add(gathered)
    return buf.reshape(E, C, D), st, sw, keep, slot


def _moe_ffn_ep(p: dict, cfg: ModelConfig, x: jax.Array, ctx
                ) -> jax.Array:
    """Expert-parallel dispatch under shard_map (see moe_ffn)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    axis = ctx.model_axis
    n_ep = mesh.shape[axis]
    E, K = cfg.n_experts, cfg.top_k
    # Exchange mode: experts sharded over the model axis, tokens moved by
    # all-to-all. Replicated mode (small banks, E ∤ axis): every device
    # holds every expert — dispatch is fully local, zero collectives.
    exchange = E % n_ep == 0
    E_loc = E // n_ep if exchange else E
    b = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    x_spec = P(b, axis if ctx.sequence_parallel else None, None)

    # Expert banks keep their native (EP over model × FSDP over data)
    # sharding at the shard_map boundary — matching specs means GSPMD
    # never reshards the *stacked* (L,E,D,F) banks outside the layer scan
    # (a 400+ GB/device f32 all-gather otherwise). The per-layer FSDP
    # gather over D happens explicitly, in bf16, inside the block.
    fsdp_axis = "data" if exchange and "data" in mesh.shape and \
        p["w_gate"].shape[1] % mesh.shape["data"] == 0 else None

    def block(x_blk, router, w_gate, w_up, w_down):
        if fsdp_axis is not None:
            w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1,
                                        tiled=True)
            w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2,
                                        tiled=True)
        Bb, Sb, D = x_blk.shape
        T = Bb * Sb
        xt = x_blk.reshape(T, D)
        C = max(1, int(-(-T * K // E) * cfg.capacity_factor))
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        buf, st, sw, keep, slot = _local_dispatch(xt, probs, E, K, C)
        if exchange:
            # (E, C, D) → (n_ep, E_loc, C, D); dim0 ↔ device all-to-all.
            send = buf.reshape(n_ep, E_loc, C, D)
            recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
            # (n_ep_src, E_loc, C, D) → (E_loc, n_ep·C, D) expert-major.
            xb = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_ep * C, D)
        else:
            xb = buf                                   # fully local
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate)) \
            * jnp.einsum("ecd,edf->ecf", xb, w_up)
        yb = jnp.einsum("ecf,efd->ecd", h, w_down)
        if exchange:
            back = jnp.moveaxis(yb.reshape(E_loc, n_ep, C, D), 1, 0)
            got = jax.lax.all_to_all(back, axis, 0, 0, tiled=False)
            got = got.reshape(E * C, D)
        else:
            got = yb.reshape(E * C, D)
        out_slots = jnp.where(keep[:, None],
                              got[slot] * sw[:, None].astype(got.dtype), 0)
        y = jnp.zeros((T, D), got.dtype).at[st].add(out_slots)
        return y.reshape(Bb, Sb, D).astype(x_blk.dtype)

    if exchange:
        wg_spec = P(axis, fsdp_axis, None)
        wd_spec = P(axis, None, fsdp_axis)
    else:
        wg_spec = P(None, None, None)
        wd_spec = P(None, None, None)
    fn = shard_map(
        block, mesh=mesh,
        in_specs=(x_spec, P(None, None), wg_spec, wg_spec, wd_spec),
        out_specs=x_spec,
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_ffn_global(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Global sort-based capacity dispatch (fallback path)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(ceil_div(T * K, E) * cfg.capacity_factor))
    xt = x.reshape(T, D)

    # 1. Routing (fp32 for a stable softmax).
    logits = dense(xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)              # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # 2-3. Slot sort and position-in-expert.
    flat_e = top_e.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - group_start[se]
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)

    # 4. Dispatch into (E·C, D).
    buf = jnp.zeros((E * C, D), x.dtype)
    gathered = jnp.where(keep[:, None], xt[st], 0)
    buf = buf.at[slot].add(jnp.where(keep[:, None], gathered, 0))
    xb = buf.reshape(E, C, D)

    # 5. Batched per-expert SwiGLU (einsum over the expert axis ⇒ EP).
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xb, p["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    # 6. Combine back to tokens.
    out_slots = jnp.where(keep[:, None], yb[slot] * sw[:, None].astype(
        yb.dtype), 0)
    y = jnp.zeros((T, D), yb.dtype).at[st].add(out_slots)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(dense(xt, sp["w_gate"],
                                   quant_mode=cfg.quant_mode))
                 * dense(xt, sp["w_up"], quant_mode=cfg.quant_mode)
                 ) @ sp["w_down"].astype(y.dtype)
    return y.reshape(B, S, D).astype(x.dtype)


def moe_load_stats(p: dict, cfg: ModelConfig, x: jax.Array) -> dict:
    """Router balance diagnostics (tests + trainer logging)."""
    B, S, D = x.shape
    logits = dense(x.reshape(-1, D).astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, cfg.top_k)
    counts = jnp.bincount(top_e.reshape(-1), length=cfg.n_experts)
    frac = counts / counts.sum()
    return {"frac_per_expert": frac,
            "max_over_mean": float(frac.max() * cfg.n_experts)}
