"""Top-level language models: init / train loss / prefill / decode.

Families
  dense | moe | vlm | audio : decoder-only transformer (GQA or MLA mixers,
                              dense or MoE FFN, optional modality prefix)
  ssm                       : mamba2 stack
  hybrid                    : jamba superblocks
  encdec                    : encoder (bidirectional) + decoder (causal +
                              cross-attention)

Prefill returns logits over the full prompt (compute roofline of the
prefill cell); decode_step consumes a pre-filled cache (decode cells pass
it as an input ShapeDtypeStruct in the dry-run).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import act_constraint
from repro.models import attention as attn
from repro.models import blocks
from repro.models import ssm as ssm_mod
from repro.models.layers import dense, rms_norm
from repro.utils import softmax_cross_entropy_masked, truncated_normal_init \
    as tn

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    D = cfg.d_model
    p: dict = {
        "embed": tn(ks[0], (cfg.vocab, D), 0.02, cfg.dtype),
        "final_norm": jnp.ones((D,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = tn(ks[1], (D, cfg.vocab), D ** -0.5, cfg.dtype)
    if cfg.frontend != "none":
        p["frontend_proj"] = tn(ks[2], (D, D), D ** -0.5, cfg.dtype)

    if cfg.is_encoder_decoder:
        p["encoder"] = blocks.init_stack(ks[3], cfg, cfg.n_enc_layers,
                                         is_ssm=False, is_moe=False)
        p["enc_norm"] = jnp.ones((D,), cfg.dtype)
        p["decoder"] = blocks.init_stack(ks[4], cfg, cfg.n_layers,
                                         is_ssm=False, is_moe=False,
                                         cross_attn=True)
    elif cfg.layer_pattern == "hybrid":
        p["layers"] = blocks.init_hybrid_stack(ks[3], cfg)
    elif cfg.layer_pattern == "ssm":
        p["layers"] = blocks.init_stack(ks[3], cfg, cfg.n_layers,
                                        is_ssm=True, is_moe=False)
    elif cfg.n_experts > 0:
        if cfg.first_dense_layers:
            p["dense_layers"] = blocks.init_stack(
                ks[3], cfg, cfg.first_dense_layers, is_ssm=False,
                is_moe=False)
        p["layers"] = blocks.init_stack(
            ks[4], cfg, cfg.n_layers - cfg.first_dense_layers,
            is_ssm=False, is_moe=True)
    else:
        p["layers"] = blocks.init_stack(ks[3], cfg, cfg.n_layers,
                                        is_ssm=False, is_moe=False)
    return p


def param_shapes(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStructs of the full parameter tree (no allocation)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed_tokens(p: dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embed"], tokens, axis=0)


def _lm_logits(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, p["final_norm"], cfg.rmsnorm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embed"])
    return dense(x, p["lm_head"], quant_mode=cfg.quant_mode)


def _prefix_embeds(p: dict, cfg: ModelConfig, batch: dict
                   ) -> Optional[jax.Array]:
    """Modality-stub prefix (precomputed frame/patch embeddings)."""
    key = {"audio": "frames", "vision": "patches"}.get(cfg.frontend)
    if key is None or key not in batch:
        return None
    return dense(batch[key].astype(cfg.dtype), p["frontend_proj"])


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(p: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Returns logits (B, S_total, V); text logits are the last S_text."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = act_constraint(_embed_tokens(p, cfg, tokens), "btd")

    if cfg.is_encoder_decoder:
        frames = batch["frames"].astype(cfg.dtype)
        mem = dense(frames, p["frontend_proj"]) \
            if cfg.frontend != "none" else frames
        mem_pos = jnp.arange(mem.shape[1])
        mem = blocks.stack_forward(p["encoder"], cfg, mem, mem_pos,
                                   is_ssm=False, is_moe=False, causal=False)
        mem = rms_norm(mem, p["enc_norm"], cfg.rmsnorm_eps)
        pos = jnp.arange(S)
        x = blocks.stack_forward(p["decoder"], cfg, x, pos, is_ssm=False,
                                 is_moe=False, causal=True, memory=mem,
                                 memory_positions=mem_pos)
        return _lm_logits(p, cfg, x)

    prefix = _prefix_embeds(p, cfg, batch)
    if prefix is not None:
        x = jnp.concatenate([prefix, x], axis=1)
    pos = jnp.arange(x.shape[1])

    if cfg.layer_pattern == "hybrid":
        x = blocks.hybrid_forward(p["layers"], cfg, x, pos)
    elif cfg.layer_pattern == "ssm":
        x = blocks.stack_forward(p["layers"], cfg, x, pos, is_ssm=True,
                                 is_moe=False)
    elif cfg.n_experts > 0:
        if "dense_layers" in p:
            x = blocks.stack_forward(p["dense_layers"], cfg, x, pos,
                                     is_ssm=False, is_moe=False)
        x = blocks.stack_forward(p["layers"], cfg, x, pos, is_ssm=False,
                                 is_moe=True)
    else:
        x = blocks.stack_forward(p["layers"], cfg, x, pos, is_ssm=False,
                                 is_moe=False)

    if prefix is not None:
        x = x[:, prefix.shape[1]:, :]
    x = act_constraint(x, "btd")
    return act_constraint(_lm_logits(p, cfg, x), "btv")


def loss_fn(p: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    logits = forward(p, cfg, batch)
    return softmax_cross_entropy_masked(
        logits.astype(jnp.float32), batch["labels"], batch["mask"])


def prefill(p: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Inference prefill: forward logits over the prompt (no grad)."""
    return forward(p, cfg, batch)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> PyTree:
    spec = attn.CacheSpec(batch, max_len, cfg.kv_cache_dtype)

    def stacked(n, one):
        return jax.tree.map(
            lambda a: jnp.zeros((n,) + a.shape, a.dtype), one)

    if cfg.is_encoder_decoder:
        kvd = cfg.n_kv_heads * cfg.hd()
        return {
            "self": stacked(cfg.n_layers, attn.init_kv_cache(cfg, spec)),
            "cross_k": jnp.zeros((cfg.n_layers, batch, enc_len, kvd),
                                 jnp.bfloat16),
            "cross_v": jnp.zeros((cfg.n_layers, batch, enc_len, kvd),
                                 jnp.bfloat16),
            "enc_len": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.layer_pattern == "hybrid":
        return blocks.init_hybrid_caches(cfg, batch, max_len)
    if cfg.layer_pattern == "ssm":
        return stacked(cfg.n_layers, ssm_mod.init_ssm_cache(cfg, batch))
    if cfg.use_mla:
        one = attn.init_mla_cache(cfg, spec)
        if cfg.first_dense_layers:
            return {"dense": stacked(cfg.first_dense_layers, one),
                    "moe": stacked(cfg.n_layers - cfg.first_dense_layers,
                                   one)}
        return stacked(cfg.n_layers, one)
    one = attn.init_kv_cache(cfg, spec)
    if cfg.n_experts > 0 and cfg.first_dense_layers:
        return {"dense": stacked(cfg.first_dense_layers, one),
                "moe": stacked(cfg.n_layers - cfg.first_dense_layers, one)}
    return stacked(cfg.n_layers, one)


def decode_step(p: dict, cfg: ModelConfig, caches: PyTree,
                tokens: jax.Array, pos: jax.Array
                ) -> tuple[jax.Array, PyTree]:
    """One new token for every sequence. tokens (B, 1); pos scalar int32
    (current write position; same for all rows in the dry-run cells)."""
    x = _embed_tokens(p, cfg, tokens)

    if cfg.is_encoder_decoder:
        x, new_self = blocks.stack_decode(
            p["decoder"], caches["self"], cfg, x, pos, is_ssm=False,
            cross_kv=(caches["cross_k"], caches["cross_v"]),
            enc_len=caches["enc_len"])
        caches = dict(caches, self=new_self)
        return _lm_logits(p, cfg, x), caches

    if cfg.layer_pattern == "hybrid":
        x, new_caches = blocks.hybrid_decode(p["layers"], caches, cfg, x,
                                             pos)
        return _lm_logits(p, cfg, x), new_caches

    if cfg.layer_pattern == "ssm":
        x, new_caches = blocks.stack_decode(p["layers"], caches, cfg, x,
                                            pos, is_ssm=True)
        return _lm_logits(p, cfg, x), new_caches

    if cfg.n_experts > 0 and cfg.first_dense_layers:
        x, new_dense = blocks.stack_decode(p["dense_layers"],
                                           caches["dense"], cfg, x, pos,
                                           is_ssm=False)
        x, new_moe = blocks.stack_decode(p["layers"], caches["moe"], cfg,
                                         x, pos, is_ssm=False)
        return _lm_logits(p, cfg, x), {"dense": new_dense, "moe": new_moe}

    x, new_caches = blocks.stack_decode(p["layers"], caches, cfg, x, pos,
                                        is_ssm=False)
    return _lm_logits(p, cfg, x), new_caches


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
