"""Attention: GQA (full + chunked-flash), qk-norm, biases, MLA, KV caches.

Chunked-flash is the pure-JAX online-softmax attention (scan over KV
chunks carrying running max / denominator / accumulator); it bounds the
live score tensor to (B, H, S_q, chunk) — required for the 32k prefill
cells to fit HBM. On real TPU hardware the same schedule maps to a Pallas
flash kernel; HLO structure (and hence the roofline terms) is equivalent.

KV caches support bf16 and int8 with *stochastic rounding* — the paper's
replay-buffer quantizer (eq. 4-6) applied to the decode cache, which is
what makes the yi-34b/llava decode_32k cells fit in 16 GB/chip (see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core softmax attention (full and chunked)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool, q_offset: int = 0,
                   kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q (B,Sq,H,dh), k/v (B,Sk,Kh,dh). Returns (B,Sq,H,dv).

    GQA is computed with grouped einsums (q reshaped to (…,Kh,G,dh)) —
    never materializing the H/Kh-times repeated K/V, which at yi-34b
    decode_32k would be a 3.8 GB/layer buffer (§Perf)."""
    B, Sq, H, dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k
                        ).astype(jnp.float32) * scale   # (B,Kh,G,Sq,Sk)
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        si = jnp.arange(Sk)[None, :]
        scores = jnp.where(si <= qi, scores, NEG_INF)
    if kv_len is not None:
        si = jnp.arange(Sk)
        mask = si[None, :] < kv_len[:, None]            # (B, Sk)
        scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, chunk: int = 1024) -> jax.Array:
    """Online-softmax attention over KV chunks (flash schedule in JAX).

    Memory: O(B·H·Sq·chunk) live scores instead of O(B·H·Sq·Sk).
    """
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    Kh = k.shape[2]
    if Sk % chunk != 0:                    # pad KV to a chunk multiple
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    k = _repeat_kv(k, H // Kh)
    v = _repeat_kv(v, H // Kh)
    kc = k.reshape(B, n_chunks, chunk, H, dh)
    vc = v.reshape(B, n_chunks, chunk, H, v.shape[-1])
    scale = dh ** -0.5
    qi = jnp.arange(Sq)[:, None]

    def body(carry, inp):
        acc, m, l = carry
        kj, vj, j = inp
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kj
                            ).astype(jnp.float32) * scale
        ki = j * chunk + jnp.arange(chunk)[None, :]
        valid = ki < Sk
        if causal:
            valid = valid & (ki <= qi)
        scores = jnp.where(valid[None, None, :, :], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vj).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, v.shape[-1]), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)   # (B,Sq,H,dv)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (memory-bounded backward)
# ---------------------------------------------------------------------------
# lax.scan-based online softmax alone is NOT enough for training: scan
# saves its per-chunk carries (acc/m/l) for the backward pass, which costs
# O(n_chunks · B·H·Sq·dh) — 20+ GB/device at yi-34b train_4k. The fix is
# the FlashAttention recipe: forward saves only (q, k, v, out, lse);
# backward recomputes P chunk-by-chunk and accumulates dq/dk/dv.
# (EXPERIMENTS.md §Perf iteration 1.)

def _flash_fwd_impl(q, k, v, causal: bool, chunk: int, sk_true: int):
    """q (B,H,Sq,dh); k,v (B,H,Sk,dh|dv). Returns out (B,H,Sq,dv), lse."""
    B, H, Sq, dh = q.shape
    Sk = k.shape[2]
    scale = dh ** -0.5
    n_chunks = Sk // chunk
    kc = k.reshape(B, H, n_chunks, chunk, -1)
    vc = v.reshape(B, H, n_chunks, chunk, -1)
    qi = jnp.arange(Sq)[:, None]

    def body(carry, inp):
        acc, m, l = carry
        kj, vj, j = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj).astype(jnp.float32) * scale
        ki = j * chunk + jnp.arange(chunk)[None, :]
        valid = ki < sk_true
        if causal:
            valid = valid & (ki <= qi)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # p materializes in the compute dtype (bf16 on TPU): the exp and
        # convert fuse into one kernel, so the f32 probabilities never
        # hit HBM — half the dominant buffer (§Perf iteration 3). Row
        # sums still accumulate in f32.
        p = jnp.exp(s - m_new[..., None]).astype(q.dtype)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.astype(jnp.float32).sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, v.shape[-1]), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0),
         jnp.arange(n_chunks)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal: bool, chunk: int, sk_true: int):
    return _flash_fwd_impl(q, k, v, causal, chunk, sk_true)[0]


def _flash_fwd(q, k, v, causal, chunk, sk_true):
    out, lse = _flash_fwd_impl(q, k, v, causal, chunk, sk_true)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, chunk, sk_true, res, dout):
    q, k, v, out, lse = res
    B, H, Sq, dh = q.shape
    Sk = k.shape[2]
    scale = dh ** -0.5
    n_chunks = Sk // chunk
    kc = k.reshape(B, H, n_chunks, chunk, -1)
    vc = v.reshape(B, H, n_chunks, chunk, -1)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                # (B,H,Sq)
    qi = jnp.arange(Sq)[:, None]

    def body(dq, inp):
        kj, vj, j = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj).astype(jnp.float32) * scale
        ki = j * chunk + jnp.arange(chunk)[None, :]
        valid = ki < sk_true
        if causal:
            valid = valid & (ki <= qi)
        s = jnp.where(valid[None, None], s, NEG_INF)
        # bf16 materialization for p and ds (f32 math stays inside the
        # producing fusions) — §Perf iteration 3.
        p = jnp.exp(s - lse[..., None]).astype(q.dtype)     # (B,H,q,k)
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, dout)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dout, vj).astype(jnp.float32)
        ds = (p.astype(jnp.float32) * (dp - delta[..., None])
              ).astype(q.dtype)
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kj
                             ).astype(jnp.float32) * scale
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
        return dq, (dk_j.astype(k.dtype), dv_j.astype(v.dtype))

    dq0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        body, dq0, (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0),
                    jnp.arange(n_chunks)))
    dk = jnp.moveaxis(dk_c, 0, 2).reshape(B, H, Sk, -1)
    dv = jnp.moveaxis(dv_c, 0, 2).reshape(B, H, Sk, -1)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, chunk: int = 1024) -> jax.Array:
    """(B,Sq,H,dh) layout wrapper; pads KV to a chunk multiple."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    Kh = k.shape[2]
    k = _repeat_kv(k, H // Kh)
    v = _repeat_kv(v, H // Kh)
    if Sk % chunk != 0:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _flash(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                 jnp.swapaxes(v, 1, 2), causal, chunk, Sk)
    return jnp.swapaxes(out, 1, 2)


def sdpa(q, k, v, causal: bool, chunk: int, q_offset: int = 0,
         kv_len=None):
    """Dispatch: flash (custom-vjp online softmax) for long KV, full
    otherwise."""
    if k.shape[1] > chunk and kv_len is None and q_offset == 0:
        return flash_attention(q, k, v, causal, chunk)
    return full_attention(q, k, v, causal, q_offset, kv_len)


# ---------------------------------------------------------------------------
# GQA block-level attention with projections
# ---------------------------------------------------------------------------

def init_gqa_params(key: jax.Array, cfg: ModelConfig) -> dict:
    hd = cfg.hd()
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    std = D ** -0.5
    from repro.utils import truncated_normal_init as tn
    p = {
        "wq": tn(ks[0], (D, cfg.n_heads * hd), std, cfg.dtype),
        "wk": tn(ks[1], (D, cfg.n_kv_heads * hd), std, cfg.dtype),
        "wv": tn(ks[2], (D, cfg.n_kv_heads * hd), std, cfg.dtype),
        "wo": tn(ks[3], (cfg.n_heads * hd, D), std, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def gqa_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, causal: bool = True,
                  kv: Optional[tuple] = None,
                  kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Self-attention (kv=None) or cross-attention (kv=(keys_src, ...)).

    x (B,S,D); positions (B,S) or (S,).
    """
    B, S, D = x.shape
    hd = cfg.hd()
    q = dense(x, p["wq"], p.get("bq"), cfg.quant_mode)
    q = q.reshape(B, S, cfg.n_heads, hd)
    if kv is None:
        src = x
        src_pos = positions
    else:
        src = kv[0]
        src_pos = kv_positions
    k = dense(src, p["wk"], p.get("bk"), cfg.quant_mode)
    v = dense(src, p["wv"], p.get("bv"), cfg.quant_mode)
    k = k.reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rms_norm(k, p["k_norm"], cfg.rmsnorm_eps)
    if kv is None:                       # rope only for self-attention
        q = apply_rope(q, jnp.broadcast_to(positions, (B, S)),
                       cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(src_pos, (B, src.shape[1])),
                       cfg.rope_theta)
    from repro.distributed.context import act_constraint, ulysses_enabled
    if kv is None and ulysses_enabled(cfg.n_heads):
        # Ulysses: all-to-all reshard (seq-sharded → head-sharded) around
        # the attention op — 1× tensor volume instead of the P× per-chunk
        # K/V all-gather. KV heads are expanded first so every shard owns
        # its heads' full-sequence K/V.
        k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
        v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
        q = act_constraint(q, "bshd")
        k = act_constraint(k, "bshd")
        v = act_constraint(v, "bshd")
        out = sdpa(q, k, v, causal=causal, chunk=cfg.attn_chunk)
        out = act_constraint(out, "bshd")
    else:
        from repro.distributed.context import current_context
        ctx = current_context()
        if kv is None and ctx is not None and ctx.attn_mode == "ulysses":
            # Ulysses requested but heads don't divide the axis: fall
            # back to an *explicit bf16* K/V gather — anchoring the
            # all-gather on the low-precision tensor halves its bytes vs
            # letting the partitioner gather post-f32-convert (§Perf).
            k = act_constraint(k, "bshd_full")
            v = act_constraint(v, "bshd_full")
        out = sdpa(q, k, v, causal=causal and kv is None,
                   chunk=cfg.attn_chunk)
    out = out.reshape(B, S, cfg.n_heads * hd)
    return dense(out, p["wo"], quant_mode=cfg.quant_mode)


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    batch: int
    max_len: int
    dtype: str = "bf16"     # bf16 | int8


def init_kv_cache(cfg: ModelConfig, spec: CacheSpec) -> dict:
    hd = cfg.hd()
    kvd = cfg.n_kv_heads * hd
    shape = (spec.batch, spec.max_len, kvd)
    if spec.dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:2] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:2] + (1,), jnp.float32),
        }
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16)}


def _quantize_kv(x: jax.Array, key: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Per-token int8 with stochastic rounding — the paper's replay-buffer
    quantizer (eq. 4-6) applied to the KV cache."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    z = x / safe
    fl = jnp.floor(z)
    frac = z - fl
    r = jax.random.uniform(key, x.shape)
    q = jnp.where(r < frac, fl + 1.0, fl)
    return jnp.clip(q, -127, 127).astype(jnp.int8), \
        scale.astype(jnp.float32)


def cache_insert(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, rng: Optional[jax.Array] = None) -> dict:
    """Write one token's k/v (B, 1, kvd) at position ``pos`` (scalar)."""
    if "k_scale" in cache:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        r1, r2 = jax.random.split(rng)
        kq, ks = _quantize_kv(k_new.astype(jnp.float32), r1)
        vq, vs = _quantize_kv(v_new.astype(jnp.float32), r2)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, pos, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, pos, 0)),
        }
    return {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0)),
    }


def cache_read(cache: dict) -> tuple[jax.Array, jax.Array]:
    if "k_scale" in cache:
        k = cache["k"].astype(jnp.bfloat16) \
            * cache["k_scale"].astype(jnp.bfloat16)
        v = cache["v"].astype(jnp.bfloat16) \
            * cache["v_scale"].astype(jnp.bfloat16)
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (deepseek-v3)
# ---------------------------------------------------------------------------

def init_mla_params(key: jax.Array, cfg: ModelConfig) -> dict:
    from repro.utils import truncated_normal_init as tn
    D = cfg.d_model
    H = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "q_down": tn(ks[0], (D, qr), D ** -0.5, cfg.dtype),
        "q_norm": jnp.ones((qr,), cfg.dtype),
        "q_up": tn(ks[1], (qr, H * (dn + dr)), qr ** -0.5, cfg.dtype),
        "kv_down": tn(ks[2], (D, kvr + dr), D ** -0.5, cfg.dtype),
        "kv_norm": jnp.ones((kvr,), cfg.dtype),
        "kv_up": tn(ks[3], (kvr, H * (dn + dv)), kvr ** -0.5, cfg.dtype),
        "wo": tn(ks[4], (H * dv, D), (H * dv) ** -0.5, cfg.dtype),
    }


def mla_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, causal: bool = True) -> jax.Array:
    """Training/prefill MLA: expand latents to per-head keys/values."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = dense(rms_norm(dense(x, p["q_down"], quant_mode=cfg.quant_mode),
                       p["q_norm"], cfg.rmsnorm_eps),
              p["q_up"], quant_mode=cfg.quant_mode)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv = dense(x, p["kv_down"], quant_mode=cfg.quant_mode)
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    kv_up = dense(rms_norm(c_kv, p["kv_norm"], cfg.rmsnorm_eps),
                  p["kv_up"], quant_mode=cfg.quant_mode)
    kv_up = kv_up.reshape(B, S, H, dn + dv)
    k_nope, v = kv_up[..., :dn], kv_up[..., dn:]

    posb = jnp.broadcast_to(positions, (B, S))
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)
    k_rope = apply_rope(k_rope, posb, cfg.rope_theta)       # (B,S,dr) shared
    k_rope = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, k_rope], axis=-1)
    # NOTE: softmax scale uses the full qk dim (dn + dr).
    from repro.distributed.context import act_constraint, ulysses_enabled
    if ulysses_enabled(cfg.n_heads):
        qf = act_constraint(qf, "bshd")
        kf = act_constraint(kf, "bshd")
        v = act_constraint(v, "bshd")
        out = sdpa(qf, kf, v, causal=causal, chunk=cfg.attn_chunk)
        out = act_constraint(out, "bshd")
    else:
        out = sdpa(qf, kf, v, causal=causal, chunk=cfg.attn_chunk)
    return dense(out.reshape(B, S, H * dv), p["wo"],
                 quant_mode=cfg.quant_mode)


def init_mla_cache(cfg: ModelConfig, spec: CacheSpec) -> dict:
    """MLA caches the *latent* (kv_lora_rank) + roped key (dr) — the memory
    win that makes deepseek-v3 decode_32k fit."""
    return {
        "c_kv": jnp.zeros((spec.batch, spec.max_len, cfg.kv_lora_rank),
                          jnp.bfloat16),
        "k_rope": jnp.zeros((spec.batch, spec.max_len,
                             cfg.qk_rope_head_dim), jnp.bfloat16),
    }


def mla_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed-matrix MLA decode: attention runs in the 512-d latent space
    (W_UK folded into q, W_UV applied after) — O(S·kv_rank) per token
    instead of O(S·H·head_dim)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    q = dense(rms_norm(dense(x, p["q_down"], quant_mode=cfg.quant_mode),
                       p["q_norm"], cfg.rmsnorm_eps),
              p["q_up"], quant_mode=cfg.quant_mode)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)

    kv = dense(x, p["kv_down"], quant_mode=cfg.quant_mode)
    c_new = rms_norm(kv[..., :kvr], p["kv_norm"], cfg.rmsnorm_eps)
    kr_new = apply_rope(kv[..., kvr:], posb, cfg.rope_theta)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(jnp.bfloat16), (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(jnp.bfloat16), (0, pos, 0)),
    }

    # Absorb W_UK into the query: q_abs (B,S,H,kvr).
    w_uk = p["kv_up"].reshape(kvr, H, dn + dv)[..., :dn]   # (kvr, H, dn)
    q_abs = jnp.einsum("bshd,khd->bshk", q_nope,
                       w_uk.astype(q_nope.dtype))
    scale = (dn + dr) ** -0.5
    c_all = cache["c_kv"]
    kr_all = cache["k_rope"]
    scores = (jnp.einsum("bshk,blk->bhsl", q_abs, c_all.astype(q_abs.dtype))
              + jnp.einsum("bshr,blr->bhsl", q_rope,
                           kr_all.astype(q_rope.dtype))
              ).astype(jnp.float32) * scale
    kv_len = jnp.broadcast_to(pos + 1, (B,))
    mask = jnp.arange(c_all.shape[1])[None, :] < kv_len[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    lat = jnp.einsum("bhsl,blk->bshk", probs, c_all.astype(x.dtype))
    w_uv = p["kv_up"].reshape(kvr, H, dn + dv)[..., dn:]   # (kvr,H,dv)
    out = jnp.einsum("bshk,khv->bshv", lat, w_uv.astype(lat.dtype))
    return dense(out.reshape(B, S, H * dv), p["wo"],
                 quant_mode=cfg.quant_mode), cache


def gqa_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
               pos: jax.Array, rng: Optional[jax.Array] = None
               ) -> tuple[jax.Array, dict]:
    """One-token decode: x (B,1,D), cache over max_len. Returns (out, cache).
    """
    B, S, D = x.shape
    hd = cfg.hd()
    q = dense(x, p["wq"], p.get("bq"), cfg.quant_mode)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = dense(x, p["wk"], p.get("bk"), cfg.quant_mode)
    v = dense(x, p["wv"], p.get("bv"), cfg.quant_mode)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    vh = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rms_norm(k, p["k_norm"], cfg.rmsnorm_eps)
    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    cache = cache_insert(cache, k.reshape(B, S, -1), vh.reshape(B, S, -1),
                         pos, rng)
    k_all, v_all = cache_read(cache)
    L = k_all.shape[1]
    k_all = k_all.reshape(B, L, cfg.n_kv_heads, hd)
    v_all = v_all.reshape(B, L, cfg.n_kv_heads, hd)
    kv_len = jnp.broadcast_to(pos + 1, (B,))
    out = full_attention(q, k_all, v_all, causal=False, kv_len=kv_len)
    out = out.reshape(B, S, cfg.n_heads * hd)
    return dense(out, p["wo"], quant_mode=cfg.quant_mode), cache
