"""Layer blocks and scanned stacks for every architecture family.

Layer = pre-norm mixer (attention / MLA / SSD / MiRU) + pre-norm FFN
(SwiGLU dense or MoE). Identical layers are stacked (leading dim L) and
executed with lax.scan (+ per-layer remat) — this is what keeps the HLO
small enough to compile 61-72 layer configs and bounds activation memory
to one layer.

Hybrid (jamba) uses a scanned *superblock* of period ``attn_every``: the
slot structure inside a superblock is static (7×SSD + 1×attention;
MoE on odd slots), superblocks scan.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import act_constraint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import rms_norm, swiglu, dense
from repro.utils import truncated_normal_init as tn

PyTree = Any


# ---------------------------------------------------------------------------
# MiRU mixer (ablation option; DESIGN.md §5)
# ---------------------------------------------------------------------------

def init_miru_mixer(key: jax.Array, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_h": tn(k1, (D, D), D ** -0.5, cfg.dtype),
            "u_h": tn(k2, (D, D), D ** -0.5, cfg.dtype),
            "b_h": jnp.zeros((D,), cfg.dtype),
            "w_out": tn(k3, (D, D), D ** -0.5, cfg.dtype)}


def miru_mixer(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.kernels import ops as kops
    B, S, D = x.shape
    if cfg.quant_mode != "none":
        # Quantized serving: route the whole recurrence through the shared
        # inference backend's device_recurrence hook — the same substrate
        # (per-step device_vmm scan, or the fused WBS×MiRU kernel where
        # the spec supports it) and the same telemetry accumulator the
        # training forward uses, instead of a float recurrence next to
        # quantized projections. The PRNG is pinned: serving is
        # deterministic; stochastic specs draw a fixed gain realization.
        from repro.backends import inference_backend
        from repro.core.miru import MiRUConfig
        backend = inference_backend(cfg.quant_mode)
        mcfg = MiRUConfig(n_x=D, n_h=D, n_y=2, beta=0.8, lam=0.5)
        # Normalize activations into the crossbar's [-1, 1] drive range
        # and compensate in w_h (the same absmax trick dense() uses) —
        # post-norm hidden states routinely exceed ±1 and would saturate
        # the sign-magnitude quantizer. The recurrent drive β·h is
        # tanh-bounded, so it never needs the rescale.
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6).astype(jnp.float32)
        mp = {"w_h": p["w_h"].astype(jnp.float32) * s,
              "u_h": p["u_h"].astype(jnp.float32),
              "b_h": p["b_h"].astype(jnp.float32)}
        h_all, _, _ = backend.device_recurrence(
            mp, mcfg, x.astype(jnp.float32) / s, jax.random.PRNGKey(0))
    else:
        xw = (x.reshape(-1, D) @ p["w_h"].astype(x.dtype)).reshape(B, S, D) \
            + p["b_h"].astype(x.dtype)
        h0 = jnp.zeros((B, D), jnp.float32)
        h_all, _ = kops.miru_scan(xw.astype(jnp.float32),
                                  p["u_h"].astype(jnp.float32), h0,
                                  beta=0.8, lam=0.5)
    return dense(h_all.astype(x.dtype), p["w_out"],
                 quant_mode=cfg.quant_mode)


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_ffn_params(key: jax.Array, cfg: ModelConfig, is_moe: bool) -> dict:
    if is_moe:
        return init_moe(key, cfg)
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": tn(k1, (D, F), D ** -0.5, cfg.dtype),
            "w_up": tn(k2, (D, F), D ** -0.5, cfg.dtype),
            "w_down": tn(k3, (F, D), F ** -0.5, cfg.dtype)}


def init_moe(key, cfg):
    return moe_mod.init_moe_params(key, cfg)


def init_layer_params(key: jax.Array, cfg: ModelConfig, is_ssm: bool,
                      is_moe: bool, cross_attn: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: dict = {"norm1": jnp.ones((D,), cfg.dtype)}
    if is_ssm:
        p["mixer"] = ssm_mod.init_ssm_params(ks[0], cfg)
    elif cfg.mixer == "miru":
        p["mixer"] = init_miru_mixer(ks[0], cfg)
    elif cfg.use_mla:
        p["mixer"] = attn.init_mla_params(ks[0], cfg)
    else:
        p["mixer"] = attn.init_gqa_params(ks[0], cfg)
    if cross_attn:
        p["norm_x"] = jnp.ones((D,), cfg.dtype)
        p["cross"] = attn.init_gqa_params(ks[1], cfg)
    has_ffn = cfg.d_ff > 0 or is_moe
    if has_ffn:
        p["norm2"] = jnp.ones((D,), cfg.dtype)
        p["ffn"] = init_ffn_params(ks[2], cfg, is_moe)
    return p


def layer_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, is_ssm: bool, is_moe: bool,
                  causal: bool = True,
                  memory: Optional[jax.Array] = None,
                  memory_positions: Optional[jax.Array] = None
                  ) -> jax.Array:
    h = rms_norm(x, p["norm1"], cfg.rmsnorm_eps)
    if is_ssm:
        mixed = ssm_mod.mamba2_forward(p["mixer"], cfg, h)
    elif cfg.mixer == "miru":
        mixed = miru_mixer(p["mixer"], cfg, h)
    elif cfg.use_mla:
        mixed = attn.mla_attention(p["mixer"], cfg, h, positions, causal)
    else:
        mixed = attn.gqa_attention(p["mixer"], cfg, h, positions, causal)
    x = x + mixed.astype(x.dtype)
    if memory is not None:
        h = rms_norm(x, p["norm_x"], cfg.rmsnorm_eps)
        x = x + attn.gqa_attention(p["cross"], cfg, h, positions,
                                   causal=False, kv=(memory,),
                                   kv_positions=memory_positions
                                   ).astype(x.dtype)
    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.rmsnorm_eps)
        if is_moe:
            x = x + moe_mod.moe_ffn(p["ffn"], cfg, h).astype(x.dtype)
        else:
            x = x + swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"],
                           p["ffn"]["w_down"], cfg.quant_mode
                           ).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Scanned homogeneous stack
# ---------------------------------------------------------------------------

def _quant_scope(cfg: ModelConfig, n: int):
    """Telemetry scale scope for a scanned stack on a quantized substrate:
    the layer body is traced once but the compiled scan executes it ``n``
    times, so the per-trace meter deltas recorded by the backend's
    ``device_vmm`` hooks must be multiplied by ``n`` (the same protocol
    ``core/continual.py`` uses for its time scan). No-op when the model is
    unquantized or the substrate's telemetry is disabled."""
    if cfg.quant_mode == "none":
        return contextlib.nullcontext()
    from repro.backends import inference_backend
    return inference_backend(cfg.quant_mode).telemetry.scaled(n)


def init_stack(key: jax.Array, cfg: ModelConfig, n_layers: int,
               is_ssm: bool, is_moe: bool, cross_attn: bool = False
               ) -> PyTree:
    keys = jax.random.split(key, n_layers)
    layers = [init_layer_params(k, cfg, is_ssm, is_moe, cross_attn)
              for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def stack_forward(stacked: PyTree, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, is_ssm: bool, is_moe: bool,
                  causal: bool = True, memory=None, memory_positions=None
                  ) -> jax.Array:
    fn = functools.partial(layer_forward, cfg=cfg, positions=positions,
                           is_ssm=is_ssm, is_moe=is_moe, causal=causal,
                           memory=memory,
                           memory_positions=memory_positions)

    def body(carry, layer_p):
        return act_constraint(fn(layer_p, x=carry), "btd"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    n_layers = jax.tree.leaves(stacked)[0].shape[0]
    with _quant_scope(cfg, n_layers):
        x, _ = jax.lax.scan(body, x, stacked)
    return x


def stack_decode(stacked: PyTree, caches: PyTree, cfg: ModelConfig,
                 x: jax.Array, pos: jax.Array, is_ssm: bool,
                 rngs: Optional[jax.Array] = None,
                 cross_kv: Optional[PyTree] = None,
                 enc_len: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, PyTree]:
    """One-token decode through a scanned stack; caches are stacked (L,…)."""

    def body(carry, inp):
        h_in = carry
        layer_p, cache_l, extra = inp
        h = rms_norm(h_in, layer_p["norm1"], cfg.rmsnorm_eps)
        if is_ssm:
            mixed, new_cache = ssm_mod.mamba2_decode(
                layer_p["mixer"], cfg, h, cache_l)
        elif cfg.use_mla:
            mixed, new_cache = attn.mla_decode(
                layer_p["mixer"], cfg, h, cache_l, pos)
        else:
            mixed, new_cache = attn.gqa_decode(
                layer_p["mixer"], cfg, h, cache_l, pos)
        h_in = h_in + mixed.astype(h_in.dtype)
        if cross_kv is not None:
            hq = rms_norm(h_in, layer_p["norm_x"], cfg.rmsnorm_eps)
            hd = cfg.hd()
            B = hq.shape[0]
            q = dense(hq, layer_p["cross"]["wq"]).reshape(
                B, 1, cfg.n_heads, hd)
            k_m, v_m = extra
            k_m = k_m.reshape(B, -1, cfg.n_kv_heads, hd)
            v_m = v_m.reshape(B, -1, cfg.n_kv_heads, hd)
            o = attn.full_attention(q, k_m, v_m, causal=False,
                                    kv_len=enc_len)
            h_in = h_in + dense(o.reshape(B, 1, -1),
                                layer_p["cross"]["wo"]).astype(h_in.dtype)
        if "ffn" in layer_p:
            h = rms_norm(h_in, layer_p["norm2"], cfg.rmsnorm_eps)
            if "router" in layer_p["ffn"]:
                h_in = h_in + moe_mod.moe_ffn(layer_p["ffn"], cfg, h
                                              ).astype(h_in.dtype)
            else:
                f = layer_p["ffn"]
                h_in = h_in + swiglu(h, f["w_gate"], f["w_up"], f["w_down"],
                                     cfg.quant_mode).astype(h_in.dtype)
        return h_in, new_cache

    n_layers = jax.tree.leaves(stacked)[0].shape[0]
    xs = (stacked, caches, cross_kv) if cross_kv is not None \
        else (stacked, caches, jnp.zeros((n_layers,)))
    with _quant_scope(cfg, n_layers):
        x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


# ---------------------------------------------------------------------------
# Hybrid (jamba) superblock
# ---------------------------------------------------------------------------

def init_superblock(key: jax.Array, cfg: ModelConfig) -> dict:
    """One period of ``attn_every`` layers with static slot structure."""
    period = cfg.attn_every
    ks = jax.random.split(key, period)
    return {f"slot{j}": init_layer_params(
        ks[j], cfg, is_ssm=cfg.is_ssm_layer(j), is_moe=cfg.is_moe_layer(j))
        for j in range(period)}


def init_hybrid_stack(key: jax.Array, cfg: ModelConfig) -> PyTree:
    assert cfg.n_layers % cfg.attn_every == 0
    n_super = cfg.n_layers // cfg.attn_every
    keys = jax.random.split(key, n_super)
    blocks = [init_superblock(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def hybrid_forward(stacked: PyTree, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    period = cfg.attn_every

    def body(carry, sb):
        h = carry
        for j in range(period):
            h = layer_forward(sb[f"slot{j}"], cfg, h, positions,
                              is_ssm=cfg.is_ssm_layer(j),
                              is_moe=cfg.is_moe_layer(j))
        return act_constraint(h, "btd"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    n_super = jax.tree.leaves(stacked)[0].shape[0]
    with _quant_scope(cfg, n_super):
        x, _ = jax.lax.scan(body, x, stacked)
    return x


def init_hybrid_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_super = cfg.n_layers // cfg.attn_every
    spec = attn.CacheSpec(batch, max_len, cfg.kv_cache_dtype)
    caches = {}
    for j in range(cfg.attn_every):
        if cfg.is_ssm_layer(j):
            one = ssm_mod.init_ssm_cache(cfg, batch)
        else:
            one = attn.init_kv_cache(cfg, spec)
        caches[f"slot{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_super,) + a.shape)
            .copy() if hasattr(a, "shape") else a, one)
    return caches


def hybrid_decode(stacked: PyTree, caches: dict, cfg: ModelConfig,
                  x: jax.Array, pos: jax.Array
                  ) -> tuple[jax.Array, dict]:
    period = cfg.attn_every

    def body(carry, inp):
        h_in = carry
        sb, cache_sb = inp
        new_cache_sb = {}
        for j in range(period):
            lp = sb[f"slot{j}"]
            h = rms_norm(h_in, lp["norm1"], cfg.rmsnorm_eps)
            if cfg.is_ssm_layer(j):
                mixed, nc = ssm_mod.mamba2_decode(lp["mixer"], cfg, h,
                                                  cache_sb[f"slot{j}"])
            else:
                mixed, nc = attn.gqa_decode(lp["mixer"], cfg, h,
                                            cache_sb[f"slot{j}"], pos)
            new_cache_sb[f"slot{j}"] = nc
            h_in = h_in + mixed.astype(h_in.dtype)
            if "ffn" in lp:
                h = rms_norm(h_in, lp["norm2"], cfg.rmsnorm_eps)
                if "router" in lp["ffn"]:
                    h_in = h_in + moe_mod.moe_ffn(lp["ffn"], cfg, h
                                                  ).astype(h_in.dtype)
                else:
                    f = lp["ffn"]
                    h_in = h_in + swiglu(h, f["w_gate"], f["w_up"],
                                         f["w_down"], cfg.quant_mode
                                         ).astype(h_in.dtype)
        return h_in, new_cache_sb

    n_super = jax.tree.leaves(stacked)[0].shape[0]
    with _quant_scope(cfg, n_super):
        x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches
