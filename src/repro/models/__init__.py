"""LM architecture zoo: every assigned architecture family in pure JAX.

- layers:    RMSNorm, Dense (+WBS quant mode), rotary embeddings.
- attention: GQA (full + chunked-flash), qk-norm, biases, MLA (+absorbed
             decode), KV caches (bf16 / int8 stochastic-quantized).
- moe:       sort-based top-k dispatch with capacity, shared experts.
- ssm:       Mamba-2 SSD (chunked scan) + recurrent decode.
- blocks:    transformer / mamba / hybrid blocks, scanned layer stacks.
- lm:        CausalLM & EncDecLM: init, train loss, prefill, decode.
- frontend:  audio/vision stub embeddings (the assigned [audio]/[vlm]
             entries specify the backbone; frontends are stubs per brief).
"""
from repro.models import attention, blocks, layers, lm, moe, ssm

__all__ = ["attention", "blocks", "layers", "lm", "moe", "ssm"]
