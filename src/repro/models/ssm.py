"""Mamba-2 (SSD — state-space duality) sequence mixer.

Training/prefill: the chunked SSD algorithm (Dao & Gu 2024, §6): intra-chunk
quadratic attention-like term + inter-chunk recurrence over chunk states.
Decode: the linear recurrence h ← dA·h + dBx, one token per step.

Layer I/O follows mamba2: in_proj → [z | x | B | C | dt], depthwise causal
conv over [x|B|C], SSD over heads of size ``ssm_head_dim``, gated RMSNorm,
out_proj.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, rms_norm
from repro.utils import truncated_normal_init as tn


def _dims(cfg: ModelConfig) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return {"d_in": d_in, "nheads": nheads, "ngroups": cfg.ssm_groups,
            "dstate": cfg.ssm_state, "hd": cfg.ssm_head_dim,
            "dconv": cfg.ssm_conv}


def init_ssm_params(key: jax.Array, cfg: ModelConfig) -> dict:
    d = _dims(cfg)
    D = cfg.d_model
    conv_dim = d["d_in"] + 2 * d["ngroups"] * d["dstate"]
    proj_out = 2 * d["d_in"] + 2 * d["ngroups"] * d["dstate"] + d["nheads"]
    ks = jax.random.split(key, 4)
    return {
        "in_proj": tn(ks[0], (D, proj_out), D ** -0.5, cfg.dtype),
        "conv_w": tn(ks[1], (d["dconv"], conv_dim), 0.1, cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, d["nheads"])
                         ).astype(jnp.float32),
        "dt_bias": jnp.zeros((d["nheads"],), jnp.float32),
        "d_skip": jnp.ones((d["nheads"],), jnp.float32),
        "norm": jnp.ones((d["d_in"],), cfg.dtype),
        "out_proj": tn(ks[2], (d["d_in"], D), d["d_in"] ** -0.5, cfg.dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD scan
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """(..., l) → (..., l, l) with out[..., i, j] = Σ_{j<k<=i} x[k],
    −inf above the diagonal (lower-triangular decay matrix)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(l)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD over a full sequence.

    x (b, l, h, p); dt (b, l, h) softplus-ed step; a_log (h,) decay;
    B, C (b, l, g, n) with heads grouped g | h. Returns (y (b,l,h,p),
    final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = x.shape[1]
    c = lp // chunk

    # Chunked views. dA (b, h, c, l): per-step log decay.
    xc = x.reshape(b, c, chunk, h, p)
    dtc = dt.reshape(b, c, chunk, h)
    Bc = B.reshape(b, c, chunk, g, n)
    Cc = C.reshape(b, c, chunk, g, n)
    dA = (-jnp.exp(a_log)[None, None, None, :] * dtc)   # (b,c,l,h) ≤ 0
    dA = jnp.moveaxis(dA, -1, 1)                        # (b,h,c,l)
    dA_cs = jnp.cumsum(dA, axis=-1)

    Br = jnp.repeat(Bc, rep, axis=3)                    # (b,c,l,h,n)
    Cr = jnp.repeat(Cc, rep, axis=3)

    # 1. Intra-chunk (quadratic attention-like) term.
    L = jnp.exp(_segsum(dA))                            # (b,h,c,l,l)
    scores = jnp.einsum("bclhn,bcshn->bhcls", Cr, Br)   # (b,h,c,l,s)
    M = scores * L
    xdt = xc * dtc[..., None]                           # (b,c,l,h,p)
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", M, xdt)

    # 2. Per-chunk final states: decay-to-end ⊗ B ⊗ x.
    decay_end = jnp.exp(dA_cs[..., -1:] - dA_cs)        # (b,h,c,l)
    states = jnp.einsum("bhcl,bclhn,bclhp->bchpn",
                        decay_end, Br, xdt)             # (b,c,h,p,n)

    # 3. Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(dA_cs[..., -1])               # (b,h,c)

    def scan_fn(carry, inp):
        s_prev = carry
        s_new, dec = inp                                # (b,h,p,n),(b,h)
        s = s_new + dec[..., None, None] * s_prev
        return s, s_prev                                # emit state *before*

    init = h0 if h0 is not None else jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 2, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (b,c,h,p,n)

    # 4. State → output within each chunk.
    decay_in = jnp.exp(dA_cs)                           # (b,h,c,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Cr, prev_states.astype(x.dtype), decay_in)
    y = (y_diag + y_off).reshape(b, lp, h, p)[:, :l]
    return y, final.astype(x.dtype)


def ssd_recurrent_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                       a_log: jax.Array, B_t: jax.Array, C_t: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
    """One decode step. state (b,h,p,n); x_t (b,h,p); dt_t (b,h);
    B_t, C_t (b,g,n). Returns (y_t (b,h,p), new_state)."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    rep = h // g
    Br = jnp.repeat(B_t, rep, axis=1)                   # (b,h,n)
    Cr = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(-jnp.exp(a_log)[None, :] * dt_t)       # (b,h)
    dBx = jnp.einsum("bhn,bhp->bhpn", Br, x_t * dt_t[..., None])
    new_state = dA[..., None, None] * state + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cr)
    return y, new_state


# ---------------------------------------------------------------------------
# Full mamba2 layer
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt: jax.Array, d: dict):
    d_in, g, n, nh = d["d_in"], d["ngroups"], d["dstate"], d["nheads"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * g * n]
    dt = zxbcdt[..., -nh:]
    return z, xBC, dt


def mamba2_forward(p: dict, cfg: ModelConfig, u: jax.Array,
                   ) -> jax.Array:
    """u (B, S, D) → (B, S, D). Training/prefill path (chunked SSD)."""
    d = _dims(cfg)
    b, s, _ = u.shape
    zxbcdt = dense(u, p["in_proj"], quant_mode=cfg.quant_mode)
    z, xBC, dt = _split_proj(zxbcdt, d)

    # Depthwise causal conv over [x|B|C].
    w = p["conv_w"]                                     # (dconv, conv_dim)
    pad = jnp.pad(xBC, ((0, 0), (d["dconv"] - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s, :] * w[i][None, None, :]
               for i in range(d["dconv"]))
    xBC = jax.nn.silu(conv + p["conv_b"])

    x = xBC[..., :d["d_in"]].reshape(b, s, d["nheads"], d["hd"])
    Bm = xBC[..., d["d_in"]:d["d_in"] + d["ngroups"] * d["dstate"]
             ].reshape(b, s, d["ngroups"], d["dstate"])
    Cm = xBC[..., d["d_in"] + d["ngroups"] * d["dstate"]:
             ].reshape(b, s, d["ngroups"], d["dstate"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])

    # Ulysses-for-SSM (§Perf iteration 15): the inter-chunk recurrence is
    # sequential along seq — under sequence parallelism GSPMD must gather
    # every chunk state to every device (77 GB/dev at mamba2 train). SSD
    # states are per-head independent, so reshard seq→heads (all-to-all)
    # around the scan and each device runs its heads' full-sequence
    # recurrence locally.
    from repro.distributed.context import act_constraint, ulysses_enabled
    uly = ulysses_enabled(d["nheads"])
    if uly:
        # Pin x seq-sharded first: without the anchor the heads-sharded
        # constraint back-propagates through the conv and gathers the
        # full-sequence conv buffer on every device.
        x = act_constraint(x, "bshd_seq")
        x = act_constraint(x, "bshd")
        dt = act_constraint(dt, "bsh")
        Bm = act_constraint(Bm, "bs__")
        Cm = act_constraint(Cm, "bs__")

    y, _ = ssd_chunked(x, dt.astype(x.dtype), p["a_log"], Bm, Cm)
    if uly:
        y = act_constraint(y, "bshd")
    y = y.astype(u.dtype) + x.astype(u.dtype) \
        * p["d_skip"][None, None, :, None].astype(u.dtype)
    y = y.reshape(b, s, d["d_in"])
    y = rms_norm(y * jax.nn.silu(z.astype(u.dtype)), p["norm"],
                 cfg.rmsnorm_eps)
    return dense(y, p["out_proj"], quant_mode=cfg.quant_mode)


def init_ssm_cache(cfg: ModelConfig, batch: int) -> dict:
    d = _dims(cfg)
    conv_dim = d["d_in"] + 2 * d["ngroups"] * d["dstate"]
    return {
        "conv": jnp.zeros((batch, d["dconv"] - 1, conv_dim), cfg.dtype),
        "ssm": jnp.zeros((batch, d["nheads"], d["hd"], d["dstate"]),
                         jnp.float32),
    }


def mamba2_decode(p: dict, cfg: ModelConfig, u: jax.Array, cache: dict
                  ) -> tuple[jax.Array, dict]:
    """One-token decode. u (B, 1, D)."""
    d = _dims(cfg)
    b = u.shape[0]
    zxbcdt = dense(u[:, 0, :], p["in_proj"], quant_mode=cfg.quant_mode)
    z, xBC, dt = _split_proj(zxbcdt, d)

    conv_buf = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    w = p["conv_w"]
    conv = jnp.einsum("btc,tc->bc", conv_buf, w)
    xBC_t = jax.nn.silu(conv + p["conv_b"])
    new_conv = conv_buf[:, 1:, :]

    x_t = xBC_t[..., :d["d_in"]].reshape(b, d["nheads"], d["hd"])
    B_t = xBC_t[..., d["d_in"]:d["d_in"] + d["ngroups"] * d["dstate"]
                ].reshape(b, d["ngroups"], d["dstate"])
    C_t = xBC_t[..., d["d_in"] + d["ngroups"] * d["dstate"]:
                ].reshape(b, d["ngroups"], d["dstate"])
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])

    y, new_ssm = ssd_recurrent_step(
        cache["ssm"], x_t.astype(jnp.float32), dt_t, p["a_log"],
        B_t.astype(jnp.float32), C_t.astype(jnp.float32))
    y = y.astype(u.dtype) + x_t * p["d_skip"][None, :, None].astype(u.dtype)
    y = y.reshape(b, d["d_in"])
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rmsnorm_eps)
    out = dense(y, p["out_proj"], quant_mode=cfg.quant_mode)
    return out[:, None, :], {"conv": new_conv, "ssm": new_ssm}
