"""Primitive layers shared by every architecture."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import truncated_normal_init


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(dt)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          quant_mode: str = "none") -> jax.Array:
    """Linear layer. Any quant_mode other than "none" resolves through the
    device-backend registry (repro.backends): "wbs" streams int8
    sign-magnitude inputs through the bit-plane crossbar matmul — the M2RU
    crossbar as a deployable quantized execution mode for any projection
    in the zoo — and every registered substrate is likewise a valid mode."""
    if quant_mode != "none":
        from repro.backends import inference_backend
        # One shared inference-specced instance per registered name (see
        # registry.inference_backend): 8-bit quantized drive, no readout
        # ADC, unit weight scale. Stochastic non-idealities are off here
        # because no PRNG key is threaded: reads are the deterministic
        # expectation. Activity is metered on the shared instance's
        # telemetry when enabled.
        backend = inference_backend(quant_mode)
        # Normalize activations into the crossbar's [-1, 1] drive range,
        # run the backend VMM, undo the scale. absmax is a cheap fused
        # reduction.
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
        y = backend.device_vmm((x / s).astype(jnp.float32),
                               w.astype(jnp.float32), tag="dense") * s
        y = y.astype(x.dtype)
    else:
        y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(key: jax.Array, d_in: int, d_out: int, dtype,
               bias: bool = False, stddev: Optional[float] = None) -> dict:
    if stddev is None:
        stddev = d_in ** -0.5
    p = {"w": truncated_normal_init(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x (..., S, H, hd) or (..., S, hd); positions (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                    # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    if x.ndim == ang.ndim + 1:                        # (..., S, H, hd)
        ang = ang[..., None, :]
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, quant_mode: str = "none") -> jax.Array:
    h = jax.nn.silu(dense(x, w_gate, quant_mode=quant_mode)) \
        * dense(x, w_up, quant_mode=quant_mode)
    return dense(h, w_down, quant_mode=quant_mode)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
             b_up=None, b_down=None, quant_mode: str = "none") -> jax.Array:
    h = jax.nn.gelu(dense(x, w_up, b_up, quant_mode=quant_mode))
    return dense(h, w_down, b_down, quant_mode=quant_mode)
