"""Real sequential datasets for the continual-learning scenarios.

The paper's benchmarks run permuted sequential MNIST (row-by-row, 28
steps × 28 features) and split CIFAR-10 on extracted features; the
synthetic stand-ins in :mod:`repro.data.synthetic` preserve the task
geometry for offline CI. This module adds the real streams behind the
same builder signature, with:

  download + cache   stdlib-only (urllib/gzip/tarfile/pickle), sha256
                     pinned per file — a corrupted or tampered download
                     always raises, it never degrades silently.
  offline policy     ``offline=True`` (or ``REPRO_DATA_OFFLINE=1``)
                     skips the network entirely and serves the
                     deterministic surrogate; ``offline=False`` insists
                     on the real bytes (network failure raises);
                     ``offline=None`` — the default — tries the cache,
                     then the network, then *falls back* to the
                     surrogate with a warning, so CI without egress
                     still runs the full scenario matrix.
  surrogate          a deterministic prototype-pool dataset with the
                     real stream's exact shapes and label space, tagged
                     ``source="surrogate"`` so results can never be
                     mistaken for real-data numbers.

The few-shot keyword stream (:func:`make_keyword_fewshot_tasks`) is
generated, not downloaded: variable-length utterances (ragged T) with
per-task decreasing shot counts (ragged n_train) — the stream that
exercises every axis of the :mod:`repro.data.ragged` padding contract.
"""
from __future__ import annotations

import gzip
import hashlib
import os
import pickle
import tarfile
import urllib.error
import urllib.request
import warnings
from pathlib import Path
from typing import Optional

import numpy as np

from repro.data.synthetic import TaskData

__all__ = ["data_root", "load_mnist", "load_cifar10",
           "make_seq_mnist_tasks", "make_seq_cifar10_tasks",
           "make_keyword_fewshot_tasks"]

_MNIST_BASE = "https://storage.googleapis.com/cvdf-datasets/mnist/"
_MNIST_FILES = {
    "train-images-idx3-ubyte.gz":
        "440fcabf73cc546fa21475e81ea370265605f56be210a4024d2ca8f203523609",
    "train-labels-idx1-ubyte.gz":
        "3552534a0a558bbed6aed32b30c495cca23d567ec52cac8be1a0730e8010255c",
    "t10k-images-idx3-ubyte.gz":
        "8d422c7b0a1c1c79245a5bcf07fe86e33eeafee792b84584aec276f5a2dbc4e6",
    "t10k-labels-idx1-ubyte.gz":
        "f7ae60f92e00ec6debd23a6088c31dbd2371eca3ffa0defaefb259924204aec6",
}
_CIFAR_URL = "https://www.cs.toronto.edu/~kriz/cifar-10-python.tar.gz"
_CIFAR_SHA256 = \
    "6d958be074577803d12ecdefd02955f39262c83c16fe9348329d7fe0b5c001ce"


def data_root() -> Path:
    """The dataset cache directory: ``$REPRO_DATA_DIR`` or
    ``~/.cache/repro_data``. Created on first use."""
    root = Path(os.environ.get("REPRO_DATA_DIR",
                               Path.home() / ".cache" / "repro_data"))
    root.mkdir(parents=True, exist_ok=True)
    return root


def _env_offline() -> bool:
    return os.environ.get("REPRO_DATA_OFFLINE", "") not in ("", "0")


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fetch(url: str, sha256: str, dest: Path) -> Path:
    """Return a verified local copy of ``url``, downloading if absent.

    A cached file with the wrong checksum — and a fresh download with
    the wrong checksum — both raise: corruption is never a soft
    failure. Network errors raise ``URLError``/``OSError`` for the
    caller's offline policy to interpret."""
    if dest.exists():
        got = _sha256(dest)
        if got == sha256:
            return dest
        raise ValueError(
            f"checksum mismatch for cached {dest.name}: expected "
            f"{sha256}, got {got}; delete the file to re-download")
    tmp = dest.with_suffix(dest.suffix + ".part")
    with urllib.request.urlopen(url, timeout=60) as r, open(tmp, "wb") as f:
        while True:
            chunk = r.read(1 << 20)
            if not chunk:
                break
            f.write(chunk)
    got = _sha256(tmp)
    if got != sha256:
        tmp.unlink()
        raise ValueError(f"checksum mismatch downloading {url}: expected "
                         f"{sha256}, got {got}")
    tmp.replace(dest)
    return dest


def _surrogate_images(side: int, channels: int, n_classes: int,
                      n_train: int, n_test: int, tag: str
                      ) -> tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    """Deterministic prototype-pool stand-in with the real stream's
    shapes: class prototypes + pixel noise, clipped to [0,1]. Seeded by
    the dataset tag only — every call sees the same pool, like a file
    on disk would be."""
    rng = np.random.default_rng(
        int.from_bytes(hashlib.sha256(tag.encode()).digest()[:8], "big"))
    dim = side * side * channels
    protos = rng.uniform(0.15, 0.85,
                         size=(n_classes, dim)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, n_classes, size=n)
        x = protos[y] + 0.25 * rng.standard_normal((n, dim)).astype(
            np.float32)
        shape = (-1, side, side) if channels == 1 \
            else (-1, side, side, channels)
        return np.clip(x, 0.0, 1.0).reshape(shape), y.astype(np.int32)

    x_tr, y_tr = draw(n_train)
    x_te, y_te = draw(n_test)
    return x_tr, y_tr, x_te, y_te


def _resolve_offline(offline: Optional[bool]) -> Optional[bool]:
    return True if _env_offline() else offline


def _load_real(loader, surrogate, offline: Optional[bool], name: str):
    """Apply the offline policy around a real-data loader."""
    offline = _resolve_offline(offline)
    if offline is True:
        return surrogate() + ("surrogate",)
    try:
        return loader() + ("real",)
    except (urllib.error.URLError, TimeoutError, OSError) as e:
        if offline is False:
            raise
        warnings.warn(
            f"{name} download failed ({e}); serving the deterministic "
            "surrogate dataset (source='surrogate'). Set offline=False "
            "to require real data.", stacklevel=3)
        return surrogate() + ("surrogate",)


def _read_idx_images(path: Path) -> np.ndarray:
    with gzip.open(path, "rb") as f:
        data = f.read()
    n = int.from_bytes(data[4:8], "big")
    rows = int.from_bytes(data[8:12], "big")
    cols = int.from_bytes(data[12:16], "big")
    return np.frombuffer(data, np.uint8, offset=16).reshape(n, rows, cols)


def _read_idx_labels(path: Path) -> np.ndarray:
    with gzip.open(path, "rb") as f:
        data = f.read()
    return np.frombuffer(data, np.uint8, offset=8)


def load_mnist(offline: Optional[bool] = None
               ) -> tuple[np.ndarray, np.ndarray,
                          np.ndarray, np.ndarray, str]:
    """MNIST as float32 [0,1]: ``(x_train (60000,28,28), y_train,
    x_test (10000,28,28), y_test, source)`` where ``source`` is
    ``"real"`` or ``"surrogate"`` (see the module offline policy)."""
    def loader():
        root = data_root() / "mnist"
        root.mkdir(exist_ok=True)
        paths = {name: _fetch(_MNIST_BASE + name, sha, root / name)
                 for name, sha in _MNIST_FILES.items()}
        x_tr = _read_idx_images(paths["train-images-idx3-ubyte.gz"])
        y_tr = _read_idx_labels(paths["train-labels-idx1-ubyte.gz"])
        x_te = _read_idx_images(paths["t10k-images-idx3-ubyte.gz"])
        y_te = _read_idx_labels(paths["t10k-labels-idx1-ubyte.gz"])
        return (x_tr.astype(np.float32) / 255.0, y_tr.astype(np.int32),
                x_te.astype(np.float32) / 255.0, y_te.astype(np.int32))

    def surrogate():
        return _surrogate_images(28, 1, 10, 4096, 1024, "mnist")

    return _load_real(loader, surrogate, offline, "MNIST")


def load_cifar10(offline: Optional[bool] = None
                 ) -> tuple[np.ndarray, np.ndarray,
                            np.ndarray, np.ndarray, str]:
    """CIFAR-10 as float32 [0,1]: ``(x_train (50000,32,32,3), y_train,
    x_test (10000,32,32,3), y_test, source)``."""
    def loader():
        root = data_root()
        tar_path = _fetch(_CIFAR_URL, _CIFAR_SHA256,
                          root / "cifar-10-python.tar.gz")
        xs, ys, xte, yte = [], [], None, None
        with tarfile.open(tar_path, "r:gz") as tar:
            for member in tar.getmembers():
                base = os.path.basename(member.name)
                if base.startswith("data_batch_") or base == "test_batch":
                    d = pickle.load(tar.extractfile(member),
                                    encoding="bytes")
                    x = np.asarray(d[b"data"], np.uint8) \
                        .reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)
                    y = np.asarray(d[b"labels"], np.int32)
                    if base == "test_batch":
                        xte, yte = x, y
                    else:
                        xs.append(x)
                        ys.append(y)
        x_tr = np.concatenate(xs)
        y_tr = np.concatenate(ys)
        return (x_tr.astype(np.float32) / 255.0, y_tr,
                xte.astype(np.float32) / 255.0, yte)

    def surrogate():
        return _surrogate_images(32, 3, 10, 4096, 1024, "cifar10")

    return _load_real(loader, surrogate, offline, "CIFAR-10")


def _subsample(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
               n: int) -> tuple[np.ndarray, np.ndarray]:
    idx = rng.choice(x.shape[0], size=min(n, x.shape[0]), replace=False)
    return x[idx], y[idx]


def make_seq_mnist_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                         n_test: int = 400,
                         offline: Optional[bool] = None) -> list[TaskData]:
    """Permuted *sequential* MNIST on real data: each image is streamed
    row-by-row (28 steps × 28 features) and each task applies a fixed
    random pixel permutation — task 0 is the identity, matching
    :func:`repro.data.synthetic.make_permuted_tasks`' protocol. One
    train/test subsample is drawn per seed and shared by every task, so
    tasks differ only by permutation (the paper's setup)."""
    x_tr, y_tr, x_te, y_te, _src = load_mnist(offline)
    rng = np.random.default_rng(seed)
    x_tr, y_tr = _subsample(rng, x_tr, y_tr, n_train)
    x_te, y_te = _subsample(rng, x_te, y_te, n_test)
    side = x_tr.shape[1]
    dim = side * side
    flat_tr = x_tr.reshape(len(x_tr), dim)
    flat_te = x_te.reshape(len(x_te), dim)
    tasks = []
    for t in range(n_tasks):
        perm = np.arange(dim) if t == 0 else rng.permutation(dim)
        tasks.append(TaskData(
            x_train=flat_tr[:, perm].reshape(-1, side, side),
            y_train=y_tr.copy(),
            x_test=flat_te[:, perm].reshape(-1, side, side),
            y_test=y_te.copy(), task_id=t))
    return tasks


def make_seq_cifar10_tasks(seed: int, n_tasks: int = 5,
                           n_train: int = 1000, n_test: int = 400,
                           offline: Optional[bool] = None
                           ) -> list[TaskData]:
    """Split sequential CIFAR-10 on real data: task t holds classes
    (2t, 2t+1) relabeled to a shared binary head (domain-incremental
    split protocol), each image streamed row-by-row as 32 steps × 96
    features (RGB rows flattened per step)."""
    if n_tasks > 5:
        raise ValueError("split CIFAR-10 supports at most 5 class-pair "
                         f"tasks, got n_tasks={n_tasks}")
    x_tr, y_tr, x_te, y_te, _src = load_cifar10(offline)
    rng = np.random.default_rng(seed)
    tasks = []
    for t in range(n_tasks):
        pair = (2 * t, 2 * t + 1)

        def pick(x, y, n):
            mask = (y == pair[0]) | (y == pair[1])
            xs, ys = _subsample(rng, x[mask], y[mask], n)
            return (xs.reshape(len(xs), 32, 96),
                    (ys == pair[1]).astype(np.int32))

        xtr, ytr = pick(x_tr, y_tr, n_train)
        xte, yte = pick(x_te, y_te, n_test)
        tasks.append(TaskData(xtr, ytr, xte, yte, task_id=t))
    return tasks


def make_keyword_fewshot_tasks(seed: int, n_tasks: int = 4,
                               n_classes: int = 4, feat_dim: int = 20,
                               base_shots: int = 64, n_test: int = 48,
                               min_len: int = 16, max_len: int = 32,
                               n_train: Optional[int] = None,
                               ) -> list[TaskData]:
    """Few-shot continual keyword-spotting-style stream — the ragged
    stress case (on-chip personalization, §VII): task t is "adapt to
    speaker t", with *decreasing* shot counts per task
    (``base_shots // 2**t``, floor 8) and variable utterance lengths in
    [min_len, max_len] — ragged in both n_train and T. Utterances are
    class keyword templates (shared across tasks) plus a per-speaker
    offset, zero-padded to max_len with true lengths recorded, so this
    stream requires a :class:`repro.data.ragged.PadPolicy` to compile.
    Generated deterministically — no download.

    ``n_train`` is the registry's uniform sizing kwarg — an alias for
    ``base_shots`` (task 0's shot count) when given."""
    if n_train is not None:
        base_shots = int(n_train)
    rng = np.random.default_rng(seed)
    templates = rng.uniform(0.2, 0.8,
                            size=(n_classes, max_len, feat_dim)
                            ).astype(np.float32)

    def draw(speaker_delta, n):
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        lengths = rng.integers(min_len, max_len + 1,
                               size=n).astype(np.int32)
        x = np.zeros((n, max_len, feat_dim), np.float32)
        for i in range(n):
            L = lengths[i]
            # Time-stretch the keyword template to this utterance's
            # own length (nearest-frame resample), then speaker-shift.
            src = np.linspace(0, max_len - 1, L).astype(int)
            utt = templates[y[i]][src] + speaker_delta \
                + 0.08 * rng.standard_normal((L, feat_dim)).astype(
                    np.float32)
            x[i, :L] = np.clip(utt, 0.0, 1.0)
        return x, y, lengths

    tasks = []
    for t in range(n_tasks):
        delta = 0.12 * rng.standard_normal(feat_dim).astype(np.float32)
        shots = max(base_shots // (2 ** t), 8)
        xtr, ytr, ltr = draw(delta, shots)
        xte, yte, lte = draw(delta, n_test)
        tasks.append(TaskData(xtr, ytr, xte, yte, task_id=t,
                              train_lengths=ltr, test_lengths=lte))
    return tasks
