"""Deterministic, restart-safe, sharded batch iterator.

Design goals at 1000+ nodes:
  * Determinism: batch content is a pure function of (seed, step) — any
    worker can reconstruct any step, which is what makes checkpoint/restart
    and elastic rescale correct without data-loader state transfer.
  * Sharding: each process materializes only its slice of the global batch
    (process_index/process_count), placed with jax.make_array_from_callback
    onto the data axis of the mesh.
  * Straggler tolerance: because batches are recomputable, a replacement
    worker can join at step s and produce bit-identical data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass
class DataState:
    """The entire pipeline state — one integer. Checkpointable trivially."""
    step: int = 0
    seed: int = 0


def _collate_ragged(rows: Sequence[np.ndarray],
                    pad_to: Optional[int] = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Zero-pad a list of (L_i, ...) rows to one (N, L, ...) array plus
    the (N,) int32 true lengths. ``pad_to`` pins the padded length (a
    fixed compile shape across steps); None pads to the batch max —
    either way the result is a pure function of the rows, so a restored
    batcher re-collates bit-identically."""
    rows = [np.asarray(r) for r in rows]
    lengths = np.array([r.shape[0] for r in rows], np.int32)
    tgt = int(lengths.max()) if pad_to is None else int(pad_to)
    if lengths.max() > tgt:
        raise ValueError(f"ragged row of length {int(lengths.max())} "
                         f"exceeds pad_to={tgt}")
    out = np.zeros((len(rows), tgt) + rows[0].shape[1:], rows[0].dtype)
    for i, r in enumerate(rows):
        out[i, :r.shape[0]] = r
    return out, lengths


class ShardedBatcher:
    """Produces per-step batches deterministically from (seed, step).

    ``gen_fn(rng, step) -> dict[str, np.ndarray]`` builds the *global*
    batch; sharding to devices happens via jax.device_put with the target
    sharding (on a single host this is a plain put; under multi-process it
    would use make_array_from_process_local_data — same call signature).

    Ragged generator outputs — a key whose value is a *list* of
    unequal-length rows — are collated in :meth:`peek`: zero-padded to
    one array plus a ``{key}_lengths`` companion (``pad_to`` pins the
    padded length to a fixed compile shape). Because collation happens
    inside ``peek``, a batcher restored from :meth:`state_dict` replays
    ragged steps bit-identically — the padding is recomputed from the
    regenerated rows, never checkpointed.
    """

    def __init__(self, gen_fn: Callable[[np.random.Generator, int],
                                        dict[str, np.ndarray]],
                 seed: int = 0, sharding: Optional[Any] = None,
                 pad_to: Optional[int] = None):
        self._gen = gen_fn
        self.state = DataState(step=0, seed=seed)
        self._sharding = sharding
        self._pad_to = pad_to

    def peek(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))
        raw = self._gen(rng, step)
        batch: dict[str, np.ndarray] = {}
        for k, v in raw.items():
            if isinstance(v, (list, tuple)):
                batch[k], batch[f"{k}_lengths"] = _collate_ragged(
                    v, self._pad_to)
            else:
                batch[k] = v
        return batch

    def next(self) -> dict[str, Any]:
        batch = self.peek(self.state.step)
        self.state.step += 1
        if self._sharding is not None:
            batch = {k: jax.device_put(v, self._sharding[k]
                                       if isinstance(self._sharding, dict)
                                       else self._sharding)
                     for k, v in batch.items()}
        return batch

    # -- checkpoint integration ------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"step": self.state.step, "seed": self.state.seed}

    def load_state_dict(self, d: dict[str, int]) -> None:
        self.state = DataState(step=int(d["step"]), seed=int(d["seed"]))


def shard_tasks(tasks, n_shards: int, index: int):
    """Per-chip training shard of a task stream (repro.fleet data
    loading): shard ``index`` of ``n_shards`` takes the strided slice
    ``index::n_shards`` of every task's training rows, truncated to
    ``n_train // n_shards`` rows so all shards share one compile shape.
    Shards are pairwise disjoint; test sets are shared untouched (every
    chip evaluates the full protocol). Requires at least one training
    row per shard."""
    from repro.data.synthetic import TaskData
    if not 0 <= index < n_shards:
        raise ValueError(f"shard index {index} out of range for "
                         f"{n_shards} shards")
    out = []
    for t in tasks:
        n = t.x_train.shape[0] // n_shards
        if n == 0:
            raise ValueError(
                f"task {t.task_id} has {t.x_train.shape[0]} training "
                f"rows — fewer than {n_shards} shards")
        sl = slice(index, index + n * n_shards, n_shards)
        out.append(TaskData(
            x_train=t.x_train[sl], y_train=t.y_train[sl],
            x_test=t.x_test, y_test=t.y_test, task_id=t.task_id,
            train_lengths=(None if t.train_lengths is None
                           else t.train_lengths[sl]),
            test_lengths=t.test_lengths, test_valid=t.test_valid))
    return out
