"""Deterministic, restart-safe, sharded batch iterator.

Design goals at 1000+ nodes:
  * Determinism: batch content is a pure function of (seed, step) — any
    worker can reconstruct any step, which is what makes checkpoint/restart
    and elastic rescale correct without data-loader state transfer.
  * Sharding: each process materializes only its slice of the global batch
    (process_index/process_count), placed with jax.make_array_from_callback
    onto the data axis of the mesh.
  * Straggler tolerance: because batches are recomputable, a replacement
    worker can join at step s and produce bit-identical data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataState:
    """The entire pipeline state — one integer. Checkpointable trivially."""
    step: int = 0
    seed: int = 0


class ShardedBatcher:
    """Produces per-step batches deterministically from (seed, step).

    ``gen_fn(rng, step) -> dict[str, np.ndarray]`` builds the *global*
    batch; sharding to devices happens via jax.device_put with the target
    sharding (on a single host this is a plain put; under multi-process it
    would use make_array_from_process_local_data — same call signature).
    """

    def __init__(self, gen_fn: Callable[[np.random.Generator, int],
                                        dict[str, np.ndarray]],
                 seed: int = 0, sharding: Optional[Any] = None):
        self._gen = gen_fn
        self.state = DataState(step=0, seed=seed)
        self._sharding = sharding

    def peek(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))
        return self._gen(rng, step)

    def next(self) -> dict[str, Any]:
        batch = self.peek(self.state.step)
        self.state.step += 1
        if self._sharding is not None:
            batch = {k: jax.device_put(v, self._sharding[k]
                                       if isinstance(self._sharding, dict)
                                       else self._sharding)
                     for k, v in batch.items()}
        return batch

    # -- checkpoint integration ------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"step": self.state.step, "seed": self.state.seed}

    def load_state_dict(self, d: dict[str, int]) -> None:
        self.state = DataState(step=int(d["step"]), seed=int(d["seed"]))
