"""Data pipeline: synthetic + real streams, ragged padding, batching.

- `synthetic`: matched-geometry substitutes (DESIGN.md §8) — permuted-
  prototype sequence streams (28 steps × 28 features, 10 classes), split
  Gaussian-mixture "ResNet-18 feature" streams (512-d), and the further
  continual-learning streams (rotated, noisy-label, gradual drift,
  class-incremental, online streaming) registered in `repro.scenarios`.
- `real`: sequential (row-wise) MNIST and CIFAR-10 adapters with
  checksum-verified download/cache and a deterministic synthetic
  surrogate when offline, plus the few-shot keyword stream.
- `ragged`: the padding contract (`PadPolicy`, `pad_tasks`,
  `eval_masks`, `needs_masked_program`) that lets unequal-shape task
  streams run through the one compiled sweep program under validity
  masks. See docs/data.md.
- `pipeline`: the sharded, deterministic, restart-safe batch iterator
  (LM trainer, streaming scenario) and `shard_tasks` — the per-chip
  fleet data loader.
"""
from repro.data.synthetic import (TaskData, lm_token_batch,
                                  make_class_incremental_tasks,
                                  make_drift_tasks, make_noisy_label_tasks,
                                  make_permuted_tasks, make_rotated_tasks,
                                  make_split_tasks, make_streaming_tasks)
from repro.data.pipeline import (ShardedBatcher, DataState, shard_tasks)
from repro.data.ragged import (PadPolicy, bucket_size, eval_masks,
                               needs_masked_program, pad_tasks)
from repro.data.real import (load_cifar10, load_mnist,
                             make_keyword_fewshot_tasks,
                             make_seq_cifar10_tasks, make_seq_mnist_tasks)

__all__ = ["make_permuted_tasks", "make_split_tasks", "make_rotated_tasks",
           "make_noisy_label_tasks", "make_drift_tasks",
           "make_class_incremental_tasks", "make_streaming_tasks",
           "TaskData", "lm_token_batch", "ShardedBatcher", "DataState",
           "shard_tasks",
           "PadPolicy", "bucket_size", "eval_masks",
           "needs_masked_program", "pad_tasks",
           "load_mnist", "load_cifar10", "make_seq_mnist_tasks",
           "make_seq_cifar10_tasks", "make_keyword_fewshot_tasks"]
