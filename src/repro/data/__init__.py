"""Data pipeline: synthetic generators + deterministic sharded batching.

MNIST / CIFAR-10 are not available offline; `synthetic` provides matched-
geometry substitutes (DESIGN.md §8): permuted-prototype sequence streams
(28 steps × 28 features, 10 classes), split Gaussian-mixture "ResNet-18
feature" streams (512-d), and the additional continual-learning streams
(rotated, noisy-label, gradual drift, class-incremental, online
streaming) registered in `repro.scenarios`. `pipeline` provides the
sharded, deterministic, restart-safe batch iterator used by the LM
trainer and the streaming scenario.
"""
from repro.data.synthetic import (TaskData, lm_token_batch,
                                  make_class_incremental_tasks,
                                  make_drift_tasks, make_noisy_label_tasks,
                                  make_permuted_tasks, make_rotated_tasks,
                                  make_split_tasks, make_streaming_tasks)
from repro.data.pipeline import ShardedBatcher, DataState

__all__ = ["make_permuted_tasks", "make_split_tasks", "make_rotated_tasks",
           "make_noisy_label_tasks", "make_drift_tasks",
           "make_class_incremental_tasks", "make_streaming_tasks",
           "TaskData", "lm_token_batch", "ShardedBatcher", "DataState"]
