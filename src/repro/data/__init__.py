"""Data pipeline: synthetic generators + deterministic sharded batching.

MNIST / CIFAR-10 are not available offline; `synthetic` provides matched-
geometry substitutes (DESIGN.md §8): permuted-prototype sequence streams
(28 steps × 28 features, 10 classes) and split Gaussian-mixture "ResNet-18
feature" streams (512-d), both organized as domain-incremental task
sequences. `pipeline` provides the sharded, deterministic, restart-safe
batch iterator used by the LM trainer.
"""
from repro.data.synthetic import (make_permuted_tasks, make_split_tasks,
                                  TaskData, lm_token_batch)
from repro.data.pipeline import ShardedBatcher, DataState

__all__ = ["make_permuted_tasks", "make_split_tasks", "TaskData",
           "lm_token_batch", "ShardedBatcher", "DataState"]
