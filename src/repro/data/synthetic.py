"""Synthetic datasets with the geometry of the paper's benchmarks.

Permuted-"MNIST": each class c has a prototype image drawn once; examples
are prototype + Gaussian pixel noise, clipped to [0,1]; each *task* applies
a fixed random pixel permutation (the standard permuted-MNIST protocol).
Presented to the RNN row-by-row: 28 time steps × 28 features.

Split-"CIFAR": class prototypes in a 512-d "ResNet-18 feature" space
(the paper extracts features with a pre-trained ResNet-18); tasks are
consecutive class pairs with a shared 2-way output head (domain-incremental
protocol). Features are presented as 16 steps × 32 features.

These preserve the paper's task structure and difficulty knobs (class
overlap via noise scale) without requiring the real datasets offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TaskData:
    """One task's train/test split. x: (N, T, F) float32 in [0,1]; y: (N,)"""
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    task_id: int


def _prototype_dataset(rng: np.random.Generator, n_classes: int, dim: int,
                       n_train: int, n_test: int, noise: float,
                       ) -> tuple[np.ndarray, np.ndarray,
                                  np.ndarray, np.ndarray]:
    protos = rng.uniform(0.15, 0.85, size=(n_classes, dim)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, n_classes, size=n)
        x = protos[y] + noise * rng.standard_normal((n, dim)).astype(
            np.float32)
        return np.clip(x, 0.0, 1.0), y.astype(np.int32)

    x_tr, y_tr = draw(n_train)
    x_te, y_te = draw(n_test)
    return x_tr, y_tr, x_te, y_te


def make_permuted_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                        n_test: int = 400, side: int = 28,
                        n_classes: int = 10, noise: float = 0.25,
                        ) -> list[TaskData]:
    """Domain-incremental permuted-pixel task stream (permuted-MNIST
    protocol, §VI-A). Task 0 is the identity permutation."""
    rng = np.random.default_rng(seed)
    dim = side * side
    x_tr, y_tr, x_te, y_te = _prototype_dataset(
        rng, n_classes, dim, n_train, n_test, noise)
    tasks = []
    for t in range(n_tasks):
        perm = np.arange(dim) if t == 0 else rng.permutation(dim)
        xt = x_tr[:, perm].reshape(-1, side, side)
        xe = x_te[:, perm].reshape(-1, side, side)
        tasks.append(TaskData(xt, y_tr, xe, y_te, task_id=t))
    return tasks


def make_split_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                     n_test: int = 400, feat_dim: int = 512,
                     steps: int = 16, noise: float = 0.35,
                     ) -> list[TaskData]:
    """Split protocol over a feature space: task t = classes (2t, 2t+1)
    relabeled to a shared binary head (domain-incremental split CIFAR-10)."""
    rng = np.random.default_rng(seed)
    n_classes = 2 * n_tasks
    protos = rng.standard_normal((n_classes, feat_dim)).astype(np.float32)
    protos = 0.5 + 0.18 * protos
    feat = feat_dim // steps

    def draw(cls_pair, n):
        y = rng.integers(0, 2, size=n)
        cls = np.asarray(cls_pair)[y]
        x = protos[cls] + noise * rng.standard_normal(
            (n, feat_dim)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0)
        return x.reshape(-1, steps, feat), y.astype(np.int32)

    tasks = []
    for t in range(n_tasks):
        pair = (2 * t, 2 * t + 1)
        x_tr, y_tr = draw(pair, n_train)
        x_te, y_te = draw(pair, n_test)
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    return tasks


# ---------------------------------------------------------------------------
# LM token streams (for the architecture zoo / trainer)
# ---------------------------------------------------------------------------

def lm_token_batch(rng: np.random.Generator, batch: int, seq_len: int,
                   vocab: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic token batch: order-1 structure so the LM loss
    actually decreases (pure uniform tokens give a flat loss surface)."""
    # Low-rank transition structure: token t+1 ~ f(token t) + noise.
    base = rng.integers(0, vocab, size=(batch, 1))
    drift = rng.integers(-7, 8, size=(batch, seq_len))
    toks = (np.cumsum(drift, axis=1) + base) % vocab
    noise_mask = rng.random((batch, seq_len)) < 0.1
    noise = rng.integers(0, vocab, size=(batch, seq_len))
    toks = np.where(noise_mask, noise, toks)
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    mask = np.ones_like(tokens, dtype=np.float32)
    mask[:, -1] = 0.0
    return {"tokens": tokens, "labels": labels, "mask": mask}
