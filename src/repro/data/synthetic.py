"""Synthetic datasets with the geometry of the paper's benchmarks.

Permuted-"MNIST": each class c has a prototype image drawn once; examples
are prototype + Gaussian pixel noise, clipped to [0,1]; each *task* applies
a fixed random pixel permutation (the standard permuted-MNIST protocol).
Presented to the RNN row-by-row: 28 time steps × 28 features.

Split-"CIFAR": class prototypes in a 512-d "ResNet-18 feature" space
(the paper extracts features with a pre-trained ResNet-18); tasks are
consecutive class pairs with a shared 2-way output head (domain-incremental
protocol). Features are presented as 16 steps × 32 features.

These preserve the paper's task structure and difficulty knobs (class
overlap via noise scale) without requiring the real datasets offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TaskData:
    """One task's train/test split. x: (N, T, F) float32 in [0,1]; y: (N,)

    Ragged streams (unequal sequence length or example count across the
    stream — see :mod:`repro.data.ragged`) carry the optional mask
    fields: per-example true sequence lengths for zero-end-padded rows
    (None means every row runs the full T) and the eval validity mask
    for zero-padded test rows that must not enter the metrics. Builders
    of uniform streams leave all three None — the historical contract.
    """
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    task_id: int
    train_lengths: "np.ndarray | None" = None   # (n_train,) int32
    test_lengths: "np.ndarray | None" = None    # (n_test,) int32
    test_valid: "np.ndarray | None" = None      # (n_test,) bool


def _prototype_dataset(rng: np.random.Generator, n_classes: int, dim: int,
                       n_train: int, n_test: int, noise: float,
                       ) -> tuple[np.ndarray, np.ndarray,
                                  np.ndarray, np.ndarray]:
    protos = rng.uniform(0.15, 0.85, size=(n_classes, dim)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, n_classes, size=n)
        x = protos[y] + noise * rng.standard_normal((n, dim)).astype(
            np.float32)
        return np.clip(x, 0.0, 1.0), y.astype(np.int32)

    x_tr, y_tr = draw(n_train)
    x_te, y_te = draw(n_test)
    return x_tr, y_tr, x_te, y_te


def make_permuted_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                        n_test: int = 400, side: int = 28,
                        n_classes: int = 10, noise: float = 0.25,
                        ) -> list[TaskData]:
    """Domain-incremental permuted-pixel task stream (permuted-MNIST
    protocol, §VI-A). Task 0 is the identity permutation."""
    rng = np.random.default_rng(seed)
    dim = side * side
    x_tr, y_tr, x_te, y_te = _prototype_dataset(
        rng, n_classes, dim, n_train, n_test, noise)
    tasks = []
    for t in range(n_tasks):
        perm = np.arange(dim) if t == 0 else rng.permutation(dim)
        xt = x_tr[:, perm].reshape(-1, side, side)
        xe = x_te[:, perm].reshape(-1, side, side)
        tasks.append(TaskData(xt, y_tr, xe, y_te, task_id=t))
    return tasks


def make_split_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                     n_test: int = 400, feat_dim: int = 512,
                     steps: int = 16, noise: float = 0.35,
                     ) -> list[TaskData]:
    """Split protocol over a feature space: task t = classes (2t, 2t+1)
    relabeled to a shared binary head (domain-incremental split CIFAR-10)."""
    rng = np.random.default_rng(seed)
    n_classes = 2 * n_tasks
    protos = rng.standard_normal((n_classes, feat_dim)).astype(np.float32)
    protos = 0.5 + 0.18 * protos
    feat = feat_dim // steps

    def draw(cls_pair, n):
        y = rng.integers(0, 2, size=n)
        cls = np.asarray(cls_pair)[y]
        x = protos[cls] + noise * rng.standard_normal(
            (n, feat_dim)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0)
        return x.reshape(-1, steps, feat), y.astype(np.int32)

    tasks = []
    for t in range(n_tasks):
        pair = (2 * t, 2 * t + 1)
        x_tr, y_tr = draw(pair, n_train)
        x_te, y_te = draw(pair, n_test)
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    return tasks


# ---------------------------------------------------------------------------
# Additional continual-learning streams (repro.scenarios registry)
# ---------------------------------------------------------------------------

def _rotate_images(x: np.ndarray, angle_deg: float) -> np.ndarray:
    """Bilinear rotation of (N, side, side) images about the center.
    Out-of-frame samples read 0 (background). angle 0 is exact identity."""
    if angle_deg == 0.0:
        return x.copy()
    n, side, _ = x.shape
    th = np.deg2rad(angle_deg)
    c, s = np.cos(th), np.sin(th)
    ctr = (side - 1) / 2.0
    rr, cc = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    src_r = c * (rr - ctr) + s * (cc - ctr) + ctr
    src_c = -s * (rr - ctr) + c * (cc - ctr) + ctr
    r0 = np.floor(src_r).astype(np.int64)
    c0 = np.floor(src_c).astype(np.int64)
    fr = (src_r - r0).astype(np.float32)
    fc = (src_c - c0).astype(np.float32)
    out = np.zeros_like(x)
    for dr, dc, w in ((0, 0, (1 - fr) * (1 - fc)), (0, 1, (1 - fr) * fc),
                      (1, 0, fr * (1 - fc)), (1, 1, fr * fc)):
        r = r0 + dr
        col = c0 + dc
        ok = (r >= 0) & (r < side) & (col >= 0) & (col < side)
        rs = np.clip(r, 0, side - 1)
        cs = np.clip(col, 0, side - 1)
        out += (w * ok) * x[:, rs, cs]
    return out


def make_rotated_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                       n_test: int = 400, side: int = 28,
                       n_classes: int = 10, noise: float = 0.25,
                       max_angle: float = 90.0) -> list[TaskData]:
    """Rotated-image domain-incremental stream: one dataset, task t viewed
    under a rotation of t/(n_tasks-1)·max_angle degrees. Task 0 is the
    unrotated identity view (rotated-MNIST protocol)."""
    rng = np.random.default_rng(seed)
    dim = side * side
    x_tr, y_tr, x_te, y_te = _prototype_dataset(
        rng, n_classes, dim, n_train, n_test, noise)
    x_tr = x_tr.reshape(-1, side, side)
    x_te = x_te.reshape(-1, side, side)
    angles = (np.linspace(0.0, max_angle, n_tasks) if n_tasks > 1
              else np.zeros(1))
    tasks = []
    for t, ang in enumerate(angles):
        tasks.append(TaskData(_rotate_images(x_tr, float(ang)), y_tr,
                              _rotate_images(x_te, float(ang)), y_te,
                              task_id=t))
    return tasks


def make_noisy_label_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                           n_test: int = 400, side: int = 28,
                           n_classes: int = 10, noise: float = 0.25,
                           max_flip: float = 0.4) -> list[TaskData]:
    """Label-noise robustness stream: a fixed domain whose *train* labels
    are corrupted at a rate ramping 0 → max_flip across tasks (flipped
    uniformly to another class). Test labels stay clean, so R[t, i] reads
    how well learning survives increasingly unreliable supervision."""
    rng = np.random.default_rng(seed)
    dim = side * side
    rates = (np.linspace(0.0, max_flip, n_tasks) if n_tasks > 1
             else np.zeros(1))
    protos = rng.uniform(0.15, 0.85, size=(n_classes, dim)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, n_classes, size=n)
        x = protos[y] + noise * rng.standard_normal((n, dim)).astype(
            np.float32)
        return np.clip(x, 0.0, 1.0).reshape(-1, side, side), \
            y.astype(np.int32)

    tasks = []
    for t, rate in enumerate(rates):
        x_tr, y_tr = draw(n_train)
        x_te, y_te = draw(n_test)
        flip = rng.random(n_train) < rate
        shift = rng.integers(1, n_classes, size=n_train).astype(np.int32)
        y_noisy = np.where(flip, (y_tr + shift) % n_classes, y_tr)
        tasks.append(TaskData(x_tr, y_noisy.astype(np.int32), x_te, y_te,
                              task_id=t))
    return tasks


def make_drift_tasks(seed: int, n_tasks: int = 5, n_train: int = 1000,
                     n_test: int = 400, side: int = 28,
                     n_classes: int = 10, noise: float = 0.25
                     ) -> list[TaskData]:
    """Gradual domain drift: class prototypes interpolate linearly from a
    start set to an independently drawn end set across the task sequence —
    task t samples around protos_t = (1−α_t)·A + α_t·B, α_t = t/(n−1).
    Neighboring tasks overlap heavily; distant tasks do not."""
    rng = np.random.default_rng(seed)
    dim = side * side
    protos_a = rng.uniform(0.15, 0.85, (n_classes, dim)).astype(np.float32)
    protos_b = rng.uniform(0.15, 0.85, (n_classes, dim)).astype(np.float32)
    alphas = (np.linspace(0.0, 1.0, n_tasks) if n_tasks > 1
              else np.zeros(1))

    tasks = []
    for t, a in enumerate(alphas):
        protos = ((1.0 - a) * protos_a + a * protos_b).astype(np.float32)

        def draw(n):
            y = rng.integers(0, n_classes, size=n)
            x = protos[y] + noise * rng.standard_normal((n, dim)).astype(
                np.float32)
            return np.clip(x, 0.0, 1.0).reshape(-1, side, side), \
                y.astype(np.int32)

        x_tr, y_tr = draw(n_train)
        x_te, y_te = draw(n_test)
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    return tasks


def make_class_incremental_tasks(seed: int, n_tasks: int = 5,
                                 n_train: int = 1000, n_test: int = 400,
                                 side: int = 28, classes_per_task: int = 2,
                                 noise: float = 0.25,
                                 imbalance: float = 1.0) -> list[TaskData]:
    """Class-incremental stream with a (logically) expanding head: task t
    introduces classes [t·c, (t+1)·c) with *global* labels over the full
    n_tasks·c-way output. The model allocates the full head up front (the
    standard compiled-friendly realization of head expansion — unseen
    logits just stay untrained), so shapes are scan-uniform.

    ``imbalance`` > 1 makes the stream class-imbalanced: task t carries
    ``n_train · imbalance^t`` train examples (test sets stay equal), so
    late classes flood any frequency-weighted rehearsal buffer — the
    regime where the *choice* of replay policy governs forgetting
    (class-balanced reservoirs keep early classes represented). Note an
    imbalanced stream is no longer shape-uniform, so the compiled
    scan-over-tasks falls back to the per-task loop."""
    rng = np.random.default_rng(seed)
    dim = side * side
    n_classes = classes_per_task * n_tasks
    protos = rng.uniform(0.15, 0.85, (n_classes, dim)).astype(np.float32)

    tasks = []
    for t in range(n_tasks):
        lo = t * classes_per_task

        def draw(n):
            y = lo + rng.integers(0, classes_per_task, size=n)
            x = protos[y] + noise * rng.standard_normal((n, dim)).astype(
                np.float32)
            return np.clip(x, 0.0, 1.0).reshape(-1, side, side), \
                y.astype(np.int32)

        x_tr, y_tr = draw(int(round(n_train * imbalance ** t)))
        x_te, y_te = draw(n_test)
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    return tasks


def make_streaming_tasks(seed: int, n_tasks: int = 6, n_train: int = 256,
                         n_test: int = 128, side: int = 28,
                         n_classes: int = 10, noise: float = 0.25
                         ) -> list[TaskData]:
    """Online single-pass streaming regime: a continuous example stream
    chopped into ``n_tasks`` segments, each under a fresh pixel
    permutation. Every batch is a pure function of (seed, step) — built
    through :class:`repro.data.pipeline.ShardedBatcher` — so any segment
    is restart-safe and bit-reproducible. The scenario registry marks this
    stream single-pass: the sweep trains one epoch per segment regardless
    of the trainer's ``epochs_per_task``."""
    from repro.data.pipeline import ShardedBatcher

    rng = np.random.default_rng(seed)
    dim = side * side
    protos = rng.uniform(0.15, 0.85, (n_classes, dim)).astype(np.float32)
    perms = np.stack([np.arange(dim)] + [rng.permutation(dim)
                                         for _ in range(n_tasks - 1)])
    chunk = 64
    steps_train = -(-n_train // chunk)          # ceil
    steps_test = -(-n_test // chunk)
    steps_per_seg = steps_train + steps_test

    def gen(step_rng: np.random.Generator, step: int
            ) -> dict[str, np.ndarray]:
        seg = step // steps_per_seg
        y = step_rng.integers(0, n_classes, size=chunk)
        x = protos[y] + noise * step_rng.standard_normal(
            (chunk, dim)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0)[:, perms[seg]]
        return {"x": x.reshape(-1, side, side), "y": y.astype(np.int32)}

    batcher = ShardedBatcher(gen, seed=seed)
    tasks = []
    for t in range(n_tasks):
        base = t * steps_per_seg
        tr = [batcher.peek(base + i) for i in range(steps_train)]
        te = [batcher.peek(base + steps_train + i)
              for i in range(steps_test)]
        x_tr = np.concatenate([b["x"] for b in tr])[:n_train]
        y_tr = np.concatenate([b["y"] for b in tr])[:n_train]
        x_te = np.concatenate([b["x"] for b in te])[:n_test]
        y_te = np.concatenate([b["y"] for b in te])[:n_test]
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    return tasks


# ---------------------------------------------------------------------------
# LM token streams (for the architecture zoo / trainer)
# ---------------------------------------------------------------------------

def lm_token_batch(rng: np.random.Generator, batch: int, seq_len: int,
                   vocab: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic token batch: order-1 structure so the LM loss
    actually decreases (pure uniform tokens give a flat loss surface)."""
    # Low-rank transition structure: token t+1 ~ f(token t) + noise.
    base = rng.integers(0, vocab, size=(batch, 1))
    drift = rng.integers(-7, 8, size=(batch, seq_len))
    toks = (np.cumsum(drift, axis=1) + base) % vocab
    noise_mask = rng.random((batch, seq_len)) < 0.1
    noise = rng.integers(0, vocab, size=(batch, seq_len))
    toks = np.where(noise_mask, noise, toks)
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    mask = np.ones_like(tokens, dtype=np.float32)
    mask[:, -1] = 0.0
    return {"tokens": tokens, "labels": labels, "mask": mask}
