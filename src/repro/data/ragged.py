"""Ragged task streams: the bucketed padding policy and its helpers.

The compiled sweep scans a stacked ``(n_tasks, S, B, T, F)`` schedule, so
historically every task had to share one ``(n_train, n_test, T)`` shape.
A :class:`PadPolicy` lifts that restriction: builders may emit tasks of
unequal example counts and unequal sequence length, and the sweep pads
them onto one bucketed shape with validity masks — masked loss/metric
reduction, replay insertion gated on valid rows, telemetry metered only
for real steps (see docs/data.md for the full contract).

Three granularities of padding, each with its own mask:

  time      per-example true lengths (``TaskData.train_lengths`` /
            ``test_lengths``); sequences are zero-padded at the end to
            the bucketed T. The recurrence is causal, so end-padding
            never changes the states at t < length; the readout and the
            DFA error are taken at each row's own last step.
  row       the final partial batch (``last_batch="pad"``) and unequal
            eval sets pad with zero rows marked invalid
            (``row_valid`` on the schedule, ``test_valid`` on the task).
  step      tasks with fewer batches than the longest pad the scan's
            step axis with no-op steps (``step_valid``) whose results
            are discarded by the carry select.

The hard contract: with a policy attached but nothing actually ragged,
:func:`repro.scenarios.sweep.run_compiled` builds the exact pre-refactor
program — bitwise-identical R/params/losses/telemetry, gated in
benchmarks/data_bench.py. The masked program (``force=True`` or real
raggedness) is a *different* compiled program; it is held to the repo's
established loop-vs-compiled standard (R matrices exactly equal, losses
within float32 ulp-level tolerance) and agrees with the unmasked
program on aligned streams at the same ulp level — XLA fuses the
runtime validity-mask multiplies into the reductions, which legally
reassociates the accumulation by ±1 ulp, so exact bit-equality across
*different programs* is not promised (only across runs of the same
program, which stay deterministic).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.synthetic import TaskData

__all__ = ["PadPolicy", "pad_tasks", "bucket_size", "eval_masks",
           "needs_masked_program"]


@dataclasses.dataclass(frozen=True)
class PadPolicy:
    """How a ragged task stream maps onto one compiled shape.

    bucket      "max" pads every axis to the stream's maximum; "pow2"
                rounds the targets up to the next power of two (fewer
                recompiles when streams grow across runs).
    last_batch  what happens to the final partial training batch of a
                task whose ``n_train`` does not divide the batch size:
                "drop" discards it (the historical behavior) and "pad"
                keeps it, zero-padded with the pad rows marked invalid.
    force       build the masked program even when the stream is already
                shape-aligned — the parity-testing knob.
    """
    bucket: str = "max"        # "max" | "pow2"
    last_batch: str = "drop"   # "drop" | "pad"
    force: bool = False

    def __post_init__(self):
        if self.bucket not in ("max", "pow2"):
            raise ValueError(f"unknown bucket mode {self.bucket!r}; "
                             "expected 'max' or 'pow2'")
        if self.last_batch not in ("drop", "pad"):
            raise ValueError(f"unknown last_batch mode {self.last_batch!r}; "
                             "expected 'drop' or 'pad'")


def bucket_size(n: int, mode: str) -> int:
    """The padded target for an axis of true size ``n``."""
    if mode == "max":
        return int(n)
    return 1 << max(0, int(n - 1).bit_length())


def _pad_time(x: np.ndarray, lengths: Optional[np.ndarray], t_tgt: int
              ) -> tuple[np.ndarray, Optional[np.ndarray]]:
    """Zero-pad (N, T, F) to (N, t_tgt, F); propagate true lengths."""
    n, t = x.shape[:2]
    if t == t_tgt:
        return x, (None if lengths is None
                   else np.asarray(lengths, np.int32))
    out = np.zeros((n, t_tgt) + x.shape[2:], x.dtype)
    out[:, :t] = x
    if lengths is None:
        lengths = np.full(n, t, np.int32)
    return out, np.asarray(lengths, np.int32)


def pad_tasks(tasks: list[TaskData], policy: PadPolicy
              ) -> tuple[list[TaskData], bool]:
    """Pad a task stream onto one bucketed (T, n_test) shape.

    Returns ``(padded_tasks, padded)`` where ``padded`` says whether any
    time or eval-row padding was actually applied (or any input task
    already carried lengths/validity masks) — the signal
    :func:`repro.scenarios.sweep.run_compiled` uses to pick the masked
    program. Training-row raggedness (unequal ``n_train``) is handled at
    schedule level, not here.
    """
    t_tgt = bucket_size(max(max(t.x_train.shape[1], t.x_test.shape[1])
                            for t in tasks), policy.bucket)
    ne_tgt = bucket_size(max(t.x_test.shape[0] for t in tasks),
                         policy.bucket)
    padded = False
    out = []
    for t in tasks:
        xtr, ltr = _pad_time(np.asarray(t.x_train), t.train_lengths, t_tgt)
        xte, lte = _pad_time(np.asarray(t.x_test), t.test_lengths, t_tgt)
        yte = np.asarray(t.y_test)
        ne = xte.shape[0]
        valid = (np.asarray(t.test_valid, bool) if t.test_valid is not None
                 else None)
        if ne < ne_tgt:
            pad = ne_tgt - ne
            xte = np.concatenate(
                [xte, np.zeros((pad,) + xte.shape[1:], xte.dtype)])
            yte = np.concatenate([yte, np.zeros(pad, yte.dtype)])
            if valid is None:
                valid = np.ones(ne, bool)
            valid = np.concatenate([valid, np.zeros(pad, bool)])
            if lte is None:
                lte = np.full(ne, t_tgt, np.int32)
            # Pad rows gather h at index 0 — any in-range index works,
            # the row is masked out of the metric.
            lte = np.concatenate([lte, np.ones(pad, np.int32)])
        padded |= (ltr is not None or lte is not None
                   or valid is not None)
        out.append(TaskData(x_train=xtr, y_train=np.asarray(t.y_train),
                            x_test=xte, y_test=yte, task_id=t.task_id,
                            train_lengths=ltr, test_lengths=lte,
                            test_valid=valid))
    return out, bool(padded)


def needs_masked_program(policy: PadPolicy, eval_padded: bool,
                         schedule) -> bool:
    """Whether a padded run must build the masked program: forced, any
    eval padding, any schedule row/length mask, or a ragged step count
    across tasks. False means nothing was actually ragged and the exact
    pre-refactor (unmasked) program runs — the bitwise-identity
    guarantee. One predicate shared by :func:`run_continual` and
    :func:`run_compiled` so the loop and the compiled sweep always make
    the same choice."""
    return bool(policy.force or eval_padded or schedule.has_masks
                or len(set(schedule.steps_per_task)) > 1)


def eval_masks(tasks: list[TaskData]) -> tuple[np.ndarray, np.ndarray]:
    """Stacked eval validity/lengths for the masked program:
    ``(n_tasks, n_test) bool`` and ``(n_tasks, n_test) int32``."""
    valid, length = [], []
    for t in tasks:
        ne, T = t.x_test.shape[:2]
        valid.append(np.ones(ne, bool) if t.test_valid is None
                     else np.asarray(t.test_valid, bool))
        length.append(np.full(ne, T, np.int32) if t.test_lengths is None
                      else np.asarray(t.test_lengths, np.int32))
    return np.stack(valid), np.stack(length)
