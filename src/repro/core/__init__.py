"""The paper's primary contribution.

- miru:      Minion Recurrent Unit (eqs. 1-3) — gate-free GRU variant.
- dfa:       Direct Feedback Alignment through time (Algorithm 1).
- kwta:      K-winner-take-all (the paper's ζ sparsifier / softmax approx).
- replay:    reservoir sampler (xorshift32) + stochastic quantizer + buffer.
- continual: domain-incremental continual-learning trainer (Fig. 4 protocol).
"""
from repro.core.miru import (MiRUConfig, init_miru_params, init_dfa_feedback,
                             miru_forward, miru_apply_readout)
from repro.core.kwta import kwta, kwta_mask
from repro.core.replay import (ReservoirSampler, Xorshift32, ReplayBuffer,
                               code_dtype, stochastic_quantize,
                               uniform_quantize, dequantize,
                               round_trip_bound)
from repro.core.dfa import (dfa_grads, bptt_grads, miru_loss,
                            grad_alignment)
from repro.core.continual import (BatchSchedule, ContinualConfig,
                                  ReplaySpec, TrainerSpec,
                                  build_batch_schedule,
                                  miru_forward_device, run_continual,
                                  evaluate_tasks)

__all__ = [
    "MiRUConfig", "init_miru_params", "init_dfa_feedback", "miru_forward",
    "miru_apply_readout", "kwta", "kwta_mask", "ReservoirSampler",
    "Xorshift32", "ReplayBuffer", "code_dtype", "stochastic_quantize",
    "uniform_quantize", "dequantize", "round_trip_bound",
    "dfa_grads", "bptt_grads", "miru_loss", "grad_alignment",
    "ContinualConfig", "TrainerSpec", "ReplaySpec", "BatchSchedule",
    "build_batch_schedule", "miru_forward_device", "run_continual",
    "evaluate_tasks",
]
