"""Minion Recurrent Unit (MiRU) — the paper's cell, eqs. (1)-(3).

MiRU replaces GRU's *learned* update/reset gates with two scalar
hyper-parameter coefficients:

    h̃ᵗ = tanh(xᵗ W_h + (β ⊙ hᵗ⁻¹) U_h + b_h)          (1)
    hᵗ  = λ ⊙ hᵗ⁻¹ + (1 − λ) ⊗ h̃ᵗ                     (2)
    ŷᵗ  = softmax(hᵗ W_o + b_o)                         (3)

β (reset): larger → retain more history inside the candidate computation.
λ (update): larger → stronger reliance on the previous hidden state.

This module is pure-functional JAX. The fused Pallas path
(`kernels.ops.miru_scan`) implements the identical recurrence with the
time loop carried in VMEM scratch; `use_fused=True` dispatches to it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.utils import glorot_uniform, normal_init


@dataclasses.dataclass(frozen=True)
class MiRUConfig:
    """Configuration of a (input → MiRU hidden → readout) network."""
    n_x: int                  # input features per time step
    n_h: int                  # hidden MiRU units
    n_y: int                  # readout classes
    beta: float = 0.8         # reset coefficient β ∈ (0, 1]
    lam: float = 0.5          # update coefficient λ ∈ [0, 1)
    dtype: Any = jnp.float32
    # K-WTA readout (the voltage-mode circuit approximating softmax). When
    # None the readout is a plain softmax (used by the software models).
    readout_k: Optional[int] = None

    def __post_init__(self):
        if not (0.0 < self.beta <= 1.0):
            raise ValueError(f"beta must be in (0,1], got {self.beta}")
        if not (0.0 <= self.lam < 1.0):
            raise ValueError(f"lam must be in [0,1), got {self.lam}")


def init_miru_params(key: jax.Array, cfg: MiRUConfig) -> dict[str, jax.Array]:
    """Trainable parameters. Glorot for matrices, zeros for biases."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_h": glorot_uniform(k1, (cfg.n_x, cfg.n_h), cfg.dtype),
        "u_h": glorot_uniform(k2, (cfg.n_h, cfg.n_h), cfg.dtype),
        "b_h": jnp.zeros((cfg.n_h,), cfg.dtype),
        "w_o": glorot_uniform(k3, (cfg.n_h, cfg.n_y), cfg.dtype),
        "b_o": jnp.zeros((cfg.n_y,), cfg.dtype),
    }


def init_dfa_feedback(key: jax.Array, cfg: MiRUConfig,
                      scale: Optional[float] = None) -> jax.Array:
    """Fixed random feedback matrix Ψ ∈ R^{n_y × n_h} (Algorithm 1, line 13).

    Ψ is *not* trained; it projects the output error onto the hidden layer.
    Scale follows the DFA literature: 1/sqrt(n_y) keeps the projected error
    magnitude comparable to the true gradient.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(cfg.n_y).astype(jnp.float32)
    return normal_init(key, (cfg.n_y, cfg.n_h), float(scale), cfg.dtype)


def miru_cell(params: dict[str, jax.Array], cfg: MiRUConfig,
              h_prev: jax.Array, x_t: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """One MiRU step. Returns (h_t, preact_t).

    preact (the tanh argument) is returned because DFA needs tanh′(preact).
    """
    pre = x_t @ params["w_h"] + (cfg.beta * h_prev) @ params["u_h"] \
        + params["b_h"]
    h_tilde = jnp.tanh(pre)
    h_t = cfg.lam * h_prev + (1.0 - cfg.lam) * h_tilde
    return h_t, pre


def miru_forward(params: dict[str, jax.Array], cfg: MiRUConfig,
                 x_seq: jax.Array, h0: Optional[jax.Array] = None,
                 use_fused: bool = False,
                 ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Run the full recurrence over a batch of sequences.

    Args:
      x_seq: (B, T, n_x).
      h0:    (B, n_h) initial state, zeros if None.
      use_fused: dispatch the recurrence to the Pallas `miru_scan` kernel.

    Returns:
      logits (B, n_y) from the *final* hidden state (the paper's readout
      uses h^{n_T} only), and a dict of intermediates for training:
        h_all   (B, T, n_h)  hidden states h¹..h^T
        h_prev  (B, T, n_h)  h⁰..h^{T-1} (inputs to each step)
        pre     (B, T, n_h)  tanh pre-activations
    """
    B, T, _ = x_seq.shape
    if h0 is None:
        h0 = jnp.zeros((B, cfg.n_h), cfg.dtype)

    if use_fused:
        from repro.kernels import ops as kops
        # Pre-compute the input projection as one big matmul (MXU-friendly),
        # then run the fused recurrence kernel over time.
        xw = x_seq.reshape(B * T, cfg.n_x) @ params["w_h"]
        xw = xw.reshape(B, T, cfg.n_h) + params["b_h"]
        h_all, pre = kops.miru_scan(xw, params["u_h"], h0,
                                    beta=cfg.beta, lam=cfg.lam)
        h_prev = jnp.concatenate([h0[:, None, :], h_all[:, :-1, :]], axis=1)
    else:
        def step(h, x_t):
            h_new, pre = miru_cell(params, cfg, h, x_t)
            return h_new, (h_new, h, pre)

        _, (h_all, h_prev, pre) = jax.lax.scan(
            step, h0, jnp.swapaxes(x_seq, 0, 1))
        h_all = jnp.swapaxes(h_all, 0, 1)
        h_prev = jnp.swapaxes(h_prev, 0, 1)
        pre = jnp.swapaxes(pre, 0, 1)

    logits = miru_apply_readout(params, cfg, h_all[:, -1, :])
    return logits, {"h_all": h_all, "h_prev": h_prev, "pre": pre}


def miru_apply_readout(params: dict[str, jax.Array], cfg: MiRUConfig,
                       h: jax.Array) -> jax.Array:
    """Readout logits. With readout_k set, emulate the voltage-mode k-WTA
    circuit: only the k largest logits survive (others pinned to a large
    negative value so softmax ≈ 0), matching the hardware's approximate
    softmax."""
    logits = h @ params["w_o"] + params["b_o"]
    if cfg.readout_k is not None and cfg.readout_k < cfg.n_y:
        from repro.core.kwta import kwta_mask
        mask = kwta_mask(logits, cfg.readout_k, by_magnitude=False)
        logits = jnp.where(mask, logits, jnp.full_like(logits, -30.0))
    return logits


def miru_param_count(cfg: MiRUConfig) -> int:
    """Trainable parameter count (excludes the fixed Ψ)."""
    return (cfg.n_x * cfg.n_h + cfg.n_h * cfg.n_h + cfg.n_h
            + cfg.n_h * cfg.n_y + cfg.n_y)


def gru_param_count(n_x: int, n_h: int, n_y: int) -> int:
    """Reference GRU parameter count (3 gates) for the compactness claim."""
    return 3 * (n_x * n_h + n_h * n_h + n_h) + n_h * n_y + n_y
