"""Hardware experience-replay pipeline (§IV-A): reservoir sampler,
stochastic quantizer, replay buffer.

The paper's data-preparation unit is digital host-side logic (counter,
xorshift32, modulus unit, LFSR-driven stochastic rounder). It is reproduced
here bit-faithfully in numpy for the host path, plus vectorized jnp versions
of the quantizers for the in-graph replay path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Xorshift32 — the paper's RNG (chosen over LFSR for unbiased indices)
# ---------------------------------------------------------------------------

class Xorshift32:
    """32-bit xorshift PRNG (Marsaglia), exactly the 13/17/5 hardware circuit.

    Produces decorrelated, uniform *words* — the property the paper relies
    on for equal-probability reservoir sampling (unlike an LFSR, whose
    maximal sequence never emits 0 and is correlated between taps).

    ``randint`` reduces a word to a range. The hardware-faithful default
    (``mode="modulus"``, the paper's modulus unit) carries modulo bias
    when the span does not divide 2^32: each value's probability deviates
    from 1/span by at most 2^-32 in absolute terms, but residues below
    ``2^32 mod span`` are overweighted by the factor
    ``ceil(2^32/span)/floor(2^32/span)`` — approaching 2× for spans near
    2^32 (quantified in tests/test_replay.py). ``mode="reject"`` draws
    words until one falls below the largest multiple of the span — exactly
    uniform, at the cost of a variable number of RNG steps, so it walks a
    *different* bit-stream and must not be enabled under seeds that
    hardware-equivalence tests pin.
    """

    def __init__(self, seed: int = 0x9E3779B9, mode: str = "modulus"):
        if mode not in ("modulus", "reject"):
            raise ValueError(f"unknown randint mode {mode!r}; expected "
                             "'modulus' (hardware-faithful) or 'reject' "
                             "(unbiased)")
        seed = np.uint32(seed if seed != 0 else 0xDEADBEEF)
        self.state = np.uint32(seed)
        self.mode = mode

    def next(self) -> int:
        x = self.state
        with np.errstate(over="ignore"):
            x = np.uint32(x ^ np.uint32(x << np.uint32(13)))
            x = np.uint32(x ^ np.uint32(x >> np.uint32(17)))
            x = np.uint32(x ^ np.uint32(x << np.uint32(5)))
        self.state = x
        return int(x)

    def randint(self, lo: int, hi: int) -> int:
        """Int in [lo, hi]: the paper's modulus unit by default (modulo
        bias ≤ 2^-32 per value — see the class docstring), or unbiased
        rejection sampling when constructed with ``mode="reject"``."""
        span = hi - lo + 1
        if self.mode == "reject":
            limit = (1 << 32) - ((1 << 32) % span)
            x = self.next()
            while x >= limit:
                x = self.next()
            return lo + x % span
        return lo + self.next() % span


# ---------------------------------------------------------------------------
# Stochastic quantizer (eqs. 4-6)
# ---------------------------------------------------------------------------

def stochastic_quantize(x: jax.Array, key: jax.Array, n_bits: int
                        ) -> jax.Array:
    """Quantize x∈[0,1] to n_bits integer codes with stochastic rounding.

        z  = x · 2^{n_b}
        q  = ⌊z⌋ + 1   if r < frac(z) and ⌊z⌋ < 2^{n_b} − 1
             ⌊z⌋       otherwise,   r ~ U(0,1)

    Unbiased away from the top code: for x ≤ 1 − 2^{−n_b},
    E[dequantize(q)] == x exactly. Codes saturate at 2^{n_b} − 1 while
    :func:`dequantize` divides by 2^{n_b} (the hardware's n-bit right
    shift), so inputs in the clip region (1 − 2^{−n_b}, 1] come back
    pinned at 1 − 2^{−n_b} — a stored 1.0 pixel is always replayed one
    LSB dim. :func:`round_trip_bound` exposes the worst-case error.
    """
    z = x * (2.0 ** n_bits)
    fl = jnp.floor(z)
    frac = z - fl
    r = jax.random.uniform(key, x.shape)
    top = 2.0 ** n_bits - 1.0
    q = jnp.where((r < frac) & (fl < top), fl + 1.0, fl)
    return jnp.clip(q, 0.0, top).astype(jnp.uint8 if n_bits <= 8
                                        else jnp.uint16)


def uniform_quantize(x: jax.Array, n_bits: int) -> jax.Array:
    """Plain truncation quantizer (the baseline in Fig. 5a)."""
    z = jnp.floor(x * (2.0 ** n_bits))
    top = 2.0 ** n_bits - 1.0
    return jnp.clip(z, 0.0, top).astype(jnp.uint8 if n_bits <= 8
                                        else jnp.uint16)


def dequantize(q: jax.Array, n_bits: int, dtype=jnp.float32) -> jax.Array:
    """Codes → [0, 1): the paper-faithful 1/2^{n_b} scale (an n-bit right
    shift in RTL). Because codes saturate at 2^{n_b} − 1, the top of the
    dequantized range is 1 − 2^{−n_b}, not 1.0 — see
    :func:`round_trip_bound`."""
    return q.astype(dtype) / (2.0 ** n_bits)


def round_trip_bound(n_bits: int) -> float:
    """Worst-case |E[dequantize(stochastic_quantize(x))] − x| over
    x ∈ [0, 1].

    The stochastic rounder is exactly unbiased on x ≤ 1 − 2^{−n_b}; in
    the clip region (1 − 2^{−n_b}, 1] the expectation is pinned at
    1 − 2^{−n_b}, so the error grows linearly to its maximum 2^{−n_b}
    at x = 1.0. Scaling dequantization by 1/(2^{n_b} − 1) instead would
    remove the clip but is *not* what the chip's shift-based datapath
    computes — the repro keeps the paper-faithful scale and documents
    the bound (pinned by a property test in tests/test_replay.py).
    """
    return 2.0 ** -n_bits


def code_dtype(n_bits: int) -> np.dtype:
    """Storage dtype for n_bits codes: uint8 holds up to 8-bit codes,
    uint16 up to 16 — matching what the quantizers emit. (Allocating
    uint8 unconditionally silently truncated the high bits of 9–16-bit
    codes.)"""
    if not 1 <= n_bits <= 16:
        raise ValueError(f"n_bits must be in [1, 16], got {n_bits}")
    return np.dtype(np.uint8 if n_bits <= 8 else np.uint16)


def lfsr_stochastic_quantize(x: np.ndarray, n_bits: int, seed: int = 1
                             ) -> np.ndarray:
    """Bit-faithful hardware rounder: an n_bits LFSR supplies r (Verilog
    model in §IV-A-2). Host-side numpy; used in hardware-equivalence tests."""
    taps = {4: (3, 2), 8: (7, 5, 4, 3)}[n_bits if n_bits in (4, 8) else 4]
    state = seed & ((1 << n_bits) - 1) or 1
    flat = x.reshape(-1)
    out = np.empty_like(flat)
    top = 2 ** n_bits - 1
    for i, v in enumerate(flat):
        fb = 0
        for t in taps:
            fb ^= (state >> t) & 1
        state = ((state << 1) | fb) & ((1 << n_bits) - 1)
        z = v * (2.0 ** n_bits)
        fl = np.floor(z)
        r = state / (2.0 ** n_bits)
        q = fl + 1 if (r < (z - fl) and fl < top) else fl
        out[i] = min(max(q, 0), top)
    return out.reshape(x.shape)


@functools.partial(jax.jit, static_argnums=1)
def _split_chain(key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """n sequential ``key, sub = split(key)`` steps in one dispatch.
    Returns (advanced key, (n, 2) subkeys) — bit-identical to the loop."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub

    return jax.lax.scan(body, key, None, length=n)


@functools.partial(jax.jit, static_argnums=2)
def _quantize_many(xs: jax.Array, keys: jax.Array, n_bits: int) -> jax.Array:
    return jax.vmap(lambda x, k: stochastic_quantize(x, k, n_bits))(xs, keys)


# ---------------------------------------------------------------------------
# Reservoir sampler + replay buffer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReservoirSampler:
    """Algorithm-R over a stream of unknown length with the paper's hardware
    construction: counter + xorshift32 + modulus + index check.

    Every element of the stream ends up in the buffer with equal probability
    k/i after i presentations.
    """
    capacity: int
    seed: int = 0x2545F491
    # "modulus" is the paper's hardware (and the bit-stream every pinned
    # seed walks); "reject" swaps in the unbiased rejection reducer.
    rng_mode: str = "modulus"

    def __post_init__(self):
        self._rng = Xorshift32(self.seed, mode=self.rng_mode)
        self.count = 0  # the paper's counter i

    def offer(self) -> Optional[int]:
        """Present one example; return the buffer slot to overwrite, or None
        if the example is not selected."""
        self.count += 1
        i = self.count
        if i <= self.capacity:
            return i - 1
        # j uniform in [1, i] via modulus unit; keep iff j <= k.
        j = self._rng.randint(1, i)
        return j - 1 if j <= self.capacity else None


class ReplayBuffer:
    """Policy-driven, stochastically-quantized replay store.

    Features are stored as n_bits integer codes (8→4-bit halves the memory,
    §IV-A-2) in a dtype sized by :func:`code_dtype`; labels as int32.
    Host-side numpy storage — this is the DRAM replay buffer, not an
    on-device tensor, and when a :class:`~repro.telemetry.meters.Telemetry`
    accumulator is attached every insert/sample is metered as DRAM traffic
    (``replay_*`` counters).

    Slot selection is delegated to a :class:`repro.replay.ReplayPolicy`
    (a registered name or an instance). The default ``"reservoir"`` is
    the paper's §IV-A hardware bit-for-bit — identical sampler seed
    derivation, identical host-RNG consumption — so schedules built
    through the policy layer hash to the pre-refactor golden digest.
    """

    def __init__(self, capacity: int, feature_shape: tuple[int, ...],
                 n_bits: int = 4, seed: int = 7, policy=None,
                 telemetry=None):
        from repro.replay import ReplayPolicy, make_policy
        if policy is None or isinstance(policy, str):
            policy = make_policy(policy or "reservoir", capacity,
                                 seed=seed)
        if not isinstance(policy, ReplayPolicy):
            raise TypeError(f"policy must be a registered name or a "
                            f"ReplayPolicy, got {type(policy).__name__}")
        if policy.in_graph:
            raise ValueError(
                f"policy {policy.name!r} is in-graph (training-state-"
                f"dependent); it runs on the scan-carried buffer in "
                f"repro.replay.ingraph, not the host ReplayBuffer")
        if policy.capacity != capacity:
            raise ValueError(f"policy capacity {policy.capacity} != "
                             f"buffer capacity {capacity}")
        self.capacity = capacity
        self.n_bits = n_bits
        self.policy = policy
        # Back-compat alias: the reservoir policy's hardware sampler.
        self.sampler = getattr(policy, "sampler", None)
        self._feat = np.zeros((capacity, *feature_shape),
                              dtype=code_dtype(n_bits))
        self._label = np.zeros((capacity,), dtype=np.int32)
        self.size = 0
        self._qkey = jax.random.PRNGKey(seed)
        self._telemetry = telemetry
        # Running DRAM-traffic tally (meter-keyed), kept even without an
        # attached accumulator so schedule builders can credit the
        # traffic to a run's telemetry exactly once (run_continual and
        # the compiled sweep build/discard schedules at different times).
        self.traffic: dict[str, int] = {}

    # ------------------------------------------------------------------
    def _meter(self, *, reads: int = 0, writes: int = 0) -> None:
        """Count DRAM traffic: rows moved and bytes (codes + int32
        label per row). Host-side concrete deltas — exact, no tracing."""
        from repro.telemetry import meters as M
        row_bytes = (self._feat.dtype.itemsize
                     * int(np.prod(self._feat.shape[1:]))
                     + self._label.dtype.itemsize)
        deltas: dict[str, int] = {}
        if reads:
            deltas[M.REPLAY_READS] = reads
            deltas[M.REPLAY_READ_BYTES] = reads * row_bytes
        if writes:
            deltas[M.REPLAY_WRITES] = writes
            deltas[M.REPLAY_WRITE_BYTES] = writes * row_bytes
        for k, v in deltas.items():
            self.traffic[k] = self.traffic.get(k, 0) + v
        if self._telemetry is not None and self._telemetry.enabled:
            self._telemetry.record(deltas)

    def add(self, x: np.ndarray, y: int, task_id: int = 0) -> bool:
        """Offer one (features∈[0,1], label) example to the policy."""
        slot = self.policy.select_insert(int(y), int(task_id))
        if slot is None:
            return False
        self._qkey, sub = jax.random.split(self._qkey)
        q = np.asarray(stochastic_quantize(jnp.asarray(x), sub, self.n_bits))
        self._feat[slot] = q
        self._label[slot] = y
        self.size = self.policy.occupancy
        self._meter(writes=1)
        return True

    def add_batch(self, xs: np.ndarray, ys: np.ndarray,
                  task_ids=None, valid=None) -> int:
        """Offer a batch to the policy. Equivalent to per-example
        :meth:`add` calls bit-for-bit (same key chain, same quantizer
        draws — asserted in tests/test_replay.py), but all accepted
        examples are quantized in one vmapped dispatch instead of one
        jax call per example — the schedule-building hot path.

        ``valid`` (a (B,) bool mask) gates padded rows out entirely:
        an invalid row is never offered to the policy and consumes no
        sampler or quantizer RNG, so a zero-padded batch leaves the
        buffer in exactly the state the unpadded batch would."""
        slots: list[int] = []
        keep: list[int] = []
        for i in range(len(xs)):
            if valid is not None and not valid[i]:
                continue
            tid = int(task_ids[i]) if task_ids is not None else 0
            slot = self.policy.select_insert(int(ys[i]), tid)
            if slot is None:
                continue
            slots.append(slot)
            keep.append(i)
        if not slots:
            return 0
        # The exact sequential key chain self._qkey would have walked,
        # computed in one scan dispatch; then one vmapped quantize.
        self._qkey, subs = _split_chain(self._qkey, len(slots))
        q = np.asarray(_quantize_many(
            jnp.asarray(np.ascontiguousarray(xs[keep])), subs, self.n_bits))
        for slot, qi, i in zip(slots, q, keep):
            self._feat[slot] = qi
            self._label[slot] = int(ys[i])
        self.size = self.policy.occupancy
        self._meter(writes=len(slots))
        return len(slots)

    def sample(self, rng: np.random.Generator, batch: int
               ) -> tuple[np.ndarray, np.ndarray]:
        """Policy-selected sample of dequantized examples for rehearsal
        (uniform over the occupied prefix under ``reservoir``/``ring``;
        stratified under the partitioned policies). Dequantizes on the
        paper's 1/2^n scale — see :func:`round_trip_bound`."""
        if self.size == 0:
            raise ValueError("empty replay buffer")
        idx = np.asarray(self.policy.select_sample(rng, batch))
        feats = self._feat[idx].astype(np.float32) / (2.0 ** self.n_bits)
        self._meter(reads=batch)
        return feats, self._label[idx]

    @property
    def nbytes(self) -> int:
        return self._feat.nbytes + self._label.nbytes
