"""Hardware experience-replay pipeline (§IV-A): reservoir sampler,
stochastic quantizer, replay buffer.

The paper's data-preparation unit is digital host-side logic (counter,
xorshift32, modulus unit, LFSR-driven stochastic rounder). It is reproduced
here bit-faithfully in numpy for the host path, plus vectorized jnp versions
of the quantizers for the in-graph replay path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Xorshift32 — the paper's RNG (chosen over LFSR for unbiased indices)
# ---------------------------------------------------------------------------

class Xorshift32:
    """32-bit xorshift PRNG (Marsaglia), exactly the 13/17/5 hardware circuit.

    Produces decorrelated, uniform indices — the property the paper relies on
    for equal-probability reservoir sampling (unlike an LFSR, whose maximal
    sequence never emits 0 and is correlated between taps).
    """

    def __init__(self, seed: int = 0x9E3779B9):
        seed = np.uint32(seed if seed != 0 else 0xDEADBEEF)
        self.state = np.uint32(seed)

    def next(self) -> int:
        x = self.state
        with np.errstate(over="ignore"):
            x = np.uint32(x ^ np.uint32(x << np.uint32(13)))
            x = np.uint32(x ^ np.uint32(x >> np.uint32(17)))
            x = np.uint32(x ^ np.uint32(x << np.uint32(5)))
        self.state = x
        return int(x)

    def randint(self, lo: int, hi: int) -> int:
        """Uniform int in [lo, hi] via the paper's modulus unit."""
        span = hi - lo + 1
        return lo + self.next() % span


# ---------------------------------------------------------------------------
# Stochastic quantizer (eqs. 4-6)
# ---------------------------------------------------------------------------

def stochastic_quantize(x: jax.Array, key: jax.Array, n_bits: int
                        ) -> jax.Array:
    """Quantize x∈[0,1] to n_bits integer codes with stochastic rounding.

        z  = x · 2^{n_b}
        q  = ⌊z⌋ + 1   if r < frac(z) and ⌊z⌋ < 2^{n_b} − 1
             ⌊z⌋       otherwise,   r ~ U(0,1)

    Unbiased: E[dequantize(q)] == x (up to the clip at the top code).
    """
    z = x * (2.0 ** n_bits)
    fl = jnp.floor(z)
    frac = z - fl
    r = jax.random.uniform(key, x.shape)
    top = 2.0 ** n_bits - 1.0
    q = jnp.where((r < frac) & (fl < top), fl + 1.0, fl)
    return jnp.clip(q, 0.0, top).astype(jnp.uint8 if n_bits <= 8
                                        else jnp.uint16)


def uniform_quantize(x: jax.Array, n_bits: int) -> jax.Array:
    """Plain truncation quantizer (the baseline in Fig. 5a)."""
    z = jnp.floor(x * (2.0 ** n_bits))
    top = 2.0 ** n_bits - 1.0
    return jnp.clip(z, 0.0, top).astype(jnp.uint8 if n_bits <= 8
                                        else jnp.uint16)


def dequantize(q: jax.Array, n_bits: int, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) / (2.0 ** n_bits)


def lfsr_stochastic_quantize(x: np.ndarray, n_bits: int, seed: int = 1
                             ) -> np.ndarray:
    """Bit-faithful hardware rounder: an n_bits LFSR supplies r (Verilog
    model in §IV-A-2). Host-side numpy; used in hardware-equivalence tests."""
    taps = {4: (3, 2), 8: (7, 5, 4, 3)}[n_bits if n_bits in (4, 8) else 4]
    state = seed & ((1 << n_bits) - 1) or 1
    flat = x.reshape(-1)
    out = np.empty_like(flat)
    top = 2 ** n_bits - 1
    for i, v in enumerate(flat):
        fb = 0
        for t in taps:
            fb ^= (state >> t) & 1
        state = ((state << 1) | fb) & ((1 << n_bits) - 1)
        z = v * (2.0 ** n_bits)
        fl = np.floor(z)
        r = state / (2.0 ** n_bits)
        q = fl + 1 if (r < (z - fl) and fl < top) else fl
        out[i] = min(max(q, 0), top)
    return out.reshape(x.shape)


@functools.partial(jax.jit, static_argnums=1)
def _split_chain(key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """n sequential ``key, sub = split(key)`` steps in one dispatch.
    Returns (advanced key, (n, 2) subkeys) — bit-identical to the loop."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub

    return jax.lax.scan(body, key, None, length=n)


@functools.partial(jax.jit, static_argnums=2)
def _quantize_many(xs: jax.Array, keys: jax.Array, n_bits: int) -> jax.Array:
    return jax.vmap(lambda x, k: stochastic_quantize(x, k, n_bits))(xs, keys)


# ---------------------------------------------------------------------------
# Reservoir sampler + replay buffer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReservoirSampler:
    """Algorithm-R over a stream of unknown length with the paper's hardware
    construction: counter + xorshift32 + modulus + index check.

    Every element of the stream ends up in the buffer with equal probability
    k/i after i presentations.
    """
    capacity: int
    seed: int = 0x2545F491

    def __post_init__(self):
        self._rng = Xorshift32(self.seed)
        self.count = 0  # the paper's counter i

    def offer(self) -> Optional[int]:
        """Present one example; return the buffer slot to overwrite, or None
        if the example is not selected."""
        self.count += 1
        i = self.count
        if i <= self.capacity:
            return i - 1
        # j uniform in [1, i] via modulus unit; keep iff j <= k.
        j = self._rng.randint(1, i)
        return j - 1 if j <= self.capacity else None


class ReplayBuffer:
    """Reservoir-sampled, stochastically-quantized replay store.

    Features are stored as n_bits integer codes (8→4-bit halves the memory,
    §IV-A-2); labels as int32. Host-side numpy storage — this is the DRAM
    replay buffer, not an on-device tensor.
    """

    def __init__(self, capacity: int, feature_shape: tuple[int, ...],
                 n_bits: int = 4, seed: int = 7):
        self.capacity = capacity
        self.n_bits = n_bits
        self.sampler = ReservoirSampler(capacity, seed=seed ^ 0x5BD1E995)
        self._feat = np.zeros((capacity, *feature_shape), dtype=np.uint8)
        self._label = np.zeros((capacity,), dtype=np.int32)
        self.size = 0
        self._qkey = jax.random.PRNGKey(seed)

    def add(self, x: np.ndarray, y: int) -> bool:
        """Offer one (features∈[0,1], label) example to the reservoir."""
        slot = self.sampler.offer()
        if slot is None:
            return False
        self._qkey, sub = jax.random.split(self._qkey)
        q = np.asarray(stochastic_quantize(jnp.asarray(x), sub, self.n_bits))
        self._feat[slot] = q
        self._label[slot] = y
        self.size = min(self.size + 1, self.capacity)
        return True

    def add_batch(self, xs: np.ndarray, ys: np.ndarray) -> int:
        """Offer a batch to the reservoir. Equivalent to per-example
        :meth:`add` calls bit-for-bit (same key chain, same quantizer
        draws — asserted in tests/test_replay.py), but all accepted
        examples are quantized in one vmapped dispatch instead of one
        jax call per example — the schedule-building hot path."""
        slots: list[int] = []
        keep: list[int] = []
        for i in range(len(xs)):
            slot = self.sampler.offer()
            if slot is None:
                continue
            slots.append(slot)
            keep.append(i)
        if not slots:
            return 0
        # The exact sequential key chain self._qkey would have walked,
        # computed in one scan dispatch; then one vmapped quantize.
        self._qkey, subs = _split_chain(self._qkey, len(slots))
        q = np.asarray(_quantize_many(
            jnp.asarray(np.ascontiguousarray(xs[keep])), subs, self.n_bits))
        for slot, qi, i in zip(slots, q, keep):
            self._feat[slot] = qi
            self._label[slot] = int(ys[i])
            self.size = min(self.size + 1, self.capacity)
        return len(slots)

    def sample(self, rng: np.random.Generator, batch: int
               ) -> tuple[np.ndarray, np.ndarray]:
        """Uniform sample of dequantized examples for rehearsal."""
        if self.size == 0:
            raise ValueError("empty replay buffer")
        idx = rng.integers(0, self.size, size=batch)
        feats = self._feat[idx].astype(np.float32) / (2.0 ** self.n_bits)
        return feats, self._label[idx]

    @property
    def nbytes(self) -> int:
        return self._feat.nbytes + self._label.nbytes
