"""K-winner-take-all — the paper's ζ sparsifier and softmax approximation.

Two uses in M2RU:
  1. Gradient sparsification (Algorithm 1, lines 19-21): ζ(∇W) keeps only the
     top-k entries by magnitude, cutting memristor write traffic ~47 % and
     extending device lifetime 6.9 → 12.2 years (§VI-B).
  2. The voltage-mode k-WTA circuit in the readout (Fig. 3-Right) that
     approximates softmax by letting only the k largest logits through.

The Pallas kernel (`kernels/kwta.py`) implements the same selection as a
bisection on the monotone count(|x| > θ) function — the digital twin of the
analog circuit's threshold settling. This module is the exact jnp version.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def kwta_mask(x: jax.Array, k: int, by_magnitude: bool = True,
              axis: int = -1) -> jax.Array:
    """Boolean mask of the k winners along ``axis``.

    Ties are broken by position (earlier index wins), matching lax.top_k.
    """
    if k <= 0:
        return jnp.zeros_like(x, dtype=bool)
    n = x.shape[axis]
    if k >= n:
        return jnp.ones_like(x, dtype=bool)
    score = jnp.abs(x) if by_magnitude else x
    score = jnp.moveaxis(score, axis, -1)
    # Threshold = value of the k-th largest score per row.
    kth = jax.lax.top_k(score, k)[0][..., -1:]
    above = score > kth
    # Handle ties at the threshold deterministically: admit the earliest
    # `k - n_above` entries equal to the threshold.
    n_above = jnp.sum(above, axis=-1, keepdims=True)
    at = score == kth
    rank_at = jnp.cumsum(at, axis=-1)  # 1-based rank among tied entries
    admit_ties = at & (rank_at <= (k - n_above))
    mask = above | admit_ties
    return jnp.moveaxis(mask, -1, axis)


def kwta(x: jax.Array, k: Optional[int] = None,
         keep_frac: Optional[float] = None, by_magnitude: bool = True,
         axis: int = -1) -> jax.Array:
    """ζ: zero out all but the k (or ``keep_frac``·n) winners along ``axis``.

    Exactly one of ``k`` / ``keep_frac`` must be given. For gradient
    sparsification the paper keeps ≈57 % of entries (a ~43 % sparsification
    ratio → ~47 % fewer writes once accumulated over training).
    """
    if (k is None) == (keep_frac is None):
        raise ValueError("pass exactly one of k / keep_frac")
    n = x.shape[axis]
    if k is None:
        k = max(1, int(round(keep_frac * n)))
    return jnp.where(kwta_mask(x, k, by_magnitude, axis), x,
                     jnp.zeros_like(x))


def kwta_global(x: jax.Array, keep_frac: float) -> jax.Array:
    """ζ applied over the *whole tensor* (the per-matrix form used for
    gradient matrices in Algorithm 1)."""
    flat = x.reshape(-1)
    out = kwta(flat, keep_frac=keep_frac, by_magnitude=True, axis=0)
    return out.reshape(x.shape)


def kwta_softmax(logits: jax.Array, k: int) -> jax.Array:
    """Voltage-mode k-WTA softmax approximation: probability mass restricted
    to the k winning logits (Fig. 3-Right)."""
    mask = kwta_mask(logits, k, by_magnitude=False)
    masked = jnp.where(mask, logits, jnp.full_like(logits, -jnp.inf))
    return jax.nn.softmax(masked, axis=-1)
