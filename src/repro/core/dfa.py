"""Direct Feedback Alignment through time — Algorithm 1, faithfully.

The output error is computed once per sequence (at t = n_T, the only step
with a readout in the paper's classification setup), projected to the hidden
layer through the fixed random matrix Ψ, and re-used at every time step of
the backward accumulation:

    δ_o   = ∂ℓ/∂(h^{n_T} W_o + b_o)                (softmax CE ⇒ p − y)
    ∇W_o  = (h^{n_T})ᵀ δ_o
    e     = δ_o Ψ                                   (line 13)
    δ_hᵗ  = λ · e ⊙ tanh′(preactᵗ)                  (line 14)
    ∇W_h += (xᵗ)ᵀ δ_hᵗ                              (line 15)
    ∇U_h += (β hᵗ⁻¹)ᵀ δ_hᵗ                          (line 16)

Because e is time-invariant, the per-step accumulation is a pair of
einsum contractions over time — no backward scan, no stored adjoints, no
transposed forward weights: exactly the properties that make the rule
hardware-friendly (no backward locking, §III).

``bptt_grads`` (true gradients via jax.grad) is the software baseline the
paper compares against (BP + Adam).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.miru import MiRUConfig, miru_forward
from repro.utils import onehot, softmax_cross_entropy


def miru_loss(params: dict[str, jax.Array], cfg: MiRUConfig,
              x_seq: jax.Array, labels: jax.Array,
              use_fused: bool = False) -> jax.Array:
    logits, _ = miru_forward(params, cfg, x_seq, use_fused=use_fused)
    return softmax_cross_entropy(logits, labels)


def dfa_grads(params: dict[str, jax.Array], psi: jax.Array, cfg: MiRUConfig,
              x_seq: jax.Array, labels: jax.Array,
              use_fused: bool = False,
              forward_fn=None,
              time_norm: bool = True,
              row_valid: Optional[jax.Array] = None,
              lengths: Optional[jax.Array] = None,
              ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """DFA-through-time gradients (Algorithm 1).

    Args:
      psi: fixed feedback matrix (n_y, n_h).
      x_seq: (B, T, n_x); labels: (B,) int.
      forward_fn: optional replacement forward (the hardware-like model
        passes its WBS/crossbar forward here); signature and returns must
        match ``miru_forward(params, cfg, x_seq)``.
      time_norm: scale the projected error by 1/n_T. Algorithm 1 re-applies
        the *undamped* e at every step, so the accumulated hidden gradient
        scales with n_T, whereas the true BPTT gradient's leaky-integration
        weights (1−λ)λ^{T−t} sum to ≈1 — a ~n_T scale mismatch that
        destabilizes training. Folding 1/n_T into Ψ (a shift in hardware)
        restores the match; the paper leaves Ψ's scale as a free design
        choice, so this is a faithful calibration, not a rule change.
      row_valid: (B,) bool — padded-batch rows to exclude from the loss
        and the error. The mean reduction becomes sum(valid)/Σvalid,
        computed with the same divide ops as the unmasked path so an
        all-valid mask is bitwise-identical to passing None.
      lengths: (B,) int32 per-example true sequence lengths (zero-end-
        padded inputs). The output error reads h at each row's own last
        step, the per-step accumulation is masked past it, and
        ``time_norm`` scales by 1/length per row. All-full lengths are
        bitwise-identical to None.

    Returns (loss, grads) where grads matches the params pytree.
    """
    B, T = x_seq.shape[0], x_seq.shape[1]
    fwd = forward_fn if forward_fn is not None else (
        lambda p, c, x: miru_forward(p, c, x, use_fused=use_fused))
    logits, aux = fwd(params, cfg, x_seq)

    # Output layer (lines 9-10). Mean-reduced over the (valid) batch.
    y = onehot(labels, cfg.n_y, dtype=logits.dtype)
    if row_valid is None:
        loss = softmax_cross_entropy(logits, labels)
        delta_o = (jax.nn.softmax(logits, axis=-1) - y) / B      # (B, n_y)
    else:
        m = row_valid.astype(logits.dtype)                        # (B,)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(m), 1.0)
        loss = jnp.sum((logz - ll) * m) / denom
        delta_o = (jax.nn.softmax(logits, axis=-1) - y) \
            * m[:, None] / denom
    if lengths is None:
        h_T = aux["h_all"][:, -1, :]                              # (B, n_h)
    else:
        idx = (lengths - 1).astype(jnp.int32)[:, None, None]
        h_T = jnp.take_along_axis(
            aux["h_all"],
            jnp.broadcast_to(idx, (B, 1, aux["h_all"].shape[-1])),
            axis=1)[:, 0, :]
    g_wo = h_T.T @ delta_o
    g_bo = jnp.sum(delta_o, axis=0)

    # Hidden layer (lines 12-17). e is shared across time.
    e = delta_o @ psi                                             # (B, n_h)
    if time_norm:
        e = e / (T if lengths is None
                 else lengths.astype(e.dtype)[:, None])
    dtanh = 1.0 - jnp.tanh(aux["pre"]) ** 2                       # (B,T,n_h)
    delta_h = cfg.lam * e[:, None, :] * dtanh                     # (B,T,n_h)
    if lengths is not None:
        tmask = (jnp.arange(T)[None, :]
                 < lengths[:, None]).astype(delta_h.dtype)
        delta_h = delta_h * tmask[:, :, None]
    g_wh = jnp.einsum("btx,bth->xh", x_seq, delta_h)
    g_uh = jnp.einsum("bth,btk->hk", cfg.beta * aux["h_prev"], delta_h)
    g_bh = jnp.sum(delta_h, axis=(0, 1))

    grads = {"w_h": g_wh, "u_h": g_uh, "b_h": g_bh,
             "w_o": g_wo, "b_o": g_bo}
    return loss, grads


def bptt_grads(params: dict[str, jax.Array], cfg: MiRUConfig,
               x_seq: jax.Array, labels: jax.Array,
               use_fused: bool = False,
               ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """True gradients (BPTT) — the paper's software baseline."""
    return jax.value_and_grad(miru_loss)(params, cfg, x_seq, labels,
                                         use_fused=use_fused)


def grad_alignment(g_dfa: dict[str, jax.Array],
                   g_bp: dict[str, jax.Array],
                   key: str = "w_h") -> jax.Array:
    """Cosine similarity between DFA and true gradients — the 'alignment'
    that makes feedback alignment converge (should grow > 0 with training)."""
    a = g_dfa[key].reshape(-1)
    b = g_bp[key].reshape(-1)
    denom = jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12
    return jnp.dot(a, b) / denom


def scaled_sparse_updates(grads: dict[str, jax.Array], lr: float,
                          keep_frac: Optional[float] = None,
                          hidden_lr_scale: float = 1.0,
                          ) -> dict[str, jax.Array]:
    """Lines 19-21: dW = −lr · ζ(∇W), with the per-layer shift.

    ``hidden_lr_scale`` applies a smaller step to the DFA-driven hidden
    weights (w_h/u_h/b_h) than to the exactly-trained readout — in hardware
    a per-layer shift of the update magnitude, needed because the projected
    error is only direction-aligned, not magnitude-calibrated. This is the
    single definition of the rule — the continual trainer and
    ``sgd_kwta_update`` both call it.
    """
    from repro.core.kwta import kwta_global
    hidden = ("w_h", "u_h", "b_h")
    updates = {}
    for name, g in grads.items():
        if keep_frac is not None and g.ndim >= 2:
            g = kwta_global(g, keep_frac)
        s = hidden_lr_scale if name in hidden else 1.0
        updates[name] = (-lr * s) * g
    return updates


def sgd_kwta_update(params: dict[str, jax.Array],
                    grads: dict[str, jax.Array], lr: float,
                    keep_frac: Optional[float] = None,
                    hidden_lr_scale: float = 1.0,
                    ) -> tuple[dict[str, jax.Array], dict[str, jax.Array]]:
    """W ← W + dW for the ζ-sparsified DFA step.

    Returns (new_params, write_masks) — the masks record which synapses were
    written, feeding the endurance tracker (§VI-B).
    """
    updates = scaled_sparse_updates(grads, lr, keep_frac, hidden_lr_scale)
    new_params = {name: p + updates[name] for name, p in params.items()}
    masks = {name: (u != 0) for name, u in updates.items()}
    return new_params, masks
