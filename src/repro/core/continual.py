"""Domain-incremental continual learning — the Fig. 4 protocol.

Tasks arrive sequentially with no identity at test time and a shared output
head. Training mixes fresh examples with reservoir-sampled, stochastically
quantized replay. Three backends:

  "adam"   — BPTT + Adam (the paper's software baseline)
  "dfa"    — DFA-through-time + SGD + K-WTA sparsification (paper, software)
  "dfa_hw" — DFA on the hardware-like model: WBS-quantized inputs, crossbar
             read/write variability, ADC quantization, sparsified noisy
             writes, endurance tracking (the M2RU accelerator)

Reported: R[t, i] = accuracy on task i after training through task t;
MA = mean of the final row (eq. 20).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analog.adc import adc_quantize
from repro.analog.endurance import EnduranceTracker
from repro.analog.wbs import WBSSpec, wbs_vmm
from repro.core import dfa as dfa_mod
from repro.core.kwta import kwta_global
from repro.core.miru import (MiRUConfig, init_dfa_feedback, init_miru_params,
                             miru_apply_readout)
from repro.data.synthetic import TaskData
from repro.optim import adam, apply_updates
from repro.utils import accuracy as acc_fn


@dataclasses.dataclass(frozen=True)
class ContinualConfig:
    trainer: str = "dfa"                # adam | dfa | dfa_hw
    epochs_per_task: int = 1
    batch_size: int = 32
    lr: float = 0.2
    hidden_lr_scale: float = 0.3        # per-layer update shift (hardware)
    adam_lr: float = 1e-3
    kwta_keep_frac: Optional[float] = 0.57
    replay_capacity: int = 512
    replay_ratio: float = 0.5           # fraction of each batch from replay
    replay_bits: int = 4                # stochastic-quantizer precision
    # Hardware-like model knobs (dfa_hw):
    input_bits: int = 8
    adc_bits: int = 8
    adc_range: float = 4.0
    gain_sigma: float = 0.02            # WBS memristor-ratio variability
    write_sigma: float = 0.10           # §V-B device write variation
    weight_clip: float = 1.5            # crossbar dynamic range (logical)
    track_endurance: bool = False
    seed: int = 0


# ---------------------------------------------------------------------------
# Hardware-like forward
# ---------------------------------------------------------------------------

def hw_miru_forward(params: dict[str, jax.Array], cfg: MiRUConfig,
                    x_seq: jax.Array, key: jax.Array, ccfg: ContinualConfig
                    ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """MiRU forward on the mixed-signal model.

    The hidden crossbar holds [W_h; U_h] on shared wordlines (Fig. 2): the
    concatenated drive [xᵗ, β·hᵗ⁻¹] is WBS-streamed; the integrator output
    is ADC-quantized, then the digital PWL tanh and λ-interpolation follow.
    """
    B, T, _ = x_seq.shape
    w_cat = jnp.concatenate([params["w_h"], params["u_h"]], axis=0)
    spec = WBSSpec(n_bits=ccfg.input_bits, gain_sigma=ccfg.gain_sigma,
                   adc_bits=None)  # ADC applied after adding the bias
    scale = ccfg.weight_clip

    def step(carry, inp):
        h, k = carry
        x_t = inp
        k, k1 = jax.random.split(k)
        drive = jnp.concatenate([x_t, cfg.beta * h], axis=-1)
        pre = wbs_vmm(drive, w_cat / scale, spec, key=k1) * scale \
            + params["b_h"]
        pre = adc_quantize(pre, ccfg.adc_bits, ccfg.adc_range)
        h_tilde = jnp.tanh(pre)
        h_new = cfg.lam * h + (1.0 - cfg.lam) * h_tilde
        return (h_new, k), (h_new, h, pre)

    h0 = jnp.zeros((B, cfg.n_h), cfg.dtype)
    (_, _), (h_all, h_prev, pre) = jax.lax.scan(
        step, (h0, key), jnp.swapaxes(x_seq, 0, 1))
    h_all = jnp.swapaxes(h_all, 0, 1)
    h_prev = jnp.swapaxes(h_prev, 0, 1)
    pre = jnp.swapaxes(pre, 0, 1)
    logits = miru_apply_readout(params, cfg, h_all[:, -1, :])
    return logits, {"h_all": h_all, "h_prev": h_prev, "pre": pre}


# ---------------------------------------------------------------------------
# Train/eval steps (jit-compiled once per backend)
# ---------------------------------------------------------------------------

def _make_steps(cfg: MiRUConfig, ccfg: ContinualConfig):
    """Build jitted (train_step, eval_fn) for the chosen backend."""
    opt = adam(ccfg.adam_lr)

    if ccfg.trainer == "adam":
        @jax.jit
        def train_step(params, opt_state, key, x, y):
            loss, grads = dfa_mod.bptt_grads(params, cfg, x, y)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, loss, updates

        @jax.jit
        def evaluate(params, key, x, y):
            logits, _ = dfa_mod.miru_forward(params, cfg, x)
            return acc_fn(logits, y)

    elif ccfg.trainer == "dfa":
        @jax.jit
        def train_step(params, opt_state, key, x, y):
            psi = opt_state["psi"]
            loss, grads = dfa_mod.dfa_grads(params, psi, cfg, x, y)
            new_params, _ = dfa_mod.sgd_kwta_update(
                params, grads, ccfg.lr, ccfg.kwta_keep_frac,
                ccfg.hidden_lr_scale)
            updates = jax.tree.map(lambda a, b: a - b, new_params, params)
            return new_params, opt_state, loss, updates

        @jax.jit
        def evaluate(params, key, x, y):
            logits, _ = dfa_mod.miru_forward(params, cfg, x)
            return acc_fn(logits, y)

    elif ccfg.trainer == "dfa_hw":
        @jax.jit
        def train_step(params, opt_state, key, x, y):
            psi = opt_state["psi"]
            k_fwd, k_wr = jax.random.split(key)
            fwd = lambda p, c, xs: hw_miru_forward(p, c, xs, k_fwd, ccfg)
            loss, grads = dfa_mod.dfa_grads(params, psi, cfg, x, y,
                                            forward_fn=fwd)
            # Sparsify, then write with device variability and clip to the
            # crossbar's dynamic range.
            new_params = {}
            updates = {}
            kws = jax.random.split(k_wr, len(params))
            hidden = ("w_h", "u_h", "b_h")
            for kw, (name, p) in zip(kws, sorted(params.items())):
                g = grads[name]
                if ccfg.kwta_keep_frac is not None and g.ndim >= 2:
                    g = kwta_global(g, ccfg.kwta_keep_frac)
                s = ccfg.hidden_lr_scale if name in hidden else 1.0
                dw = -ccfg.lr * s * g
                noise = 1.0 + ccfg.write_sigma * jax.random.normal(
                    kw, dw.shape)
                dw = jnp.where(dw != 0, dw * noise, 0.0)
                newp = jnp.clip(p + dw, -ccfg.weight_clip, ccfg.weight_clip)
                new_params[name] = newp
                updates[name] = newp - p
            return new_params, opt_state, loss, updates

        @jax.jit
        def evaluate(params, key, x, y):
            logits, _ = hw_miru_forward(params, cfg, x, key, ccfg)
            return acc_fn(logits, y)

    else:
        raise ValueError(f"unknown trainer {ccfg.trainer!r}")

    return train_step, evaluate, opt


def evaluate_tasks(evaluate, params, key, tasks: list[TaskData],
                   upto: int) -> np.ndarray:
    accs = np.zeros(upto + 1)
    for i, task in enumerate(tasks[:upto + 1]):
        accs[i] = float(evaluate(params, key,
                                 jnp.asarray(task.x_test),
                                 jnp.asarray(task.y_test)))
    return accs


# ---------------------------------------------------------------------------
# Main loop
# ---------------------------------------------------------------------------

def run_continual(cfg: MiRUConfig, ccfg: ContinualConfig,
                  tasks: list[TaskData]) -> dict[str, Any]:
    """Train through the task sequence; return the R matrix, MA, and
    (optionally) endurance statistics."""
    from repro.core.replay import ReplayBuffer

    key = jax.random.PRNGKey(ccfg.seed)
    key, k_param, k_psi = jax.random.split(key, 3)
    params = init_miru_params(k_param, cfg)
    psi = init_dfa_feedback(k_psi, cfg)

    train_step, evaluate, opt = _make_steps(cfg, ccfg)
    if ccfg.trainer == "adam":
        opt_state = opt.init(params)
    else:
        opt_state = {"psi": psi}

    T, F = tasks[0].x_train.shape[1:]
    buffer = ReplayBuffer(ccfg.replay_capacity, (T, F),
                          n_bits=ccfg.replay_bits, seed=ccfg.seed)
    tracker = EnduranceTracker() if ccfg.track_endurance else None
    host_rng = np.random.default_rng(ccfg.seed + 1)

    n_tasks = len(tasks)
    R = np.zeros((n_tasks, n_tasks))
    losses: list[float] = []

    for t, task in enumerate(tasks):
        n = task.x_train.shape[0]
        bs = ccfg.batch_size
        for _ in range(ccfg.epochs_per_task):
            order = host_rng.permutation(n)
            for s in range(0, n - bs + 1, bs):
                idx = order[s:s + bs]
                xb = task.x_train[idx]
                yb = task.y_train[idx]
                # Mix in replay (after the first task has populated it).
                if t > 0 and buffer.size > 0 and ccfg.replay_ratio > 0:
                    n_rep = int(round(bs * ccfg.replay_ratio))
                    if n_rep > 0:
                        xr, yr = buffer.sample(host_rng, n_rep)
                        xb = np.concatenate([xb[:bs - n_rep],
                                             xr.reshape(-1, T, F)])
                        yb = np.concatenate([yb[:bs - n_rep], yr])
                key, k_step = jax.random.split(key)
                params, opt_state, loss, updates = train_step(
                    params, opt_state, k_step, jnp.asarray(xb),
                    jnp.asarray(yb))
                losses.append(float(loss))
                if tracker is not None:
                    tracker.record_update(
                        {k: np.asarray(v != 0) for k, v in updates.items()
                         if np.ndim(v) >= 2})
                # Reservoir-sample the *fresh* examples into the buffer.
                fresh = xb[:max(1, bs - int(round(bs * ccfg.replay_ratio)))]
                fresh_y = yb[:fresh.shape[0]]
                buffer.add_batch(fresh.reshape(fresh.shape[0], -1)
                                 .reshape(fresh.shape[0], T, F), fresh_y)
        key, k_eval = jax.random.split(key)
        R[t, :t + 1] = evaluate_tasks(evaluate, params, k_eval, tasks, t)

    out: dict[str, Any] = {
        "R": R,
        "MA": float(R[-1, :].mean()),
        "acc_after_each": [float(R[t, :t + 1].mean())
                           for t in range(n_tasks)],
        "losses": losses,
        "params": params,
    }
    if tracker is not None:
        out["endurance"] = tracker
    return out
