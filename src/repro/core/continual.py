"""Domain-incremental continual learning — the Fig. 4 protocol.

Tasks arrive sequentially with no identity at test time and a shared output
head. Training mixes fresh examples with reservoir-sampled, stochastically
quantized replay.

The run is described by three composable records plus a device backend:

  TrainerSpec   the learning rule — "adam" (BPTT + Adam, the paper's
                software baseline) or "dfa" (DFA-through-time + SGD +
                K-WTA sparsification, Algorithm 1) — and its knobs.
  ReplaySpec    rehearsal buffer capacity / mix ratio / quantizer
                precision / replay policy (repro.replay registry;
                "reservoir" is the paper's hardware sampler and the
                bit-identical default).
  DeviceBackend the substrate (repro.backends): "ideal", "wbs", "analog",
                or any registered custom backend. The forward VMMs, the
                readout ADC, and the weight writes all route through it.

``ContinualConfig`` is the legacy flat record; it still accepts the old
kwargs and the old trainer strings ("adam" | "dfa" | "dfa_hw") and maps
them onto the new specs via :meth:`ContinualConfig.specs`.

Reported: R[t, i] = accuracy on task i after training through task t;
MA = mean of the final row (eq. 20).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analog.crossbar import CrossbarSpec
from repro.backends import DeviceBackend, DeviceSpec, get_backend
from repro.core import dfa as dfa_mod
from repro.telemetry import meters
from repro.core.miru import (MiRUConfig, init_dfa_feedback, init_miru_params,
                             miru_apply_readout)
from repro.data.synthetic import TaskData
from repro.optim import adam
from repro.utils import accuracy as acc_fn
from repro.utils import softmax_cross_entropy


# ---------------------------------------------------------------------------
# Composable run specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainerSpec:
    """The learning rule and its hyper-parameters."""
    algo: str = "dfa"                   # adam | dfa
    epochs_per_task: int = 1
    batch_size: int = 32
    lr: float = 0.2                     # SGD step (dfa)
    hidden_lr_scale: float = 0.3        # per-layer update shift
    adam_lr: float = 1e-3               # Adam step (adam)
    kwta_keep_frac: Optional[float] = 0.57  # ζ gradient sparsification
    seed: int = 0
    # Fused one-kernel recurrence (kernels/wbs_miru_scan.py; bit-identical
    # to the per-step device_vmm scan). None defers to the backend's own
    # fused_recurrence flag — fused by default where the substrate
    # supports it; False forces the per-step path everywhere (the
    # --no-fused escape hatch); True insists on fusing where valid even
    # on a backend constructed with fused_recurrence=False.
    fused_recurrence: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class ReplaySpec:
    """The rehearsal pipeline (§IV-A) — buffer sizing plus the policy.

    ``policy`` names a registered :mod:`repro.replay` policy
    (``reservoir`` | ``ring`` | ``class_balanced`` | ``task_stratified``
    | ``loss_aware``). None means "no preference": scenario metadata
    (``ScenarioSpec.replay_policy``) may resolve it, and otherwise it
    falls back to ``reservoir`` — the paper's hardware sampler,
    bit-identical to the pre-policy-subsystem behavior.
    """
    capacity: int = 512
    ratio: float = 0.5                  # fraction of each batch from replay
    bits: int = 4                       # stochastic-quantizer precision
    policy: Optional[str] = None        # replay policy (None → reservoir)
    # Staleness decay on stored in-graph priorities (loss_aware): each
    # offer round multiplies every stored priority by ``decay`` before
    # the fresh rows compete, keeping stored CE scores comparable to
    # fresh ones as the model trains on (paired with the class-aware
    # eviction in repro.replay.ingraph that fixes the task-boundary
    # collapse). Host policies ignore it; 1.0 reproduces the legacy
    # no-decay buffer bit-for-bit.
    decay: float = 0.9

    @property
    def resolved_policy(self) -> str:
        return self.policy if self.policy is not None else "reservoir"


# Legacy trainer string → (algorithm, backend name).
TRAINER_ALIASES: dict[str, tuple[str, str]] = {
    "adam": ("adam", "ideal"),
    "dfa": ("dfa", "ideal"),
    "dfa_hw": ("dfa", "analog"),
}


@dataclasses.dataclass(frozen=True)
class ContinualConfig:
    """Legacy flat config — deprecation shim over the composable specs.

    New code should build TrainerSpec / ReplaySpec and a backend from
    ``repro.backends`` directly; this record remains so existing call
    sites (old kwargs, old trainer strings) keep working unchanged.
    """
    trainer: str = "dfa"                # adam | dfa | dfa_hw
    epochs_per_task: int = 1
    batch_size: int = 32
    lr: float = 0.2
    hidden_lr_scale: float = 0.3        # per-layer update shift (hardware)
    adam_lr: float = 1e-3
    kwta_keep_frac: Optional[float] = 0.57
    replay_capacity: int = 512
    replay_ratio: float = 0.5           # fraction of each batch from replay
    replay_bits: int = 4                # stochastic-quantizer precision
    # Hardware-like model knobs (dfa_hw):
    input_bits: int = 8
    adc_bits: int = 8
    adc_range: float = 4.0
    gain_sigma: float = 0.02            # WBS memristor-ratio variability
    write_sigma: float = 0.10           # §V-B device write variation
    weight_clip: float = 1.5            # crossbar dynamic range (logical)
    track_endurance: bool = False
    seed: int = 0
    fused_recurrence: Optional[bool] = None  # fused one-kernel recurrence

    def specs(self) -> tuple[TrainerSpec, ReplaySpec, DeviceBackend]:
        """Map the flat legacy record onto (TrainerSpec, ReplaySpec,
        DeviceBackend). The old trainer strings resolve through the
        backend registry: "dfa_hw" ≡ DFA on the "analog" backend."""
        try:
            algo, backend_name = TRAINER_ALIASES[self.trainer]
        except KeyError:
            raise ValueError(
                f"unknown trainer {self.trainer!r}; expected one of "
                f"{sorted(TRAINER_ALIASES)}") from None
        trainer = TrainerSpec(algo=algo,
                              epochs_per_task=self.epochs_per_task,
                              batch_size=self.batch_size, lr=self.lr,
                              hidden_lr_scale=self.hidden_lr_scale,
                              adam_lr=self.adam_lr,
                              kwta_keep_frac=self.kwta_keep_frac,
                              seed=self.seed,
                              fused_recurrence=self.fused_recurrence)
        replay = ReplaySpec(capacity=self.replay_capacity,
                            ratio=self.replay_ratio, bits=self.replay_bits)
        if backend_name == "analog":
            dspec = DeviceSpec(
                input_bits=self.input_bits, adc_bits=self.adc_bits,
                adc_range=self.adc_range, gain_sigma=self.gain_sigma,
                weight_clip=self.weight_clip,
                crossbar=CrossbarSpec(write_sigma=self.write_sigma,
                                      read_sigma=0.0,
                                      w_clip=self.weight_clip),
                track_endurance=self.track_endurance)
        else:
            dspec = DeviceSpec(track_endurance=self.track_endurance)
        return trainer, replay, get_backend(backend_name, spec=dspec)


# ---------------------------------------------------------------------------
# Backend-parameterized forward
# ---------------------------------------------------------------------------

def _meter_chip_step(backend: DeviceBackend, cfg: MiRUConfig, B: int,
                     anchor) -> None:
    """Per-time-step chip activity the software forward does not execute
    but the streaming hardware does (metered ×T by the enclosing scaled
    scope): the readout crossbar evaluates ŷᵗ every step (eq. 3) and the
    λ-interpolator blends every candidate state. The backend-executed
    VMMs/ADC are metered by the ``device_*`` hooks themselves."""
    tele = backend.telemetry
    if not tele.enabled:
        return
    spec = backend.spec
    deltas = {f"{meters.MACS}/w_o": B * cfg.n_h * cfg.n_y,
              f"{meters.VMM_ROWS}/w_o": B,
              f"{meters.INTERP}/h": B * cfg.n_h,
              meters.SAMPLE_STEPS: B}
    if spec.input_bits:
        deltas[f"{meters.BIT_PULSES}/w_o"] = B * cfg.n_h * spec.input_bits
        deltas[f"{meters.WBS_PHASES}/w_o"] = B * spec.input_bits
    if spec.adc_bits is not None:
        deltas[f"{meters.ADC_CONVERSIONS}/out"] = B * cfg.n_y
    tele.record(deltas, anchor=anchor)


def miru_forward_device(params: dict[str, jax.Array], cfg: MiRUConfig,
                        x_seq: jax.Array, key: jax.Array,
                        backend: DeviceBackend,
                        state: Optional[Any] = None,
                        fused: Optional[bool] = None,
                        lengths: Optional[jax.Array] = None
                        ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """MiRU forward with the hidden-layer recurrence routed through a
    device backend.

    On the chip the hidden crossbar holds [W_h; U_h] on shared wordlines
    (Fig. 2) and streams the concatenated drive [xᵗ, β·hᵗ⁻¹]; here the two
    weight tiles are evaluated as separate backend VMMs with independent
    PRNG keys — same fixed-point math (bit-identical to the software
    ``miru_forward`` on the ideal backend), but stochastic non-idealities
    like per-plane gain noise are drawn per tile rather than shared across
    the concatenated crossbar as the old ``dfa_hw`` path did. The
    integrator output is ADC-quantized by the backend after the bias add,
    then the digital PWL tanh and λ-interpolation follow. The readout
    (``miru_apply_readout``) stays digital — the paper's K-WTA voltage
    readout is modeled there, not in the backend.

    The recurrence itself is the backend's
    :meth:`~repro.backends.DeviceBackend.device_recurrence`: a
    per-timestep ``device_vmm`` scan by default, or the fused one-kernel
    WBS×MiRU scan on substrates that support it (bit-identical; see
    ``kernels/wbs_miru_scan.py``). ``fused=False`` forces the per-step
    path; None defers to the backend's ``fused_recurrence`` flag.

    ``state`` is the backend's device state (conductance pairs for
    ``analog_state``); stateless backends ignore it. When the backend's
    telemetry is enabled, every tile access, ADC conversion and
    interpolation is metered — including the streamed per-step readout
    the chip performs — and flushed jit-safely at the end.

    ``lengths`` ((B,) int32) supports zero-end-padded ragged sequences:
    the readout is taken at each row's own last true step instead of
    t = T−1. The recurrence is causal, so padding never perturbs the
    states it reads; ``lengths=None`` (or all-full lengths) is
    bitwise-identical to the historical program. The chip still streams
    all T steps — the telemetry deliberately meters the padded tail as
    executed work (docs/data.md).
    """
    B, T, _ = x_seq.shape
    tele = backend.telemetry

    h_all, h_prev, pre = backend.device_recurrence(
        params, cfg, x_seq, key, state=state, fused=fused)
    with tele.scaled(T):
        _meter_chip_step(backend, cfg, B, anchor=x_seq)
    tele.record({meters.SEQUENCES: B}, anchor=x_seq)
    if lengths is None:
        h_last = h_all[:, -1, :]
    else:
        idx = (lengths - 1).astype(jnp.int32)[:, None, None]
        h_last = jnp.take_along_axis(
            h_all, jnp.broadcast_to(idx, (B, 1, h_all.shape[-1])),
            axis=1)[:, 0, :]
    logits = miru_apply_readout(params, cfg, h_last)
    tele.emit_pending()
    return logits, {"h_all": h_all, "h_prev": h_prev, "pre": pre}


def hw_miru_forward(params: dict[str, jax.Array], cfg: MiRUConfig,
                    x_seq: jax.Array, key: jax.Array, ccfg: ContinualConfig
                    ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Deprecated: the old hardware forward. Equivalent to
    ``miru_forward_device`` on the "analog" backend built from ``ccfg``."""
    warnings.warn("hw_miru_forward is deprecated; use miru_forward_device "
                  "with repro.backends.get_backend('analog')",
                  DeprecationWarning, stacklevel=2)
    _, _, backend = dataclasses.replace(ccfg, trainer="dfa_hw").specs()
    return miru_forward_device(params, cfg, x_seq, key, backend)


# ---------------------------------------------------------------------------
# Train/eval steps (jit-compiled once per trainer × backend)
# ---------------------------------------------------------------------------

def _make_raw_steps(cfg: MiRUConfig, trainer: TrainerSpec,
                    backend: DeviceBackend):
    """Build *unjitted* (train_step, eval_fn, opt) for the learning rule on
    the given device backend. Both algorithms share one forward and one
    write path — the backend supplies the substrate-specific pieces.
    ``run_continual`` jits these per call; the compiled scenario sweep
    (`repro.scenarios.sweep`) traces the same functions inside its
    scan-over-tasks, which is what keeps the two paths bit-comparable."""
    opt = adam(trainer.adam_lr)

    def fwd(p, c, xs, k, st):
        return miru_forward_device(p, c, xs, k, backend, state=st,
                                   fused=trainer.fused_recurrence)

    if trainer.algo == "adam":
        def train_step(params, opt_state, key, x, y, dev_state):
            k_fwd, k_wr = jax.random.split(key)

            def loss_fn(p):
                logits, _ = fwd(p, cfg, x, k_fwd, dev_state)
                return softmax_cross_entropy(logits, y)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state_ = opt.update(grads, opt_state, params)
            params, applied, dev_state = backend.device_apply_update(
                params, updates, k_wr, state=dev_state)
            backend.telemetry.emit_pending()
            return params, opt_state_, loss, applied, dev_state

    elif trainer.algo == "dfa":
        def train_step(params, opt_state, key, x, y, dev_state):
            psi = opt_state["psi"]
            k_fwd, k_wr = jax.random.split(key)
            loss, grads = dfa_mod.dfa_grads(
                params, psi, cfg, x, y,
                forward_fn=lambda p, c, xs: fwd(p, c, xs, k_fwd,
                                                dev_state))
            # ζ-sparsify, scale per layer, hand the write to the device.
            updates = dfa_mod.scaled_sparse_updates(
                grads, trainer.lr, trainer.kwta_keep_frac,
                trainer.hidden_lr_scale)
            params, applied, dev_state = backend.device_apply_update(
                params, updates, k_wr, state=dev_state)
            backend.telemetry.emit_pending()
            return params, opt_state, loss, applied, dev_state

    else:
        raise ValueError(f"unknown trainer algo {trainer.algo!r}; "
                         f"expected 'adam' or 'dfa'")

    def evaluate(params, key, x, y, dev_state):
        logits, _ = fwd(params, cfg, x, key, dev_state)
        backend.telemetry.emit_pending()
        return acc_fn(logits, y)

    return train_step, evaluate, opt


def _make_masked_steps(cfg: MiRUConfig, trainer: TrainerSpec,
                       backend: DeviceBackend):
    """The masked-reduction twins of :func:`_make_raw_steps` for padded
    ragged schedules (:mod:`repro.data.ragged`).

    ``train_step(params, opt_state, key, x, y, dev_state, valid,
    lengths)`` and ``evaluate(params, key, x, y, dev_state, valid,
    lengths)``: ``valid`` is the (B,) row mask (padded rows contribute
    nothing to loss, gradients or accuracy), ``lengths`` the (B,) true
    sequence lengths (readout and DFA error at each row's own last
    step). Every reduction divides by Σvalid with the same ``lax.div``
    the unmasked mean uses, and masks multiply by exactly 0.0/1.0, so
    an all-valid, all-full-length batch computes the same values as the
    raw steps — equal to float32 ulp-level (XLA may fuse the runtime
    mask multiplies into the reductions and reassociate by ±1 ulp; see
    :mod:`repro.data.ragged`), the tolerance benchmarks/data_bench.py
    gates.
    """
    opt = adam(trainer.adam_lr)

    def fwd(p, c, xs, k, st, lengths):
        return miru_forward_device(p, c, xs, k, backend, state=st,
                                   fused=trainer.fused_recurrence,
                                   lengths=lengths)

    if trainer.algo == "adam":
        def train_step(params, opt_state, key, x, y, dev_state, valid,
                       lengths):
            k_fwd, k_wr = jax.random.split(key)

            def loss_fn(p):
                logits, _ = fwd(p, cfg, x, k_fwd, dev_state, lengths)
                m = valid.astype(logits.dtype)
                logz = jax.nn.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(logits, y[..., None],
                                         axis=-1)[..., 0]
                return jnp.sum((logz - ll) * m) \
                    / jnp.maximum(jnp.sum(m), 1.0)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state_ = opt.update(grads, opt_state, params)
            params, applied, dev_state = backend.device_apply_update(
                params, updates, k_wr, state=dev_state)
            backend.telemetry.emit_pending()
            return params, opt_state_, loss, applied, dev_state

    elif trainer.algo == "dfa":
        def train_step(params, opt_state, key, x, y, dev_state, valid,
                       lengths):
            psi = opt_state["psi"]
            k_fwd, k_wr = jax.random.split(key)
            loss, grads = dfa_mod.dfa_grads(
                params, psi, cfg, x, y,
                forward_fn=lambda p, c, xs: fwd(p, c, xs, k_fwd,
                                                dev_state, lengths),
                row_valid=valid, lengths=lengths)
            updates = dfa_mod.scaled_sparse_updates(
                grads, trainer.lr, trainer.kwta_keep_frac,
                trainer.hidden_lr_scale)
            params, applied, dev_state = backend.device_apply_update(
                params, updates, k_wr, state=dev_state)
            backend.telemetry.emit_pending()
            return params, opt_state, loss, applied, dev_state

    else:
        raise ValueError(f"unknown trainer algo {trainer.algo!r}; "
                         f"expected 'adam' or 'dfa'")

    def evaluate(params, key, x, y, dev_state, valid, lengths):
        logits, _ = fwd(params, cfg, x, key, dev_state, lengths)
        backend.telemetry.emit_pending()
        m = valid.astype(jnp.float32)
        ok = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.sum(ok * m) / jnp.maximum(jnp.sum(m), 1.0)

    return train_step, evaluate, opt


def _make_ingraph_replay_step(cfg: MiRUConfig, trainer: TrainerSpec,
                              rspec: ReplaySpec, backend: DeviceBackend,
                              raw_train):
    """Wrap a raw train step with the scan-carried replay buffer that
    training-state-dependent policies (``loss_aware``) run on.

    The wrapped step consumes *fresh-only* schedule batches and, at run
    time: splices a priority-proportional rehearsal draw into the batch
    tail (same tail layout the host schedule materializes), trains,
    scores the batch's per-example loss with one extra forward on the
    just-updated params (the "last-seen loss" priority signal), and
    offers the fresh rows to the device-resident buffer
    (:mod:`repro.replay.ingraph`). All extra PRNG keys are folded off
    the step key, so the training/eval streams stay on the same chain
    the host-policy path walks.

    Signature: ``step(params, opt_state, key, x, y, dev_state, rstate,
    replay_on) -> (params, opt_state, loss, applied, dev_state,
    rstate)`` where ``replay_on`` is a traced bool (past task 0). Pure
    in (state, key, inputs): the same step sequence is bit-identical
    whether driven by the Python loop or a ``lax.scan`` — the
    loop/compiled parity property.
    """
    from repro.replay import ingraph_insert, ingraph_mix, per_example_ce

    n_rep = (int(round(trainer.batch_size * rspec.ratio))
             if rspec.ratio > 0 else 0)
    bits = rspec.bits

    def fwd(p, xs, k, st):
        return miru_forward_device(p, cfg, xs, k, backend, state=st,
                                   fused=trainer.fused_recurrence)

    def train_step(params, opt_state, key, x, y, dev_state, rstate,
                   replay_on):
        B = x.shape[0]
        k_mix = jax.random.fold_in(key, 0x5E1)
        k_prio = jax.random.fold_in(key, 0x5E2)
        k_ins = jax.random.fold_in(key, 0x5E3)
        active = replay_on & (rstate["size"] > 0) & (n_rep > 0)
        xb, yb = ingraph_mix(rstate, k_mix, x, y, n_rep, active, bits,
                             n_classes=cfg.n_y)
        params, opt_state, loss, applied, dev_state = raw_train(
            params, opt_state, key, xb, yb, dev_state)
        logits, _ = fwd(params, xb, k_prio, dev_state)
        prio = per_example_ce(logits, yb)
        # Rehearsed tail rows are never re-offered (host-schedule rule).
        valid = jnp.where(active, jnp.arange(B) < B - n_rep, True)
        rstate = ingraph_insert(rstate, k_ins, xb, yb, prio, bits,
                                valid=valid, decay=rspec.decay,
                                n_classes=cfg.n_y)
        return params, opt_state, loss, applied, dev_state, rstate

    return train_step


def _ingraph_replay_traffic(rspec: ReplaySpec, batch_size: int,
                            steps_per_task: list[int],
                            feature_shape: tuple[int, ...]
                            ) -> dict[str, int]:
    """Exact DRAM traffic of the scan-carried (loss_aware) buffer for
    one run: rehearsal is active on every step past task 0 (the buffer
    is non-empty from task 0's first step on), so per such step the
    device fetches ``n_rep`` rows and is offered the ``B − n_rep``
    fresh rows; task-0 steps offer the whole batch and fetch nothing.
    (Insertion *acceptance* is data-dependent; offered rows are the
    programmed-traffic bound.) Row = quantized codes + int32 label."""
    from repro.core.replay import code_dtype

    n_rep = (int(round(batch_size * rspec.ratio))
             if rspec.ratio > 0 else 0)
    s0 = steps_per_task[0] if steps_per_task else 0
    s_rest = sum(steps_per_task[1:])
    reads = n_rep * s_rest
    writes = batch_size * s0 + (batch_size - n_rep) * s_rest
    row_b = (code_dtype(rspec.bits).itemsize
             * int(np.prod(feature_shape)) + 4)
    return {meters.REPLAY_READS: reads,
            meters.REPLAY_READ_BYTES: reads * row_b,
            meters.REPLAY_WRITES: writes,
            meters.REPLAY_WRITE_BYTES: writes * row_b}


def _init_run(cfg: MiRUConfig, trainer: TrainerSpec,
              backend: DeviceBackend):
    """The run's initial state — params, Ψ, device state — and the live
    training PRNG key. One definition shared by :func:`run_continual` and
    the compiled sweep so the two consume identical key streams."""
    key = jax.random.PRNGKey(trainer.seed)
    key, k_param, k_psi = jax.random.split(key, 3)
    params = init_miru_params(k_param, cfg)
    psi = init_dfa_feedback(k_psi, cfg)
    # Device-state key folded off to the side so the training/eval PRNG
    # streams stay bit-identical to the stateless backends'.
    dev_state = backend.init_device_state(
        params, jax.random.fold_in(key, 0x0DE5))
    return key, params, psi, dev_state


# ---------------------------------------------------------------------------
# Batch schedule — the replay-mixed training stream, materialized
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchSchedule:
    """The full train-batch stream for a task sequence.

    Batch content — epoch shuffles, reservoir offers, quantized rehearsal
    draws — is a pure function of (trainer, replay, tasks): none of it
    depends on training state. So the entire replay-mixed stream can be
    materialized up front, and both :func:`run_continual` (per-batch
    Python loop) and the compiled sweep (`lax.scan` over tasks) consume
    the *same* arrays, which is what makes their results bit-comparable.

    ``x[t]`` is (S_t, B, T, F); ``y[t]`` is (S_t, B).

    ``replay_traffic`` tallies the host replay buffer's DRAM traffic
    (meter-keyed rows/bytes) consumed while materializing the stream;
    the runner that actually *uses* the schedule credits it to its
    backend's telemetry exactly once.

    ``occupancy[t][s]`` is the host replay buffer's fill after step
    ``s`` of task ``t``'s offers — the schedule-derived occupancy
    stream :mod:`repro.obs` reports for host-materialized policies
    (in-graph policies read theirs from the scan-carried buffer
    instead). Not part of :meth:`digest` — the golden schedule hash
    covers only the batch content.

    ``row_valid``/``lengths`` exist only on schedules built under a
    :class:`repro.data.ragged.PadPolicy`: per task, ``row_valid[t]`` is
    (S_t, B) bool (False on zero-padded rows of a kept partial batch)
    and ``lengths[t]`` is (S_t, B) int32 true sequence lengths. None on
    both (the default build) is the historical schedule, byte for byte.
    """
    x: list[np.ndarray]
    y: list[np.ndarray]
    replay_traffic: dict = dataclasses.field(default_factory=dict)
    occupancy: list[np.ndarray] = dataclasses.field(default_factory=list)
    row_valid: Optional[list] = None
    lengths: Optional[list] = None

    def digest(self) -> str:
        """sha256 over the materialized stream — the schedule's identity
        for golden-hash gates (tests/test_determinism.py and the
        bench-scenarios CI job both pin
        :data:`GOLDEN_PERMUTED_SCHEDULE_SHA256`). Masked schedules fold
        the masks in too (mask content is schedule identity)."""
        import hashlib
        h = hashlib.sha256()
        for arr in self.x + self.y:
            h.update(np.ascontiguousarray(arr).tobytes())
        if self.row_valid is not None:
            for arr in self.row_valid + self.lengths:
                h.update(np.ascontiguousarray(arr).tobytes())
        return h.hexdigest()

    @property
    def has_masks(self) -> bool:
        """True when any row is padding or any sequence is short — the
        signal (with eval padding and ``PadPolicy.force``) that the
        compiled sweep must build the masked program."""
        if self.row_valid is None:
            return False
        if any(not rv.all() for rv in self.row_valid):
            return True
        return any(ln.size and int(ln.min()) < xt.shape[2]
                   for ln, xt in zip(self.lengths, self.x))

    @property
    def steps_per_task(self) -> list[int]:
        return [xt.shape[0] for xt in self.x]

    @property
    def uniform(self) -> bool:
        """True when every task has the same step count and batch shape —
        the precondition for stacking into a scan-over-tasks."""
        shapes = {xt.shape for xt in self.x}
        return len(shapes) == 1

    def occupancy_stream(self) -> np.ndarray:
        """The per-step buffer-fill series flattened across tasks,
        ``(total_steps,)`` int32 (zeros for in-graph fresh-only
        schedules, which carry no host buffer)."""
        if not self.occupancy:
            return np.zeros(sum(self.steps_per_task), np.int32)
        return np.concatenate(
            [np.asarray(o, np.int32) for o in self.occupancy])


# Pinned digest of the permuted reference schedule (permuted scenario,
# seed 0, 2 tasks × 64 train / 16 test, dfa × 1 epoch × seed 0,
# ReplaySpec(capacity=32)): any unintended change to the host RNG
# consumption order (epoch shuffle, reservoir offers, quantizer key
# chain) shows up against this constant before it silently breaks
# loop/compiled bit-parity. Asserted in tests/test_determinism.py and
# gated in benchmarks/scenarios_grid.py (the bench-scenarios CI job).
GOLDEN_PERMUTED_SCHEDULE_SHA256 = ("2fe9e2b677cf741551717cd54502398f"
                                   "ddf8094b6d6ab35df1ec113f068b12ee")


def _stream_context(tasks: list[TaskData]) -> dict[str, int]:
    """Stream facts partitioned replay policies need: the full label
    range (class-incremental heads expand logically — size for all of
    it) and the task count."""
    n_classes = int(max(int(t.y_train.max()) for t in tasks)) + 1
    return {"n_classes": max(n_classes, 2), "n_tasks": len(tasks)}


def build_batch_schedule(trainer: TrainerSpec, replay: ReplaySpec,
                         tasks: list[TaskData],
                         pad: Optional[Any] = None) -> BatchSchedule:
    """Materialize the replay-mixed batch stream ``run_continual`` trains
    on, consuming the host RNG streams (epoch shuffle, replay-policy
    sampler, stochastic quantizer) in exactly the order the training
    loop does. Slot selection routes through the
    :mod:`repro.replay` policy named by ``replay.resolved_policy``
    (``reservoir`` reproduces the pre-policy schedule bit-for-bit —
    pinned by the golden hash in tests/test_determinism.py).

    For an in-graph policy (``loss_aware``) the buffer cannot be
    materialized — insertion depends on training state — so the schedule
    is the *fresh-only* stream (full batches, no replay rows, no
    host-buffer RNG consumption) and the trainer splices rehearsal rows
    into each batch tail at run time from the scan-carried device
    buffer (:mod:`repro.replay.ingraph`).

    The buffer's DRAM traffic comes back on
    :attr:`BatchSchedule.replay_traffic`; the runner that consumes the
    schedule credits it to its telemetry (building a schedule that is
    then discarded — e.g. the ragged-stream fallback — meters nothing).

    ``pad`` (a :class:`repro.data.ragged.PadPolicy`) builds the masked
    schedule for ragged streams: the tasks are expected already
    time-padded (:func:`repro.data.ragged.pad_tasks`), per-row true
    lengths are threaded onto :attr:`BatchSchedule.lengths`, and
    ``pad.last_batch`` picks the partial-final-batch semantics —
    ``"drop"`` discards it exactly as the default build always has,
    ``"pad"`` keeps it zero-padded with the pad rows marked invalid in
    :attr:`BatchSchedule.row_valid` (never offered to the replay
    buffer; contributing nothing to loss or gradient). A padded batch's
    replay tail still occupies the last ``n_rep`` rows. With ``pad``
    given but nothing actually partial or short, the emitted stream —
    batch content, buffer offers, host-RNG consumption — is byte-
    identical to the default build.
    """
    from repro.core.replay import ReplayBuffer
    from repro.replay import get_policy_class, make_policy

    T, F = tasks[0].x_train.shape[1:]
    bs = trainer.batch_size
    keep_partial = pad is not None and pad.last_batch == "pad"
    policy_name = replay.resolved_policy
    in_graph = get_policy_class(policy_name).in_graph
    buffer = None
    if not in_graph:
        policy = make_policy(policy_name, replay.capacity,
                             seed=trainer.seed, **_stream_context(tasks))
        buffer = ReplayBuffer(replay.capacity, (T, F), n_bits=replay.bits,
                              seed=trainer.seed, policy=policy)
    host_rng = np.random.default_rng(trainer.seed + 1)

    xs_all: list[np.ndarray] = []
    ys_all: list[np.ndarray] = []
    occ_all: list[np.ndarray] = []
    rv_all: list[np.ndarray] = []
    ln_all: list[np.ndarray] = []
    for t, task in enumerate(tasks):
        n = task.x_train.shape[0]
        row_len = (np.asarray(task.train_lengths, np.int32)
                   if task.train_lengths is not None
                   else np.full(n, T, np.int32))
        xs_t: list[np.ndarray] = []
        ys_t: list[np.ndarray] = []
        occ_t: list[int] = []
        rv_t: list[np.ndarray] = []
        ln_t: list[np.ndarray] = []
        stop = n + 1 if keep_partial else n - bs + 1
        for _ in range(trainer.epochs_per_task):
            order = host_rng.permutation(n)
            for s in range(0, stop, bs):
                idx = order[s:s + bs]
                n_real = len(idx)
                if n_real == 0:
                    continue
                xb = task.x_train[idx]
                yb = task.y_train[idx]
                rv = np.ones(bs, bool)
                ln = np.full(bs, T, np.int32)
                ln[:n_real] = row_len[idx]
                if n_real < bs:
                    # Kept partial batch: zero rows, marked invalid.
                    xb = np.concatenate(
                        [xb, np.zeros((bs - n_real, T, F), xb.dtype)])
                    yb = np.concatenate(
                        [yb, np.zeros(bs - n_real, yb.dtype)])
                    rv[n_real:] = False
                    ln[n_real:] = 1
                # Mix in replay (after the first task has populated it);
                # replay occupies the tail n_rep rows of the batch.
                n_rep = 0
                if (buffer is not None and t > 0 and buffer.size > 0
                        and replay.ratio > 0):
                    n_rep = int(round(bs * replay.ratio))
                    if n_rep > 0:
                        xr, yr = buffer.sample(host_rng, n_rep)
                        xb = np.concatenate([xb[:bs - n_rep],
                                             xr.reshape(-1, T, F)])
                        yb = np.concatenate([yb[:bs - n_rep], yr])
                        # Rehearsal rows are real work, replayed at
                        # full T (the buffer stores fixed-shape rows).
                        rv[bs - n_rep:] = True
                        ln[bs - n_rep:] = T
                # Offer only the *fresh* rows to the policy — all of
                # them (on task 0 no replay was mixed, so the whole
                # batch is fresh; never re-offer rehearsed rows), and
                # never the invalid zero-padding of a partial batch.
                n_fresh = bs - n_rep
                if buffer is not None and n_fresh > 0:
                    # The valid kwarg only appears on padded schedules —
                    # the historical call shape stays byte-for-byte.
                    mask_kw = ({"valid": rv[:n_fresh]}
                               if pad is not None else {})
                    buffer.add_batch(xb[:n_fresh], yb[:n_fresh],
                                     task_ids=np.full(n_fresh, t),
                                     **mask_kw)
                xs_t.append(xb)
                ys_t.append(yb)
                occ_t.append(buffer.size if buffer is not None else 0)
                rv_t.append(rv)
                ln_t.append(ln)
        xs_all.append(np.stack(xs_t) if xs_t
                      else np.zeros((0, bs, T, F), np.float32))
        ys_all.append(np.stack(ys_t) if ys_t
                      else np.zeros((0, bs), np.int32))
        occ_all.append(np.asarray(occ_t, np.int32))
        rv_all.append(np.stack(rv_t) if rv_t
                      else np.zeros((0, bs), bool))
        ln_all.append(np.stack(ln_t) if ln_t
                      else np.zeros((0, bs), np.int32))
    return BatchSchedule(x=xs_all, y=ys_all,
                         replay_traffic=dict(buffer.traffic)
                         if buffer is not None else {},
                         occupancy=occ_all,
                         row_valid=rv_all if pad is not None else None,
                         lengths=ln_all if pad is not None else None)


def evaluate_tasks(evaluate, params, key, tasks: list[TaskData],
                   upto: int, dev_state=None) -> np.ndarray:
    accs = np.zeros(upto + 1)
    for i, task in enumerate(tasks[:upto + 1]):
        accs[i] = float(evaluate(params, key,
                                 jnp.asarray(task.x_test),
                                 jnp.asarray(task.y_test), dev_state))
    return accs


# ---------------------------------------------------------------------------
# Main loop
# ---------------------------------------------------------------------------

def _resolve_specs(spec: Union[ContinualConfig, TrainerSpec],
                   replay: Optional[ReplaySpec],
                   device: Union[str, DeviceBackend, None]
                   ) -> tuple[TrainerSpec, ReplaySpec, DeviceBackend]:
    if isinstance(spec, ContinualConfig):
        if replay is not None or device is not None:
            raise ValueError("pass either a legacy ContinualConfig or "
                             "TrainerSpec + replay/device, not both")
        warnings.warn(
            "passing ContinualConfig to run_continual is deprecated; use "
            "TrainerSpec/ReplaySpec + a repro.backends device backend",
            DeprecationWarning, stacklevel=3)
        return spec.specs()
    if not isinstance(spec, TrainerSpec):
        raise TypeError(f"expected ContinualConfig or TrainerSpec, got "
                        f"{type(spec).__name__}")
    backend = get_backend(device if device is not None else "ideal")
    if backend.tracker is not None and backend.tracker.updates_applied:
        warnings.warn(
            "device backend carries endurance statistics from a previous "
            "run; write counts will accumulate across runs — pass a fresh "
            "backend for per-run statistics", stacklevel=3)
    return (spec, replay if replay is not None else ReplaySpec(), backend)


def run_continual(cfg: MiRUConfig,
                  spec: Union[ContinualConfig, TrainerSpec],
                  tasks: list[TaskData],
                  replay: Optional[ReplaySpec] = None,
                  device: Union[str, DeviceBackend, None] = None,
                  obs: Optional[Any] = None,
                  pad: Optional[Any] = None) -> dict[str, Any]:
    """Train through the task sequence; return the R matrix, MA, and
    (optionally) endurance statistics.

    ``spec`` is a :class:`TrainerSpec` (with ``replay`` and ``device`` —
    a registered backend name or instance — supplied separately), or a
    legacy :class:`ContinualConfig` that maps onto all three.

    ``obs`` is a :class:`repro.obs.ObsSpec`; when it asks for metric
    streams the result carries ``"runlog"`` — a
    :class:`repro.obs.RunLog` matching the compiled sweep's for the
    same run: integer streams bit-identical, float streams to the same
    few-ulp tolerance as the existing loop/compiled ``losses`` parity
    (the loop computes the identical per-step scalars with the same
    jitted :func:`repro.obs.step_stats`).
    ``obs=None`` (the default) adds nothing to the loop.

    ``pad`` is a :class:`repro.data.ragged.PadPolicy` for ragged task
    streams: tasks are padded onto one bucketed shape and the loop runs
    the masked step/eval twins (:func:`_make_masked_steps`) over the
    masked schedule — or, when nothing is actually ragged and
    ``pad.force`` is off, the exact unmasked program. The loop walks
    only real steps (the compiled sweep's step-axis padding does not
    exist here), on the same PRNG chain, which is what keeps the two
    paths bit-comparable on padded streams too.
    """
    trainer, rspec, backend = _resolve_specs(spec, replay, device)

    from repro.replay import get_policy_class, ingraph_init
    in_graph = get_policy_class(rspec.resolved_policy).in_graph
    masked = False
    ev_valid = ev_len = None
    if pad is not None:
        from repro.data.ragged import eval_masks, pad_tasks
        if in_graph:
            raise ValueError(
                "in-graph replay policies (loss_aware) are not supported "
                "on the padded ragged path; pick a host-materialized "
                "policy (reservoir/ring/class_balanced/task_stratified)")
        tasks, eval_padded = pad_tasks(tasks, pad)

    key, params, psi, dev_state = _init_run(cfg, trainer, backend)

    # The (host-policy) replay-mixed batch stream is training-state-
    # independent, so it is materialized up front; the compiled sweep
    # consumes the same schedule, which keeps the two paths
    # bit-comparable. In-graph policies (loss_aware) get a fresh-only
    # schedule plus a device-resident buffer carried through the steps.
    schedule = build_batch_schedule(trainer, rspec, tasks, pad=pad)
    if pad is not None:
        from repro.data.ragged import needs_masked_program
        masked = needs_masked_program(pad, eval_padded, schedule)
        if masked:
            ev_valid, ev_len = eval_masks(tasks)

    raw_train, raw_eval, opt = (_make_masked_steps if masked
                                else _make_raw_steps)(cfg, trainer,
                                                      backend)
    if trainer.algo == "adam":
        opt_state = opt.init(params)
    else:
        opt_state = {"psi": psi}

    evaluate = jax.jit(raw_eval)
    rstate = None
    if in_graph:
        T, F = tasks[0].x_train.shape[1:]
        rstate = ingraph_init(rspec.capacity, (T, F), rspec.bits)
        train_step = jax.jit(_make_ingraph_replay_step(
            cfg, trainer, rspec, backend, raw_train))
        replay_traffic = _ingraph_replay_traffic(
            rspec, trainer.batch_size, schedule.steps_per_task, (T, F))
    else:
        train_step = jax.jit(raw_train)
        replay_traffic = schedule.replay_traffic
    if backend.telemetry.enabled and replay_traffic:
        backend.telemetry.record(replay_traffic)

    # Observability streams (repro.obs): the loop computes the same
    # per-step scalars the compiled scan emits, with the same jitted
    # reduction, so the two RunLogs are bit-identical.
    obs_on = obs is not None and getattr(obs, "metrics", False)
    if obs_on:
        from repro.obs import build_runlog, drift_stream, step_stats
        stats_fn = jax.jit(step_stats)
        obs_loss: list[np.ndarray] = []
        obs_pulses: list[np.ndarray] = []
        obs_dg: list[np.ndarray] = []
        obs_occ: list[np.ndarray] = []

    n_tasks = len(tasks)
    R = np.zeros((n_tasks, n_tasks))
    losses: list[float] = []

    for t in range(n_tasks):
        replay_on = jnp.asarray(t > 0)
        for s in range(schedule.x[t].shape[0]):
            key, k_step = jax.random.split(key)
            if in_graph:
                (params, opt_state, loss, applied, dev_state,
                 rstate) = train_step(
                    params, opt_state, k_step,
                    jnp.asarray(schedule.x[t][s]),
                    jnp.asarray(schedule.y[t][s]), dev_state, rstate,
                    replay_on)
            elif masked:
                params, opt_state, loss, applied, dev_state = train_step(
                    params, opt_state, k_step,
                    jnp.asarray(schedule.x[t][s]),
                    jnp.asarray(schedule.y[t][s]), dev_state,
                    jnp.asarray(schedule.row_valid[t][s]),
                    jnp.asarray(schedule.lengths[t][s]))
            else:
                params, opt_state, loss, applied, dev_state = train_step(
                    params, opt_state, k_step,
                    jnp.asarray(schedule.x[t][s]),
                    jnp.asarray(schedule.y[t][s]), dev_state)
            losses.append(float(loss))
            if obs_on:
                pu, dg, oc = stats_fn(applied, rstate)
                obs_loss.append(np.asarray(loss))
                obs_pulses.append(np.asarray(pu))
                obs_dg.append(np.asarray(dg))
                obs_occ.append(np.asarray(oc))
            backend.record_endurance(applied)
        key, k_eval = jax.random.split(key)
        if masked:
            for i, task in enumerate(tasks[:t + 1]):
                R[t, i] = float(evaluate(
                    params, k_eval, jnp.asarray(task.x_test),
                    jnp.asarray(task.y_test), dev_state,
                    jnp.asarray(ev_valid[i]), jnp.asarray(ev_len[i])))
        else:
            R[t, :t + 1] = evaluate_tasks(evaluate, params, k_eval,
                                          tasks, t, dev_state)

    out: dict[str, Any] = {
        "R": R,
        "MA": float(R[-1, :].mean()),
        "acc_after_each": [float(R[t, :t + 1].mean())
                           for t in range(n_tasks)],
        "losses": losses,
        "params": params,
    }
    if obs_on:
        cb = backend.spec.crossbar
        drifting = (dev_state is not None and cb is not None
                    and getattr(cb, "drift_rate", 0.0) > 0)
        total = sum(schedule.steps_per_task)
        out["runlog"] = build_runlog(
            cadence=obs.cadence,
            steps_per_task=schedule.steps_per_task,
            loss=np.stack(obs_loss) if obs_loss else np.zeros(0),
            write_pulses=np.stack(obs_pulses) if obs_pulses
            else np.zeros(0, np.int64),
            dg_mag=np.stack(obs_dg) if obs_dg else np.zeros(0),
            replay_occupancy=(np.stack(obs_occ) if obs_occ
                              else np.zeros(0, np.int32)) if in_graph
            else schedule.occupancy_stream(),
            drift_ticks=drift_stream(total, drifting=drifting),
            task_acc=R)
    if dev_state is not None:
        out["device_state"] = dev_state
    if backend.tracker is not None:
        out["endurance"] = backend.tracker
    if backend.telemetry.enabled:
        out["telemetry"] = backend.telemetry
    return out
