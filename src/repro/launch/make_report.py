"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON artifacts.

    PYTHONPATH=src python -m repro.launch.make_report > report.md
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import list_archs
from repro.configs.shapes import SHAPES
from repro.launch.roofline import (RESULTS_DIR, load_record, model_flops,
                                   roofline_from_record, summarize)


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(mesh: str, tag: str = "") -> str:
    rows = ["| arch | shape | status | compile s | args GB/dev | "
            "temp GB/dev | collective ops (count) |",
            "|---|---|---|---|---|---|---|"]
    for arch in list_archs():
        for shape in SHAPES:
            rec = load_record(arch, shape, mesh, tag)
            if rec is None:
                continue
            if rec.get("skipped"):
                rows.append(f"| {arch} | {shape} | SKIP (sub-quadratic "
                            f"attention required) | — | — | — | — |")
                continue
            if not rec.get("ok"):
                rows.append(f"| {arch} | {shape} | **FAIL** | — | — | — | "
                            f"{rec.get('error', '')[:60]} |")
                continue
            mem = rec.get("memory_analysis", {})
            colls = rec.get("hlo_analysis", {}).get("per_collective", {})
            coll_str = ", ".join(
                f"{k}×{int(v['count'])}" for k, v in sorted(colls.items()))
            rows.append(
                f"| {arch} | {shape} | OK | {rec.get('compile_s', '?')} "
                f"| {_gb(mem.get('argument_size_in_bytes', 0))} "
                f"| {_gb(mem.get('temp_size_in_bytes', 0))} "
                f"| {coll_str or '—'} |")
    return "\n".join(rows)


def roofline_table(mesh: str, tag: str = "",
                   flash_adjust: bool = False) -> str:
    rows = ["| arch | shape | compute ms | memory ms | collective ms | "
            "bound | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in summarize(mesh, tag, flash_adjust=flash_adjust):
        rows.append(r.row())
    return "\n".join(rows)


def perf_compare_table() -> str:
    """Baseline vs optimized per-cell step-time bound comparison."""
    rows = ["| arch | shape | baseline step ms (bound) | optimized step "
            "ms (bound) | +pallas-flash ms | Δ total |",
            "|---|---|---|---|---|---|"]
    base = {(r.arch, r.shape): r for r in summarize("16x16", "")}
    opt = {(r.arch, r.shape): r for r in summarize("16x16", "opt")}
    fl = {(r.arch, r.shape): r
          for r in summarize("16x16", "opt", flash_adjust=True)}
    for key, b in base.items():
        o = opt.get(key)
        f = fl.get(key)
        if o is None:
            continue
        gain = b.step_s / f.step_s if f and f.step_s else 1.0
        rows.append(
            f"| {key[0]} | {key[1]} | {b.step_s*1e3:.1f} ({b.bound}) "
            f"| {o.step_s*1e3:.1f} ({o.bound}) "
            f"| {f.step_s*1e3:.1f} | {gain:.2f}× |")
    return "\n".join(rows)


def main() -> None:
    print("## Dry-run — 16×16 (single pod, 256 chips), baseline\n")
    print(dryrun_table("16x16"))
    print("\n## Dry-run — 2×16×16 (two pods, 512 chips), baseline\n")
    print(dryrun_table("2x16x16"))
    print("\n## Roofline — baseline (16×16)\n")
    print(roofline_table("16x16"))
    print("\n## Roofline — optimized (ulysses + EP MoE, 16×16)\n")
    print(roofline_table("16x16", "opt"))
    print("\n## Roofline — optimized + pallas-flash adjustment\n")
    print(roofline_table("16x16", "opt", flash_adjust=True))
    print("\n## Baseline vs optimized\n")
    print(perf_compare_table())


if __name__ == "__main__":
    main()
