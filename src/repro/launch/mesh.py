"""Production mesh definitions.

A function, not a module-level constant — importing this module never
touches jax device state (jax locks the device count on first backend
init, and only dryrun.py is allowed to set the 512-device XLA flag).

Axes:
  pod   — cross-pod data parallelism (DCN): gradients all-reduce here;
          candidates for top-k + error-feedback compression.
  data  — in-pod FSDP axis: batch, parameter/optimizer sharding.
  model — TP/EP/SP axis: heads, FFN hidden, experts, vocab, sequence.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
