"""HLO analyzer: FLOPs / bytes / collective bytes with loop multipliers.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body
ONCE — with scanned layer stacks that under-counts by the layer count
(and by the KV-chunk count inside flash attention). This module parses
the post-optimization HLO text, builds the computation call graph, and
multiplies every instruction by the product of enclosing
``known_trip_count`` annotations.

Counted (per device — the HLO is the SPMD per-device program):
  * FLOPs — dot ops: 2 · prod(output dims) · prod(lhs contracting dims).
    Operand shapes are resolved through a module-wide name→shape table
    (post-optimization HLO references operands by name only).
  * bytes_accessed — sum of output-buffer bytes of every materialized
    instruction (fusion bodies excluded — not materialized), × loop
    multipliers. This counts each produced buffer once per execution;
    re-reads are not double-counted, so it is a slight lower bound.
  * collective bytes — output bytes per collective op type, × multiplier.

Validated in tests/test_hlo_analysis.py against analytic 6·N·D FLOPs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLED_RE = re.compile(r'(?:body|condition|to_apply|calls)=%?([\w.\-]+)')
_BRANCH_RE = re.compile(r'branch_computations=\{([^}]*)\}')
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

# Ops that do not materialize a new buffer (aliases/metadata) — excluded
# from the bytes_accessed traffic estimate. while/conditional/call carry
# tuples are aliased in place; their bodies are walked separately.
_NO_MATERIALIZE = frozenset({
    "", "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
    "while", "conditional", "call",
})

# In-place update ops: traffic = the update operand, not the full output.
_INPLACE_UPDATE = frozenset({"dynamic-update-slice", "scatter"})


def _dims_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(seg: str) -> int:
    return sum(_dims_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(seg))


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_bytes: int
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_fusion_body: bool = False


def _split_header_name(line: str) -> Optional[str]:
    if not line.rstrip().endswith("{"):
        return None
    if ") -> " not in line and "ENTRY" not in line:
        return None
    m = _HDR_RE.match(line.strip())
    return m.group(2) if m else None


def parse_module(hlo: str):
    """Returns (computations, shape_table name→(dtype, dims) of first
    output shape segment)."""
    comps: dict[str, Computation] = {}
    shapes: dict[str, tuple[str, str]] = {}
    fusion_bodies: set[str] = set()
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            name = _split_header_name(stripped)
            if name:
                cur = Computation(name, [])
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(stripped)
        if not im:
            continue
        iname = im.group(1)
        rest = stripped[im.end():]
        om = _OPCODE_RE.search(rest)
        opcode = om.group(1) if om else ""
        out_seg = rest[:om.start()] if om else rest
        out_bytes = _shapes_bytes(out_seg)
        first = _SHAPE_RE.search(out_seg)
        if first:
            shapes[iname] = (first.group(1), first.group(2))
        cur.instrs.append(Instr(iname, opcode, out_bytes, stripped))
        if opcode == "fusion":
            cm = _CALLED_RE.search(stripped)
            if cm:
                fusion_bodies.add(cm.group(1))
    for n in fusion_bodies:
        if n in comps:
            comps[n].is_fusion_body = True
    return comps, shapes


def _dot_flops(ins: Instr, shapes: dict) -> int:
    line = ins.line
    di = line.find(" dot(")
    if di < 0:
        return 0
    out = shapes.get(ins.name)
    if out is None:
        return 0
    out_elems = _dims_elems(out[1])
    ops = _OPERAND_RE.findall(line[di:])
    if not ops:
        return 0
    lhs = shapes.get(ops[0])
    if lhs is None:
        return 0
    lhs_dims = [int(d) for d in lhs[1].split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contracted = 1
    if mc:
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    return 2 * out_elems * contracted


def _update_operand_bytes(ins: Instr, shapes: dict) -> int:
    """For in-place ops, count the update operand (operand index 1)."""
    pi = ins.line.find("(")
    ops = _OPERAND_RE.findall(ins.line[pi:])
    if len(ops) >= 2 and ops[1] in shapes:
        dt, dims = shapes[ops[1]]
        return _dims_elems(dims) * _DTYPE_BYTES[dt]
    return ins.out_bytes


def _fusion_inplace_bytes(ins: Instr, comps: dict, shapes: dict
                          ) -> Optional[int]:
    """XLA fuses cache dynamic-update-slices into loop fusions whose
    output *is* the full cache buffer — in-place on TPU/CPU (buffer
    aliasing). When the fusion body's ROOT chain is a DUS with the same
    shape as the fusion output, count the DUS *update* operand instead of
    the whole cache. Returns None when not an in-place-update fusion."""
    cm = _CALLED_RE.search(ins.line)
    if not cm:
        return None
    body = comps.get(cm.group(1))
    if body is None:
        return None
    out_sig = shapes.get(ins.name)
    for bins in body.instrs:
        if bins.opcode == "dynamic-update-slice" \
                and shapes.get(bins.name) == out_sig:
            return _update_operand_bytes(bins, shapes)
    return None


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0,
                                                     "bytes": 0.0}))
    by_shape: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add_scaled(self, other: "Analysis", mult: float) -> None:
        self.flops += mult * other.flops
        self.bytes_accessed += mult * other.bytes_accessed
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.per_collective.items():
            self.per_collective[k]["count"] += mult * v["count"]
            self.per_collective[k]["bytes"] += mult * v["bytes"]
        for k, v in other.by_shape.items():
            self.by_shape[k] += mult * v

    def top_shapes(self, n: int = 12) -> list:
        return sorted(self.by_shape.items(), key=lambda kv: -kv[1])[:n]

    def as_dict(self) -> dict:
        return {"flops": self.flops,
                "bytes_accessed": self.bytes_accessed,
                "collective_bytes": self.collective_bytes,
                "per_collective": {k: dict(v) for k, v in
                                   self.per_collective.items()},
                "top_shapes": [
                    {"op_shape": f"{op} {shape}", "bytes": b}
                    for (op, shape), b in self.top_shapes()]}


def analyze(hlo: str) -> Analysis:
    comps, shapes = parse_module(hlo)
    if not comps:
        return Analysis()
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    entry = m.group(1) if m else next(iter(comps))
    memo: dict[tuple[str, bool], Analysis] = {}

    def walk(name: str, in_fusion: bool) -> Analysis:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        total = Analysis()
        memo[key] = total            # cycle guard (shouldn't happen)
        comp = comps.get(name)
        if comp is None:
            return total
        fusionish = in_fusion or comp.is_fusion_body
        for ins in comp.instrs:
            if ins.opcode == "dot":
                total.flops += _dot_flops(ins, shapes)
            if not fusionish and ins.opcode not in _NO_MATERIALIZE:
                if ins.opcode in _INPLACE_UPDATE:
                    b = _update_operand_bytes(ins, shapes)
                elif ins.opcode == "fusion":
                    ib = _fusion_inplace_bytes(ins, comps, shapes)
                    b = ib if ib is not None else ins.out_bytes
                else:
                    b = ins.out_bytes
                total.bytes_accessed += b
                sig = shapes.get(ins.name)
                total.by_shape[(ins.opcode,
                                f"{sig[0]}[{sig[1]}]" if sig else "?")] += b
            for coll in _COLL:
                if ins.opcode.startswith(coll):
                    total.collective_bytes += ins.out_bytes
                    total.per_collective[coll]["count"] += 1
                    total.per_collective[coll]["bytes"] += ins.out_bytes
                    break
            mult = 1.0
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    mult = float(tm.group(1))
            called = _CALLED_RE.findall(ins.line)
            bm = _BRANCH_RE.search(ins.line)
            if bm:
                called += [c.strip().lstrip("%")
                           for c in bm.group(1).split(",")]
            for cname in called:
                sub = walk(cname, fusionish or ins.opcode == "fusion")
                total.add_scaled(sub, mult)
        return total

    return walk(entry, False)
