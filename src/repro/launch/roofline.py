"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e-class, per brief):
    197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI

Terms, per (arch × shape × mesh), all in seconds *per step*:
    compute    = HLO_FLOPs            / (chips · peak)
    memory     = HLO_bytes            / (chips · hbm_bw)
    collective = collective_bytes     / (chips · link_bw)

FLOPs / bytes / collective bytes come from ``hlo_analysis`` (per-device
program, loop trip counts multiplied through — XLA's own cost_analysis
under-counts while bodies) scaled ×chips for the global figure. The
dominant term is the bottleneck the §Perf loop iterates on.

MODEL_FLOPS (the "useful" fraction):
    train  : 6 · N(active) · tokens  (+ 12·L·S²·H·hd attention term)
    prefill: 2 · N(active) · tokens  (+ attention term)
    decode : 2 · N(active) · batch   (+ 4·L·S·H·hd cache-attention term)
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

from repro.configs import get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    bound: str
    step_s: float                 # max of the three (no-overlap bound)
    roofline_frac: float          # compute_s / step_s ("% of roofline")
    per_collective: dict
    note: str = ""

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} "
                f"| {self.collective_s*1e3:.2f} | {self.bound} "
                f"| {self.useful_ratio:.2f} | {self.roofline_frac*100:.0f}% |")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytical 'useful' FLOPs per step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"]
    B, S = shape.global_batch, shape.seq_len

    # Attention score/value FLOPs (not in 6·N·D).
    hd = cfg.hd()
    n_attn_layers = sum(0 if cfg.is_ssm_layer(i) else 1
                        for i in range(cfg.n_layers))
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        attn_per_tok_pair = 2 * cfg.n_heads * (qk_dim + cfg.v_head_dim)
    else:
        attn_per_tok_pair = 4 * cfg.n_heads * hd

    if shape.kind == "train":
        tokens = B * S
        flops = 6 * n_active * tokens
        flops += 3 * n_attn_layers * attn_per_tok_pair * B * S * S / 2
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2 * n_active * tokens
        flops += n_attn_layers * attn_per_tok_pair * B * S * S / 2
    else:  # decode: one token per sequence, attention over S cache
        flops = 2 * n_active * B
        flops += n_attn_layers * attn_per_tok_pair * B * S
    return float(flops)


def load_record(arch: str, shape: str, mesh: str,
                tag: str = "", out_dir: Path = RESULTS_DIR
                ) -> Optional[dict]:
    suffix = f"-{tag}" if tag else ""
    f = out_dir / f"{arch}--{shape}--{mesh}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def attention_score_bytes(rec: dict, chunk: int = 1024) -> float:
    """HBM traffic of materialized attention score/probability chunks —
    the buffers the Pallas flash kernel (kernels/flash_attention.py)
    keeps in VMEM. Identified from the per-shape breakdown: 4-D dot /
    fusion outputs whose last dim is the attention chunk size.

    Used for the 'pallas-flash' adjusted memory term in §Perf: the
    kernel exists and is validated in interpret mode; the dry-run
    compiles the XLA fallback (CPU cannot codegen TPU Pallas), so the
    adjustment is applied analytically and transparently here."""
    hlo = rec.get("hlo_analysis") or {}
    total = 0.0
    for ent in hlo.get("top_shapes", []):
        op_shape = ent["op_shape"]
        if not op_shape.startswith(("dot", "fusion")):
            continue
        dims = op_shape.split("[")[-1].rstrip("]").split(",")
        if len(dims) == 4 and dims[-1] == str(chunk):
            total += ent["bytes"]
    return total


def roofline_from_record(rec: dict, flash_adjust: bool = False
                         ) -> Optional[Roofline]:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    hlo = rec.get("hlo_analysis")
    if not hlo:
        return None
    chips = rec["chips"]
    # hlo_analysis numbers are per-device; wall-clock per step:
    compute_s = hlo["flops"] / PEAK_FLOPS
    bytes_acc = hlo["bytes_accessed"]
    note = ""
    if flash_adjust:
        adj = attention_score_bytes(rec)
        if adj:
            bytes_acc -= adj
            note = f"pallas-flash −{adj:.2e} B score traffic"
    memory_s = bytes_acc / HBM_BW
    collective_s = hlo["collective_bytes"] / LINK_BW
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = hlo["flops"] * chips
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    step_s = max(terms.values())
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops=mf, hlo_flops=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        bound=bound, step_s=step_s,
        roofline_frac=compute_s / step_s if step_s else 0.0,
        per_collective=hlo.get("per_collective", {}), note=note)


def summarize(mesh: str = "16x16", tag: str = "",
              out_dir: Path = RESULTS_DIR,
              flash_adjust: bool = False) -> list[Roofline]:
    from repro.configs import list_archs
    out = []
    for arch in list_archs():
        for shape in SHAPES:
            rec = load_record(arch, shape, mesh, tag, out_dir)
            if rec is None:
                continue
            r = roofline_from_record(rec, flash_adjust)
            if r is not None:
                out.append(r)
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = summarize(args.mesh, args.tag)
    print("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| bound | useful | roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(r.row())


if __name__ == "__main__":
    main()
