import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
backend initialization, and the production meshes need 512 placeholder
host devices. (Only this entry point sets the flag; tests and benches see
the real single device.)

Per cell:
  * build ShapeDtypeStruct inputs (configs.shapes.input_specs — no
    allocation),
  * jit the step with explicit in/out shardings from
    distributed.sharding, lower, compile,
  * record memory_analysis / cost_analysis / per-collective byte counts
    parsed from the compiled HLO,
  * append the record to benchmarks/results/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --seq-par=0 --remat=1   (perf knobs)
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.distributed import (ShardingContext, batch_specs, cache_specs,
                               opt_state_specs, param_specs, sharding_scope)
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.utils import tree_bytes, tree_size

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z0-9.]*\s*=?\s*\(?([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO."""
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op, dt, dims = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_optimizer(cfg, n_params: int):
    """8-bit Adam moments for the ≥100 B configs, fp32 AdamW otherwise."""
    if n_params > 100e9:
        return optim.adam_8bit(3e-4), "adam_8bit"
    return optim.adamw(3e-4), "adamw"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               seq_par: bool = True, remat: bool = True,
               extra_tag: str = "", attn_mode: str = "gather",
               moe_mode: str = "global",
               kv_dtype: str = "", quant: str = "none") -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, remat=remat)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    if quant and quant != "none":
        # Quantized execution modes resolve through the device-backend
        # registry — fail fast on unknown substrates, before compiling.
        from repro.backends import get_backend
        get_backend(quant)
        cfg = dataclasses.replace(cfg, quant_mode=quant)

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_chips = mesh.devices.size

    pshapes = lm.param_shapes(cfg)
    n_params = tree_size(pshapes)
    # Serving cells drop FSDP when TP-only weights fit per-chip HBM —
    # removes all per-layer weight gathers from the decode step (§Perf).
    tp_bytes_per_chip = tree_bytes(pshapes) / mesh.shape["model"]
    use_fsdp = shape.kind != "decode" or tp_bytes_per_chip > 8e9
    # Replicated small banks pair with the EP-local dispatch (train /
    # prefill); decode uses the global path where TP-sharded banks win.
    pspecs = param_specs(cfg, pshapes, mesh, fsdp=use_fsdp,
                         replicate_small_banks=(moe_mode == "ep" and
                                                shape.kind != "decode"))
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips), "kind": shape.kind,
        "n_params": int(n_params),
        "param_bytes": int(tree_bytes(pshapes)),
        "seq_par": seq_par, "remat": remat, "tag": extra_tag,
        "fsdp": use_fsdp, "quant_mode": cfg.quant_mode,
    }

    record["attn_mode"] = attn_mode
    record["moe_mode"] = moe_mode
    ctx = ShardingContext(mesh=mesh, batch_axes=batch_axes,
                          sequence_parallel=seq_par and
                          shape.kind != "decode",
                          attn_mode=attn_mode, moe_mode=moe_mode)
    t0 = time.time()

    with sharding_scope(ctx):
        if shape.kind == "train":
            specs = input_specs(cfg, shape_name)
            optimizer, opt_name = make_optimizer(cfg, n_params)
            record["optimizer"] = opt_name
            oshapes = jax.eval_shape(optimizer.init, pshapes)
            ospecs = opt_state_specs(oshapes, pspecs, mesh)
            bspecs = batch_specs(specs, mesh, multi_pod)
            record["opt_bytes"] = int(tree_bytes(oshapes))

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, cfg, batch))(params)
                updates, new_opt = optimizer.update(grads, opt_state,
                                                    params)
                new_params = optim.apply_updates(params, updates)
                return new_params, new_opt, loss

            step = jax.jit(
                train_step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                              _named(mesh, bspecs)),
                out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1))
            lowered = step.lower(pshapes, oshapes, specs)

        elif shape.kind == "prefill":
            specs = input_specs(cfg, shape_name)
            bspecs = batch_specs(specs, mesh, multi_pod)
            logits_spec = ctx.spec("btv")
            if cfg.vocab % mesh.shape["model"] != 0:
                logits_spec = P(logits_spec[0], None, None)

            def prefill_step(params, batch):
                return lm.prefill(params, cfg, batch)

            step = jax.jit(
                prefill_step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
                out_shardings=NamedSharding(mesh, logits_spec))
            lowered = step.lower(pshapes, specs)

        else:  # decode
            specs = input_specs(cfg, shape_name)
            cspecs = cache_specs(specs["caches"], mesh, multi_pod)
            tok_spec = batch_specs(
                {"tokens": specs["tokens"]}, mesh, multi_pod)["tokens"]
            record["cache_bytes"] = int(tree_bytes(specs["caches"]))

            def serve_step(params, caches, tokens, pos):
                logits, new_caches = lm.decode_step(params, cfg, caches,
                                                    tokens, pos)
                return lm.greedy_token(logits), new_caches

            B = specs["tokens"].shape[0]
            n_dp = 1
            for a in batch_axes:
                n_dp *= mesh.shape[a]
            out_tok_spec = P(batch_axes if len(batch_axes) > 1
                             else batch_axes[0]) if B % n_dp == 0 else P()
            step = jax.jit(
                serve_step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, out_tok_spec),
                               _named(mesh, cspecs)),
                donate_argnums=(1,))
            lowered = step.lower(pshapes, specs["caches"], specs["tokens"],
                                 specs["pos"])

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    # ------------------------------------------------------------------
    # Analyses
    # ------------------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover - backend-dependent
        record["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        record["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower())}
    except Exception as e:  # pragma: no cover
        record["cost_analysis"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        record["collectives"] = collective_bytes(hlo)
        record["hlo_chars"] = len(hlo)
        from repro.launch.hlo_analysis import analyze
        record["hlo_analysis"] = analyze(hlo).as_dict()
        del hlo
    except Exception as e:  # pragma: no cover
        record["collectives"] = {"error": str(e)}

    record["ok"] = True
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             seq_par: bool = True, remat: bool = True,
             tag: str = "", attn_mode: str = "gather",
             moe_mode: str = "global", kv_dtype: str = "",
             quant: str = "none") -> dict:
    reason = skip_reason(get_config(arch), shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": reason, "ok": True}
    try:
        return lower_cell(arch, shape_name, multi_pod, seq_par, remat, tag,
                          attn_mode, moe_mode, kv_dtype, quant)
    except Exception as e:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-par", type=int, default=1)
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--attn", default="gather",
                    choices=["gather", "ulysses"])
    ap.add_argument("--moe", default="global", choices=["global", "ep"])
    ap.add_argument("--kv", default="", choices=["", "bf16", "int8"])
    ap.add_argument("--quant", default="none",
                    help="quantized execution substrate: any name in the "
                         "repro.backends registry (validated before "
                         "compile), or 'none'")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            rec = run_cell(arch, shape_name, args.multi_pod,
                           bool(args.seq_par), bool(args.remat), args.tag,
                           args.attn, args.moe, args.kv, args.quant)
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            suffix = f"-{args.tag}" if args.tag else ""
            fname = out_dir / f"{arch}--{shape_name}--{mesh_tag}{suffix}.json"
            fname.write_text(json.dumps(rec, indent=1))
            status = ("SKIP" if rec.get("skipped")
                      else "OK" if rec.get("ok") else "FAIL")
            print(f"[{status}] {arch} × {shape_name} × {mesh_tag}"
                  + (f"  compile={rec.get('compile_s')}s"
                     if "compile_s" in rec else "")
                  + (f"  {rec.get('error', '')}" if not rec.get("ok")
                     else ""), flush=True)
            n_fail += 0 if rec.get("ok") else 1
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
