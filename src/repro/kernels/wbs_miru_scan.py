"""Pallas TPU kernel: fused device-true MiRU recurrence (WBS × eqs. 1-2).

This is the quantized-hardware analogue of ``miru_scan``: one kernel runs
the *entire* hidden recurrence the way the chip does — the recurrent
crossbar tile and the hidden state never leave VMEM between timesteps —
instead of the per-timestep hot loop that launches a fresh
``wbs_matmul_pallas`` grid (plus re-quantization and re-padding in jnp and
an HBM round-trip for ``h``) at every step.

Dataflow per (i, t) grid cell (T innermost ⇒ sequential time per batch
tile, the paper's §IV-B-1 tiling with ``h`` in the shift-register file):

  VMEM-resident across all T steps:  u_ref   (H, H)  pre-scaled U_h/clip
                                     h_scr   (bm, H) carried hidden state
  streamed per step:                 drive   (bm, 1, H) precomputed input
                                     gains   (1, nb)   per-step plane gains
  per step, entirely in VMEM:
    1. sign-magnitude quantize β·h to n_bits   (the WBS buffer write)
    2. acc = Σ_b gains[t, b] · (plane_b ⊙ sign) @ u      (MXU per plane)
    3. pre = (drive_t + acc·2^nb/(2^nb−1)·w_scale) + b_h (the integrator)
    4. ADC epilogue (optional mid-rise quantizer)
    5. h ← λ·h + (1−λ)·tanh(pre)               (the λ-interpolator)

The input projection x@W_h has no sequential dependency, so it is NOT in
this kernel: callers hoist it into one batched (B·T, K) WBS matmul
(``ops.wbs_input_drive``) and pass the resulting drive.

``gains`` is (T, n_bits): per-step memristor-ratio plane gains, so a
stochastic gain draw per timestep (the per-step path's behavior under
``gain_sigma > 0``) streams through the same kernel; ideal ratios are just
T identical rows.

Bit-exactness contract: at ``read_sigma == 0`` this kernel computes the
same per-plane accumulation order as the per-timestep
``wbs_matmul_pallas`` path, and ``ref.wbs_miru_scan_ref`` mirrors the jnp
(einsum) per-step path — both asserted in tests/test_fused_recurrence.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wbs_miru_kernel(drive_ref, u_ref, h0_ref, b_ref, gains_ref,
                     hall_ref, hprev_ref, pre_ref, h_scr, *,
                     beta: float, lam: float, n_bits: int,
                     adc_bits: Optional[int], adc_range: float,
                     w_scale: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h = h_scr[...]
    u = u_ref[...].astype(jnp.float32)

    # 1. Sign-magnitude quantization of the recurrent drive β·h — the
    # host-side buffer write the per-step path does in jnp, here done
    # in-kernel so h never leaves VMEM.
    top = float(2 ** n_bits - 1)
    bh = beta * h
    mag = jnp.clip(jnp.round(jnp.abs(bh) * top), 0.0, top)
    sign = jnp.sign(bh)
    code = mag.astype(jnp.int32)

    # 2. One MXU matmul per bit plane, gain-weighted with this step's
    # plane gains (same accumulation order as wbs_matmul_pallas).
    acc = jnp.zeros_like(h)
    for b in range(n_bits):
        shift = n_bits - 1 - b                     # MSB first (k=1 ⇒ 2^-1)
        plane = ((code >> shift) & 1).astype(jnp.float32) * sign
        acc = acc + gains_ref[0, b] * jnp.dot(
            plane, u, preferred_element_type=jnp.float32)

    # 3. Integrator: normalized crossbar read, de-scaled to logical
    # weights, summed with the precomputed input drive and the bias —
    # in the exact fp order of the per-step path: (v_w + v_u) + b_h.
    y = acc * (2.0 ** n_bits / (2.0 ** n_bits - 1.0)) * w_scale
    pre = (drive_ref[:, 0, :].astype(jnp.float32) + y) + b_ref[...]

    # 4. Fused output ADC (mid-rise, matching analog/adc.adc_quantize).
    if adc_bits is not None:
        levels = 2 ** adc_bits
        step = 2.0 * adc_range / levels
        pre = jnp.clip(jnp.round(pre / step),
                       -(levels // 2), levels // 2 - 1) * step

    # 5. λ-interpolation; h stays in VMEM for the next step.
    h_new = lam * h + (1.0 - lam) * jnp.tanh(pre)
    h_scr[...] = h_new
    hall_ref[:, 0, :] = h_new
    hprev_ref[:, 0, :] = h
    pre_ref[:, 0, :] = pre


@functools.partial(jax.jit, static_argnames=(
    "beta", "lam", "n_bits", "adc_bits", "adc_range", "w_scale", "bm",
    "interpret"))
def wbs_miru_scan_pallas(drive: jax.Array, u_scaled: jax.Array,
                         h0: jax.Array, b_h: jax.Array, gains: jax.Array,
                         beta: float, lam: float, n_bits: int,
                         adc_bits: Optional[int] = None,
                         adc_range: float = 4.0, w_scale: float = 1.0,
                         bm: int = 8, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """drive (B, T, H) precomputed input projection (no bias); u_scaled
    (H, H) recurrent weights already divided by the logical weight scale;
    h0 (B, H); b_h (1, H); gains (T, n_bits) per-step plane gains.

    Returns (h_all, h_prev, pre), each (B, T, H) f32. B must divide by bm
    and H should be 128-aligned (ops.py pads; zero-padding is exact —
    padded columns quantize to sign 0 and contribute nothing).
    """
    B, T, H = drive.shape
    assert B % bm == 0, (B, bm)
    assert u_scaled.shape == (H, H) and h0.shape == (B, H)
    assert b_h.shape == (1, H) and gains.shape == (T, n_bits)

    grid = (B // bm, T)
    kernel = functools.partial(
        _wbs_miru_kernel, beta=float(beta), lam=float(lam), n_bits=n_bits,
        adc_bits=adc_bits, adc_range=float(adc_range),
        w_scale=float(w_scale))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),   # drive
            pl.BlockSpec((H, H), lambda i, t: (0, 0)),          # u_scaled
            pl.BlockSpec((bm, H), lambda i, t: (i, 0)),         # h0
            pl.BlockSpec((1, H), lambda i, t: (0, 0)),          # b_h
            pl.BlockSpec((1, gains.shape[1]), lambda i, t: (t, 0)),  # gains
        ],
        out_specs=[
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),   # h_all
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),   # h_prev
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),   # pre
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, H), jnp.float32)],
        interpret=interpret,
    )(drive, u_scaled, h0, b_h, gains)
    return out[0], out[1], out[2]
