"""Pallas TPU kernels for M2RU's compute hot-spots.

- wbs_matmul: weighted-bit-streaming crossbar VMM (the paper's §V-A,
  TPU-adapted: bit-planes as MXU matmuls, fused gains + ADC epilogue).
- miru_scan:  fused MiRU recurrence (grid-sequential time, h carried in
  VMEM scratch — the TPU analogue of the paper's tiled interpolation).
- wbs_miru_scan: the device-true fused recurrence — WBS quantization,
  per-step plane gains, bit-plane MXU accumulation and the ADC epilogue
  all inside one kernel, with u_h and h VMEM-resident across timesteps
  (bit-identical to the per-step device_vmm scan; docs/kernels.md).
- kwta:       k-winner-take-all via threshold bisection (digital twin of
  the voltage-mode circuit, Fig. 3-Right).
- flash_attention: fwd + dq/dkv bwd kernels — the beyond-paper fix for
  the score-traffic memory bound found in the dry-run roofline.

ops.py — public jit'd wrappers (padding, dispatch, interpret-mode on CPU).
ref.py — pure-jnp oracles; every kernel is swept against them in
tests/test_kernels.py across shapes and dtypes.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
