"""Pallas TPU kernel: fused MiRU recurrence (eqs. 1-2).

The input projection x@W_h + b_h is one big MXU matmul done *outside* (it
has no sequential dependency); this kernel runs the inherently-sequential
part — the (β·h)U_h recurrence and λ-interpolation — with the hidden state
carried in VMEM scratch across a sequential time grid.

This is the TPU analogue of the paper's tiling scheme (§IV-B-1): batch
tiles are the concurrent units ("tiles work concurrently at the layer
level"), time steps are sequential within each tile, and the carried
h never leaves VMEM between steps (the paper's shift-register file).

Grid = (B/bm, T), T innermost ⇒ for a fixed batch tile the kernel visits
t = 0..T−1 in order; `h_scratch` is the carried state, re-seeded from h0
at t == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _miru_kernel(xw_ref, u_ref, h0_ref, hall_ref, pre_ref, h_scratch, *,
                 beta: float, lam: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    h = h_scratch[...]
    u = u_ref[...].astype(jnp.float32)
    pre = xw_ref[:, 0, :].astype(jnp.float32) + jnp.dot(
        beta * h, u, preferred_element_type=jnp.float32)
    h_new = lam * h + (1.0 - lam) * jnp.tanh(pre)
    h_scratch[...] = h_new
    hall_ref[:, 0, :] = h_new
    pre_ref[:, 0, :] = pre


@functools.partial(jax.jit, static_argnames=("beta", "lam", "bm",
                                             "interpret"))
def miru_scan_pallas(xw: jax.Array, u_h: jax.Array, h0: jax.Array,
                     beta: float, lam: float, bm: int = 8,
                     interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """xw (B, T, H) precomputed input drive; u_h (H, H); h0 (B, H).

    Returns (h_all, pre), both (B, T, H) f32. B must divide by bm and H
    should be 128-aligned (ops.py pads).
    """
    B, T, H = xw.shape
    assert B % bm == 0, (B, bm)
    assert u_h.shape == (H, H) and h0.shape == (B, H)

    grid = (B // bm, T)
    kernel = functools.partial(_miru_kernel, beta=float(beta),
                               lam=float(lam))
    h_all, pre = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),  # xw
            pl.BlockSpec((H, H), lambda i, t: (0, 0)),         # u_h
            pl.BlockSpec((bm, H), lambda i, t: (i, 0)),        # h0
        ],
        out_specs=[
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),  # h_all
            pl.BlockSpec((bm, 1, H), lambda i, t: (i, t, 0)),  # pre
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, H), jnp.float32)],
        interpret=interpret,
    )(xw, u_h, h0)
    return h_all, pre
