"""Pallas TPU kernel: weighted-bit-streaming crossbar matmul (§V-A).

TPU adaptation of the paper's WBS (DESIGN.md §2): the chip streams input
bits serially over time with memristor-ratio gains 2^{-k}; the MXU instead
evaluates all n_b bit-planes as matmuls inside one VMEM-resident kernel,
accumulating gain-weighted partial products in an fp32 scratch accumulator
(the integrator) and applying the ADC quantizer in the epilogue.

Dataflow per (i, j, k) grid cell (K innermost → accumulator carries):
    acc[i,j] += Σ_b gains[b] · ((code_tile >> (nb−1−b)) & 1 ⊙ sign) @ w_tile
epilogue (k == K−1):
    out = ADC( acc · 2^nb/(2^nb − 1) )

Block shapes default to 128-aligned tiles (MXU native); the ops.py wrapper
pads arbitrary shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wbs_kernel(sign_ref, code_ref, w_ref, gains_ref, out_ref, acc_ref, *,
                n_bits: int, n_k: int, adc_bits: Optional[int],
                adc_range: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sign = sign_ref[...].astype(jnp.float32)
    code = code_ref[...]
    w = w_ref[...].astype(jnp.float32)

    acc = acc_ref[...]
    # One MXU matmul per bit plane, gain-weighted (the analog bit
    # significance). n_bits is static → fully unrolled.
    for b in range(n_bits):
        shift = n_bits - 1 - b                      # MSB first (k=1 ⇒ 2^-1)
        plane = ((code >> shift) & 1).astype(jnp.float32) * sign
        acc = acc + gains_ref[0, b] * jnp.dot(
            plane, w, preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = acc_ref[...] * (2.0 ** n_bits / (2.0 ** n_bits - 1.0))
        if adc_bits is not None:
            levels = 2 ** adc_bits
            step = 2.0 * adc_range / levels
            y = jnp.clip(jnp.round(y / step),
                         -(levels // 2), levels // 2 - 1) * step
        out_ref[...] = y


@functools.partial(jax.jit, static_argnames=(
    "adc_bits", "adc_range", "bm", "bk", "bn", "interpret"))
def wbs_matmul_pallas(sign: jax.Array, code: jax.Array, w: jax.Array,
                      gains: jax.Array, adc_bits: Optional[int] = None,
                      adc_range: float = 4.0, bm: int = 128, bk: int = 128,
                      bn: int = 128, interpret: bool = False) -> jax.Array:
    """sign/code (M, K) int8/uint8, w (K, N), gains (n_bits,) → (M, N) f32.

    Shapes must already be multiples of the block sizes (ops.py pads).
    """
    M, K = sign.shape
    K2, N = w.shape
    assert K == K2, (sign.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    n_bits = gains.shape[0]
    gains2d = gains.reshape(1, n_bits).astype(jnp.float32)
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    kernel = functools.partial(_wbs_kernel, n_bits=n_bits, n_k=n_k,
                               adc_bits=adc_bits, adc_range=adc_range)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # sign
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # code
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((1, n_bits), lambda i, j, k: (0, 0)),  # gains
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(sign, code, w, gains2d)
