"""Pallas TPU kernel: weighted-bit-streaming crossbar matmul (§V-A).

TPU adaptation of the paper's WBS (DESIGN.md §2): the chip streams input
bits serially over time with memristor-ratio gains 2^{-k}; the MXU instead
evaluates all n_b bit-planes as matmuls inside one VMEM-resident kernel,
accumulating gain-weighted partial products in an fp32 scratch accumulator
(the integrator) and applying the ADC quantizer in the epilogue.

Dataflow per (i, j, k) grid cell (K innermost → accumulator carries):
    acc[i,j] += Σ_b gains[b] · ((code_tile >> (nb−1−b)) & 1 ⊙ sign) @ w_tile
epilogue (k == K−1):
    out = ADC( acc · 2^nb/(2^nb − 1) )

With ``read_sigma > 0`` the kernel models per-access conductance read
noise (``CrossbarSpec.read_sigma``) *inside* the kernel: each grid cell
seeds the on-chip PRNG from (seed, cell-id) and perturbs its weight tile
with Box–Muller gaussians — every access to a weight element sees a fresh
draw, with no (K, N) noise matrix materialized in HBM. The TPU PRNG has no
CPU interpret-mode lowering, so ``ops.wbs_matmul`` applies the jnp
reference noise model up front on CPU instead (one draw per call).

Block shapes default to 128-aligned tiles (MXU native); the ops.py wrapper
pads arbitrary shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _uniform_01(shape):
    """Uniform in (0, 1] from the on-chip PRNG (24-bit mantissa).

    ``prng_random_bits`` yields *int32*; bitcast to uint32 before the
    shift — an arithmetic shift on the signed view would send half of
    all draws negative (then clamp to 2^-24, wrecking the distribution).
    """
    bits = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    u = (bits >> 8).astype(jnp.float32) * (2.0 ** -24)
    return jnp.maximum(u, 2.0 ** -24)


def _wbs_kernel(sign_ref, code_ref, w_ref, gains_ref, *refs,
                n_bits: int, n_k: int, adc_bits: Optional[int],
                adc_range: float, read_sigma: float):
    if read_sigma > 0:
        seed_ref, out_ref, acc_ref = refs
    else:
        out_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sign = sign_ref[...].astype(jnp.float32)
    code = code_ref[...]
    w = w_ref[...].astype(jnp.float32)

    if read_sigma > 0:
        # Fresh per-access conductance noise: unique PRNG stream per grid
        # cell, Box–Muller normals over the weight tile.
        i, j = pl.program_id(0), pl.program_id(1)
        cell = (i * pl.num_programs(1) + j) * pl.num_programs(2) + k
        pltpu.prng_seed(seed_ref[0], cell)
        u1 = _uniform_01(w.shape)
        u2 = _uniform_01(w.shape)
        z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
        w = w * (1.0 + read_sigma * z)

    acc = acc_ref[...]
    # One MXU matmul per bit plane, gain-weighted (the analog bit
    # significance). n_bits is static → fully unrolled.
    for b in range(n_bits):
        shift = n_bits - 1 - b                      # MSB first (k=1 ⇒ 2^-1)
        plane = ((code >> shift) & 1).astype(jnp.float32) * sign
        acc = acc + gains_ref[0, b] * jnp.dot(
            plane, w, preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = acc_ref[...] * (2.0 ** n_bits / (2.0 ** n_bits - 1.0))
        if adc_bits is not None:
            levels = 2 ** adc_bits
            step = 2.0 * adc_range / levels
            y = jnp.clip(jnp.round(y / step),
                         -(levels // 2), levels // 2 - 1) * step
        out_ref[...] = y


@functools.partial(jax.jit, static_argnames=(
    "adc_bits", "adc_range", "bm", "bk", "bn", "read_sigma", "interpret"))
def wbs_matmul_pallas(sign: jax.Array, code: jax.Array, w: jax.Array,
                      gains: jax.Array, adc_bits: Optional[int] = None,
                      adc_range: float = 4.0, bm: int = 128, bk: int = 128,
                      bn: int = 128, read_sigma: float = 0.0,
                      seed: Optional[jax.Array] = None,
                      interpret: bool = False) -> jax.Array:
    """sign/code (M, K) int8/uint8, w (K, N), gains (n_bits,) → (M, N) f32.

    Shapes must already be multiples of the block sizes (ops.py pads).
    ``read_sigma > 0`` requires a ``seed`` (shape (1,) int32) and a
    compiled TPU target — the in-kernel PRNG has no interpret-mode
    lowering (ops.py falls back to the jnp noise model on CPU).
    """
    M, K = sign.shape
    K2, N = w.shape
    assert K == K2, (sign.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    n_bits = gains.shape[0]
    gains2d = gains.reshape(1, n_bits).astype(jnp.float32)
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    kernel = functools.partial(_wbs_kernel, n_bits=n_bits, n_k=n_k,
                               adc_bits=adc_bits, adc_range=adc_range,
                               read_sigma=read_sigma)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # sign
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # code
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w
        pl.BlockSpec((1, n_bits), lambda i, j, k: (0, 0)),  # gains
    ]
    operands = [sign, code, w, gains2d]
    if read_sigma > 0:
        if seed is None:
            raise ValueError("read_sigma > 0 requires a PRNG seed")
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))  # seed
        operands.append(seed.astype(jnp.int32).reshape(1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
