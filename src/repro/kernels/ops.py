"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples, dtype handling,
interpret-mode dispatch (interpret=True on CPU — kernels execute in
Python for bit-exact validation; compiled on TPU), and jnp fallbacks
where a kernel's VMEM contract would be violated (documented per-op).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kwta import kwta_pallas
from repro.kernels.miru_scan import miru_scan_pallas
from repro.kernels.wbs_matmul import wbs_matmul_pallas
from repro.utils import round_up


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    return jnp.pad(x, ((0, m - x.shape[0]), (0, n - x.shape[1])))


# ---------------------------------------------------------------------------
# WBS matmul
# ---------------------------------------------------------------------------

def quantize_inputs(x: jax.Array, n_bits: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Sign-magnitude digitization of x ∈ [-1, 1] (the host-side buffer
    write that precedes WBS streaming). Alias of the canonical
    ``repro.analog.wbs.quantize_signed``."""
    from repro.analog.wbs import quantize_signed
    return quantize_signed(x, n_bits)


def wbs_matmul(sign: jax.Array, code: jax.Array, w: jax.Array,
               gains: jax.Array, adc_bits: Optional[int] = None,
               adc_range: float = 4.0, block: int = 128,
               read_sigma: float = 0.0,
               read_key: Optional[jax.Array] = None) -> jax.Array:
    """Padded/dispatched WBS crossbar matmul. See wbs_matmul_pallas.

    ``read_sigma``/``read_key`` model per-access conductance read noise.
    On compiled targets the noise is drawn inside the kernel (a fresh
    draw per weight-tile access); in interpret mode (CPU) the TPU PRNG
    has no lowering, so the jnp reference model — one draw per weight
    element per call — is applied to ``w`` up front.
    """
    M, K = sign.shape
    _, N = w.shape
    seed = None
    if read_sigma > 0:
        if read_key is None:
            raise ValueError("read_sigma > 0 requires read_key")
        if _interpret():
            w = w * (1.0 + read_sigma
                     * jax.random.normal(read_key, w.shape))
            read_sigma = 0.0
        else:
            seed = jax.random.randint(read_key, (1,), 0, 2 ** 31 - 1,
                                      dtype=jnp.int32)
    bm = min(block, round_up(M, 8))
    bk = min(block, round_up(K, 128))
    bn = min(block, round_up(N, 128))
    Mp, Kp, Np = round_up(M, bm), round_up(K, bk), round_up(N, bn)
    sign_p = _pad2(sign, Mp, Kp)     # sign=0 ⇒ padded inputs contribute 0
    code_p = _pad2(code, Mp, Kp)
    w_p = _pad2(w, Kp, Np)
    y = wbs_matmul_pallas(sign_p, code_p, w_p, gains, adc_bits=adc_bits,
                          adc_range=adc_range, bm=bm, bk=bk, bn=bn,
                          read_sigma=read_sigma, seed=seed,
                          interpret=_interpret())
    return y[:M, :N]


def wbs_dense(x: jax.Array, w: jax.Array, n_bits: int = 8,
              adc_bits: Optional[int] = 8, adc_range: float = 4.0,
              gains: Optional[jax.Array] = None,
              read_sigma: float = 0.0,
              read_key: Optional[jax.Array] = None) -> jax.Array:
    """QuantMode.WBS linear layer: float activations → sign-magnitude
    codes → bit-plane crossbar matmul. x (..., K) @ w (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if gains is None:
        gains = 2.0 ** (-jnp.arange(1, n_bits + 1, dtype=jnp.float32))
    sign, code = quantize_inputs(x2, n_bits)
    y = wbs_matmul(sign, code, w, gains, adc_bits, adc_range,
                   read_sigma=read_sigma, read_key=read_key)
    return y.reshape(*lead, w.shape[-1])


def device_vmm(x: jax.Array, w: jax.Array, backend="wbs",
               key: Optional[jax.Array] = None, **backend_kwargs
               ) -> jax.Array:
    """Registry-dispatched VMM: route x @ w through a registered device
    backend ("ideal" | "wbs" | "analog" | any custom registration).
    ``backend`` is a name or a DeviceBackend instance; extra kwargs
    (``spec``, ``spec_overrides``, …) pass through to ``get_backend``.
    Activity lands on the backend's telemetry when enabled."""
    from repro.backends import get_backend
    return get_backend(backend, **backend_kwargs).device_vmm(x, w, key)


# ---------------------------------------------------------------------------
# MiRU fused recurrence
# ---------------------------------------------------------------------------

def miru_scan(xw: jax.Array, u_h: jax.Array, h0: jax.Array, beta: float,
              lam: float) -> tuple[jax.Array, jax.Array]:
    """Fused MiRU recurrence. xw (B,T,H), u_h (H,H), h0 (B,H)."""
    B, T, H = xw.shape
    bm = 8 if B >= 8 else B
    Bp = round_up(B, bm)
    Hp = round_up(H, 128)
    if Bp != B or Hp != H:
        xw_p = jnp.pad(xw, ((0, Bp - B), (0, 0), (0, Hp - H)))
        u_p = jnp.pad(u_h, ((0, Hp - H), (0, Hp - H)))
        h0_p = jnp.pad(h0, ((0, Bp - B), (0, Hp - H)))
    else:
        xw_p, u_p, h0_p = xw, u_h, h0
    h_all, pre = miru_scan_pallas(xw_p, u_p, h0_p, beta=beta, lam=lam,
                                  bm=bm, interpret=_interpret())
    return h_all[:B, :, :H], pre[:B, :, :H]


# ---------------------------------------------------------------------------
# Flash attention (forward)
# ---------------------------------------------------------------------------

def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, bq: int = 128, bk: int = 128
                        ) -> tuple[jax.Array, jax.Array]:
    """(B, Sq, H, dh) layout wrapper around the Pallas flash forward.

    Pads Sq/Sk to block multiples; repeats GQA KV heads; returns
    (out (B,Sq,H,dv), lse (B,H,Sq))."""
    from repro.kernels.flash_attention import flash_attention_fwd_pallas
    B, Sq, H, dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    rep = H // Kh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    dv = v.shape[-1]
    bq = min(bq, round_up(Sq, 8))
    bk = min(bk, round_up(Sk, 8))
    Sqp, Skp = round_up(Sq, bq), round_up(Sk, bk)
    qt = jnp.swapaxes(q, 1, 2).reshape(B * H, Sq, dh)
    kt = jnp.swapaxes(k, 1, 2).reshape(B * H, Sk, dh)
    vt = jnp.swapaxes(v, 1, 2).reshape(B * H, Sk, dv)
    qt = jnp.pad(qt, ((0, 0), (0, Sqp - Sq), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, Skp - Sk), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, Skp - Sk), (0, 0)))
    out, lse = flash_attention_fwd_pallas(
        qt, kt, vt, causal=causal, bq=bq, bk=bk, sk_true=Sk,
        interpret=_interpret())
    out = out[:, :Sq].reshape(B, H, Sq, dv)
    return jnp.swapaxes(out, 1, 2), lse[:, :Sq].reshape(B, H, Sq)


# ---------------------------------------------------------------------------
# k-WTA
# ---------------------------------------------------------------------------

_KWTA_VMEM_LIMIT = 1 << 20  # rows longer than this fall back to jnp top_k


def kwta(x: jax.Array, k: int, iters: int = 32) -> jax.Array:
    """Per-row k-WTA by magnitude. 1-D input treated as a single row."""
    squeeze = x.ndim == 1
    x2 = x[None, :] if squeeze else x
    R, N = x2.shape
    if k >= N:
        return x
    if N > _KWTA_VMEM_LIMIT:
        out = ref.kwta_ref(x2, k)       # exact jnp fallback (huge rows)
    else:
        br = 8 if R >= 8 else R
        Rp = round_up(R, br)
        x_p = jnp.pad(x2, ((0, Rp - R), (0, 0)))
        out = kwta_pallas(x_p, k=k, iters=iters, br=br,
                          interpret=_interpret())[:R]
    return out[0] if squeeze else out
