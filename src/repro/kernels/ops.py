"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples, dtype handling,
interpret-mode dispatch (interpret=True on CPU — kernels execute in
Python for bit-exact validation; compiled on TPU), and jnp fallbacks
where a kernel's VMEM contract would be violated (documented per-op).
"""
from __future__ import annotations

import collections
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kwta import kwta_pallas
from repro.kernels.miru_scan import miru_scan_pallas
from repro.kernels.wbs_matmul import wbs_matmul_pallas
from repro.kernels.wbs_miru_scan import wbs_miru_scan_pallas
from repro.utils import round_up


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    return jnp.pad(x, ((0, m - x.shape[0]), (0, n - x.shape[1])))


# ---------------------------------------------------------------------------
# WBS matmul
# ---------------------------------------------------------------------------

def quantize_inputs(x: jax.Array, n_bits: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Sign-magnitude digitization of x ∈ [-1, 1] (the host-side buffer
    write that precedes WBS streaming). Alias of the canonical
    ``repro.analog.wbs.quantize_signed``."""
    from repro.analog.wbs import quantize_signed
    return quantize_signed(x, n_bits)


def pad_wbs_weights(w: jax.Array, block: int = 128) -> jax.Array:
    """Pre-pad a weight tile to the block multiples ``wbs_matmul`` would
    derive for it — the once-per-forward half of the pad work, hoistable
    out of a per-timestep scan (``DeviceBackend.prepare_weights``). The
    (K, N) padding depends only on the tile shape and block size, never
    on the drive, so one padded copy serves every call."""
    K, N = w.shape
    bk = min(block, round_up(K, 128))
    bn = min(block, round_up(N, 128))
    return _pad2(w, round_up(K, bk), round_up(N, bn))


def wbs_matmul(sign: jax.Array, code: jax.Array, w: jax.Array,
               gains: jax.Array, adc_bits: Optional[int] = None,
               adc_range: float = 4.0, block: int = 128,
               read_sigma: float = 0.0,
               read_key: Optional[jax.Array] = None,
               w_prepared: Optional[jax.Array] = None) -> jax.Array:
    """Padded/dispatched WBS crossbar matmul. See wbs_matmul_pallas.

    ``read_sigma``/``read_key`` model per-access conductance read noise.
    On compiled targets the noise is drawn inside the kernel (a fresh
    draw per weight-tile access); in interpret mode (CPU) the TPU PRNG
    has no lowering, so the jnp reference model — one draw per weight
    element per call — is applied to ``w`` up front.

    ``w_prepared`` is a :func:`pad_wbs_weights` copy of ``w`` (same
    block size); it skips the per-call pad except where the per-call
    noise model rewrote ``w``.
    """
    M, K = sign.shape
    _, N = w.shape
    seed = None
    if read_sigma > 0:
        if read_key is None:
            raise ValueError("read_sigma > 0 requires read_key")
        if _interpret():
            w = w * (1.0 + read_sigma
                     * jax.random.normal(read_key, w.shape))
            read_sigma = 0.0
            w_prepared = None    # per-call perturbation: must re-pad
        else:
            seed = jax.random.randint(read_key, (1,), 0, 2 ** 31 - 1,
                                      dtype=jnp.int32)
    bm = min(block, round_up(M, 8))
    bk = min(block, round_up(K, 128))
    bn = min(block, round_up(N, 128))
    Mp, Kp, Np = round_up(M, bm), round_up(K, bk), round_up(N, bn)
    sign_p = _pad2(sign, Mp, Kp)     # sign=0 ⇒ padded inputs contribute 0
    code_p = _pad2(code, Mp, Kp)
    if w_prepared is not None and w_prepared.shape == (Kp, Np):
        w_p = w_prepared
    else:
        w_p = _pad2(w, Kp, Np)
    y = wbs_matmul_pallas(sign_p, code_p, w_p, gains, adc_bits=adc_bits,
                          adc_range=adc_range, bm=bm, bk=bk, bn=bn,
                          read_sigma=read_sigma, seed=seed,
                          interpret=_interpret())
    return y[:M, :N]


def wbs_dense(x: jax.Array, w: jax.Array, n_bits: int = 8,
              adc_bits: Optional[int] = 8, adc_range: float = 4.0,
              gains: Optional[jax.Array] = None,
              read_sigma: float = 0.0,
              read_key: Optional[jax.Array] = None,
              w_prepared: Optional[jax.Array] = None) -> jax.Array:
    """QuantMode.WBS linear layer: float activations → sign-magnitude
    codes → bit-plane crossbar matmul. x (..., K) @ w (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if gains is None:
        gains = 2.0 ** (-jnp.arange(1, n_bits + 1, dtype=jnp.float32))
    sign, code = quantize_inputs(x2, n_bits)
    y = wbs_matmul(sign, code, w, gains, adc_bits, adc_range,
                   read_sigma=read_sigma, read_key=read_key,
                   w_prepared=w_prepared)
    return y.reshape(*lead, w.shape[-1])


def device_vmm(x: jax.Array, w: jax.Array, backend="wbs",
               key: Optional[jax.Array] = None, **backend_kwargs
               ) -> jax.Array:
    """Registry-dispatched VMM: route x @ w through a registered device
    backend ("ideal" | "wbs" | "analog" | any custom registration).
    ``backend`` is a name or a DeviceBackend instance; extra kwargs
    (``spec``, ``spec_overrides``, …) pass through to ``get_backend``.
    Activity lands on the backend's telemetry when enabled."""
    from repro.backends import get_backend
    return get_backend(backend, **backend_kwargs).device_vmm(x, w, key)


# ---------------------------------------------------------------------------
# MiRU fused recurrence
# ---------------------------------------------------------------------------

def miru_scan(xw: jax.Array, u_h: jax.Array, h0: jax.Array, beta: float,
              lam: float) -> tuple[jax.Array, jax.Array]:
    """Fused MiRU recurrence. xw (B,T,H), u_h (H,H), h0 (B,H)."""
    B, T, H = xw.shape
    bm = 8 if B >= 8 else B
    Bp = round_up(B, bm)
    Hp = round_up(H, 128)
    if Bp != B or Hp != H:
        xw_p = jnp.pad(xw, ((0, Bp - B), (0, 0), (0, Hp - H)))
        u_p = jnp.pad(u_h, ((0, Hp - H), (0, Hp - H)))
        h0_p = jnp.pad(h0, ((0, Bp - B), (0, Hp - H)))
    else:
        xw_p, u_p, h0_p = xw, u_h, h0
    h_all, pre = miru_scan_pallas(xw_p, u_p, h0_p, beta=beta, lam=lam,
                                  bm=bm, interpret=_interpret())
    return h_all[:B, :, :H], pre[:B, :, :H]


# ---------------------------------------------------------------------------
# Device-true fused recurrence (WBS × MiRU)
# ---------------------------------------------------------------------------

# VMEM guard for the fused kernel: the (Hp, Hp) recurrent tile must stay
# resident for all T steps next to the state/drive buffers; past 1024
# (4 MB f32) the budget is gone and ops falls back to the jnp reference.
_FUSED_H_LIMIT = 1024

_FusedStatic = collections.namedtuple(
    "_FusedStatic",
    "beta lam n_bits adc_bits adc_range weight_scale use_kernel")


def wbs_input_drive(x_seq: jax.Array, w_h: jax.Array, n_bits: int,
                    weight_scale: float = 1.0,
                    gains: Optional[jax.Array] = None,
                    use_kernel: Optional[bool] = None) -> jax.Array:
    """The hoisted WBS input projection: the x@W_h half of the MiRU
    recurrence has no sequential dependency, so the whole (B, T, K)
    sequence is sign-magnitude quantized and driven through the crossbar
    as ONE batched (B·T, K) matmul instead of T per-step calls.

    ``gains`` is (T, n_bits) per-step plane gains (the per-step path
    draws a fresh gain vector per timestep under ``gain_sigma > 0``) or
    None for ideal ratios. Returns the quantized drive (B, T, H) f32,
    bit-identical per row to the per-step ``wbs_vmm``/``wbs_matmul``
    evaluation. No bias, no ADC — both are applied inside the scan.
    """
    B, T, K = x_seq.shape
    use_kernel = use_kernel if use_kernel is not None else not _interpret()
    w = (w_h / weight_scale).astype(jnp.float32)
    norm = 2.0 ** n_bits / (2.0 ** n_bits - 1.0)
    x2 = x_seq.reshape(B * T, K)
    if gains is None and use_kernel:
        sign, code = quantize_inputs(x2, n_bits)
        g = 2.0 ** (-jnp.arange(1, n_bits + 1, dtype=jnp.float32))
        y = wbs_matmul(sign, code, w, g)        # epilogue applies ``norm``
    elif gains is None:
        # Ideal ratios: Σ_k 2^{-k}·plane_k is exactly code·2^{-n_b}
        # (dyadic), the same collapse XLA applies to the per-step einsum.
        top = float(2 ** n_bits - 1)
        deq = jnp.clip(jnp.round(x2 * top), -top, top) * (2.0 ** -n_bits)
        y = jnp.dot(deq, w, preferred_element_type=jnp.float32) * norm
    else:
        # Per-step plane gains: accumulate the gain-weighted bit planes
        # one plane at a time — MSB first, the same reduction order as
        # the per-step einsum collapse — without materializing the full
        # (n_bits, B, T, K) plane stack. Sign distributes exactly over
        # the dyadic plane sum, so it is applied once at the end.
        sign, code = quantize_inputs(x2.reshape(B, T, K), n_bits)
        codes = code.astype(jnp.int32)
        g = gains.astype(jnp.float32)
        deq = jnp.zeros((B, T, K), jnp.float32)
        for b in range(n_bits):
            shift = n_bits - 1 - b
            plane = ((codes >> shift) & 1).astype(jnp.float32)
            deq = deq + g[None, :, b, None] * plane
        deq = deq * sign.astype(jnp.float32)
        y = jnp.dot(deq.reshape(B * T, K), w,
                    preferred_element_type=jnp.float32) * norm
    return (y * weight_scale).reshape(B, T, w.shape[-1])


def _wbs_miru_scan_primal(static: _FusedStatic, drive, u_h, h0, b_h,
                          gains):
    B, T, H = drive.shape
    use_kernel = static.use_kernel if static.use_kernel is not None \
        else not _interpret()
    u_scaled = (u_h / static.weight_scale).astype(jnp.float32)
    if use_kernel and round_up(H, 128) <= _FUSED_H_LIMIT:
        bm = 8 if B >= 8 else B
        Bp, Hp = round_up(B, bm), round_up(H, 128)
        drive_p = jnp.pad(drive, ((0, Bp - B), (0, 0), (0, Hp - H)))
        u_p = jnp.pad(u_scaled, ((0, Hp - H), (0, Hp - H)))
        h0_p = jnp.pad(h0, ((0, Bp - B), (0, Hp - H)))
        b_p = jnp.pad(b_h.reshape(1, H), ((0, 0), (0, Hp - H)))
        if gains is None:
            g = 2.0 ** (-jnp.arange(1, static.n_bits + 1,
                                    dtype=jnp.float32))
            gains_p = jnp.tile(g[None, :], (T, 1))
        else:
            gains_p = gains.astype(jnp.float32)
        h_all, h_prev, pre = wbs_miru_scan_pallas(
            drive_p, u_p, h0_p, b_p, gains_p, beta=static.beta,
            lam=static.lam, n_bits=static.n_bits,
            adc_bits=static.adc_bits, adc_range=static.adc_range,
            w_scale=static.weight_scale, bm=bm, interpret=_interpret())
        return (h_all[:B, :, :H], h_prev[:B, :, :H], pre[:B, :, :H])
    return ref.wbs_miru_scan_ref(
        drive, u_scaled, h0, b_h.reshape(1, H), beta=static.beta,
        lam=static.lam, n_bits=static.n_bits, adc_bits=static.adc_bits,
        adc_range=static.adc_range, w_scale=static.weight_scale,
        gains=gains)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _wbs_miru_scan_core(static: _FusedStatic, drive, u_h, h0, b_h, gains):
    return _wbs_miru_scan_primal(static, drive, u_h, h0, b_h, gains)


def _wbs_miru_scan_fwd(static, drive, u_h, h0, b_h, gains):
    out = _wbs_miru_scan_primal(static, drive, u_h, h0, b_h, gains)
    h_all, h_prev, pre = out
    return out, (u_h, h_prev, pre, gains)


def _wbs_miru_scan_bwd(static, res, cts):
    """Straight-through backward — the transpose of the per-step path's
    STE composition: the quantized matmul backpropagates as the linear
    product with the *raw* logical weights, the ADC as identity, and the
    λ-interpolation/tanh exactly."""
    u_h, h_prev, pre, gains = res
    ct_hall, ct_hprev, ct_pre = cts
    beta, lam = static.beta, static.lam
    u = u_h.astype(jnp.float32)
    dtanh = 1.0 - jnp.tanh(pre) ** 2

    def back(carry, inp):
        gh, du = carry
        ct_a, ct_hp, ct_p, dt_t, hp_t = inp
        g_tot = ct_a + gh
        g_pre = ct_p + (1.0 - lam) * dt_t * g_tot
        du = du + (beta * hp_t).T @ g_pre
        gh_prev = ct_hp + lam * g_tot + beta * (g_pre @ u.T)
        return (gh_prev, du), g_pre

    swap = lambda a: jnp.swapaxes(a, 0, 1)
    carry0 = (jnp.zeros_like(h_prev[:, 0, :]), jnp.zeros_like(u))
    (gh, du), g_pre_all = jax.lax.scan(
        back, carry0,
        (swap(ct_hall), swap(ct_hprev), swap(ct_pre), swap(dtanh),
         swap(h_prev)),
        reverse=True)
    d_drive = swap(g_pre_all)
    d_b = jnp.sum(g_pre_all, axis=(0, 1))
    d_gains = None if gains is None else jnp.zeros_like(gains)
    return d_drive, du.astype(u_h.dtype), gh, d_b, d_gains


_wbs_miru_scan_core.defvjp(_wbs_miru_scan_fwd, _wbs_miru_scan_bwd)


def wbs_miru_scan(drive: jax.Array, u_h: jax.Array, b_h: jax.Array,
                  h0: Optional[jax.Array] = None, *, beta: float,
                  lam: float, n_bits: int, adc_bits: Optional[int] = None,
                  adc_range: float = 4.0, weight_scale: float = 1.0,
                  gains: Optional[jax.Array] = None,
                  use_kernel: Optional[bool] = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused device-true MiRU recurrence over a precomputed input drive.

    drive (B, T, H) from :func:`wbs_input_drive`; u_h (H, H) *raw*
    logical recurrent weights (the wrapper divides by ``weight_scale``
    once, outside the scan — the per-step path re-derived it every
    timestep); b_h (H,); gains (T, n_bits) per-step plane gains or None.

    Dispatch: the single Pallas kernel (``wbs_miru_scan_pallas``) on
    compiled targets with H ≤ ``_FUSED_H_LIMIT``; the vectorized jnp
    reference (``ref.wbs_miru_scan_ref``) in interpret-mode environments
    (CPU) and above the VMEM limit. Differentiable via straight-through
    estimation (exact quantized forward, linear backward on the raw
    weights).

    Returns (h_all, h_prev, pre), each (B, T, H) f32.
    """
    B, T, H = drive.shape
    if h0 is None:
        h0 = jnp.zeros((B, H), jnp.float32)
    static = _FusedStatic(beta=float(beta), lam=float(lam), n_bits=n_bits,
                          adc_bits=adc_bits, adc_range=float(adc_range),
                          weight_scale=float(weight_scale),
                          use_kernel=use_kernel)
    return _wbs_miru_scan_core(static, drive, u_h, h0, b_h, gains)


# ---------------------------------------------------------------------------
# Flash attention (forward)
# ---------------------------------------------------------------------------

def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, bq: int = 128, bk: int = 128
                        ) -> tuple[jax.Array, jax.Array]:
    """(B, Sq, H, dh) layout wrapper around the Pallas flash forward.

    Pads Sq/Sk to block multiples. GQA KV heads are *not* repeated: the
    kv→q head mapping rides the kernel's BlockSpec index maps, so the
    un-repeated (B·Kh, Sk, ·) arrays go to the kernel as-is instead of a
    rep×-materialized copy round-tripping HBM first. Returns
    (out (B,Sq,H,dv), lse (B,H,Sq))."""
    from repro.kernels.flash_attention import flash_attention_fwd_pallas
    B, Sq, H, dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    bq = min(bq, round_up(Sq, 8))
    bk = min(bk, round_up(Sk, 8))
    Sqp, Skp = round_up(Sq, bq), round_up(Sk, bk)
    qt = jnp.swapaxes(q, 1, 2).reshape(B * H, Sq, dh)
    kt = jnp.swapaxes(k, 1, 2).reshape(B * Kh, Sk, dh)
    vt = jnp.swapaxes(v, 1, 2).reshape(B * Kh, Sk, dv)
    qt = jnp.pad(qt, ((0, 0), (0, Sqp - Sq), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, Skp - Sk), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, Skp - Sk), (0, 0)))
    out, lse = flash_attention_fwd_pallas(
        qt, kt, vt, causal=causal, bq=bq, bk=bk, sk_true=Sk,
        q_heads=H, kv_heads=Kh, interpret=_interpret())
    out = out[:, :Sq].reshape(B, H, Sq, dv)
    return jnp.swapaxes(out, 1, 2), lse[:, :Sq].reshape(B, H, Sq)


# ---------------------------------------------------------------------------
# k-WTA
# ---------------------------------------------------------------------------

_KWTA_VMEM_LIMIT = 1 << 20  # rows longer than this fall back to jnp top_k


def kwta(x: jax.Array, k: int, iters: int = 32) -> jax.Array:
    """Per-row k-WTA by magnitude. 1-D input treated as a single row."""
    squeeze = x.ndim == 1
    x2 = x[None, :] if squeeze else x
    R, N = x2.shape
    if k >= N:
        return x
    if N > _KWTA_VMEM_LIMIT:
        out = ref.kwta_ref(x2, k)       # exact jnp fallback (huge rows)
    else:
        br = 8 if R >= 8 else R
        Rp = round_up(R, br)
        x_p = jnp.pad(x2, ((0, Rp - R), (0, 0)))
        out = kwta_pallas(x_p, k=k, iters=iters, br=br,
                          interpret=_interpret())[:R]
    return out[0] if squeeze else out
