"""Pallas TPU kernel: k-winner-take-all via threshold bisection.

The voltage-mode k-WTA circuit (Fig. 3-Right) settles an analog threshold
until exactly k outputs remain high. Its digital twin: bisect the monotone
function count(|x| > θ) toward k — branch-free, O(iters · n) VPU work per
row, no sort. After ``iters`` rounds [lo, hi] brackets the k-th magnitude:
count(>lo) ≥ k ≥ count(>hi); the epilogue picks whichever bound yields
exactly k when possible (always, for distinct well-separated magnitudes).

Used for gradient sparsification ζ where approximate-k is acceptable by
construction (the paper's sparsification ratio is itself a tuning knob).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kwta_kernel(x_ref, out_ref, *, k: int, iters: int):
    x = x_ref[...].astype(jnp.float32)
    mag = jnp.abs(x)
    rows = x.shape[0]
    lo = jnp.zeros((rows, 1), jnp.float32)
    hi = jnp.max(mag, axis=-1, keepdims=True) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag > mid).astype(jnp.int32), axis=-1, keepdims=True)
        gt = cnt > k
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # Prefer the tight bound when it already admits exactly k winners.
    cnt_hi = jnp.sum((mag > hi).astype(jnp.int32), axis=-1, keepdims=True)
    theta = jnp.where(cnt_hi >= k, hi, lo)
    out_ref[...] = jnp.where(mag > theta, x, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "iters", "br",
                                             "interpret"))
def kwta_pallas(x: jax.Array, k: int, iters: int = 32, br: int = 8,
                interpret: bool = False) -> jax.Array:
    """x (R, N) → k-WTA per row. R must divide by br (ops.py pads)."""
    R, N = x.shape
    assert R % br == 0, (R, br)
    kernel = functools.partial(_kwta_kernel, k=k, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, N), x.dtype),
        interpret=interpret,
    )(x)
