"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's *exact* integer/bit semantics so the
sweep tests can assert allclose at fp32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wbs_matmul_ref(sign: jax.Array, code: jax.Array, w: jax.Array,
                   gains: jax.Array, adc_bits: int | None = None,
                   adc_range: float = 4.0) -> jax.Array:
    """Weighted-bit-streaming VMM oracle.

    sign (M, K) int8 ∈ {-1, 0, +1}; code (M, K) uint8 magnitudes;
    w (K, N); gains (n_bits,) MSB-first plane gains (ideal: 2^{-1}..2^{-nb}).

    y = Σ_k gains[k] · (plane_k ⊙ sign) @ w, rescaled by 2^nb/(2^nb − 1)
    so ideal gains reproduce the sign-magnitude fixed-point product, then
    optionally ADC-quantized.
    """
    n_bits = gains.shape[0]
    ks = jnp.arange(n_bits - 1, -1, -1, dtype=code.dtype)       # MSB first
    planes = (code[None, :, :] >> ks[:, None, None]) & 1        # (nb, M, K)
    signed = planes.astype(jnp.float32) * sign.astype(jnp.float32)[None]
    y = jnp.einsum("b,bmk,kn->mn", gains.astype(jnp.float32), signed,
                   w.astype(jnp.float32))
    y = y * (2.0 ** n_bits / (2.0 ** n_bits - 1.0))
    if adc_bits is not None:
        levels = 2 ** adc_bits
        step = 2.0 * adc_range / levels
        q = jnp.clip(jnp.round(y / step), -(levels // 2), levels // 2 - 1)
        y = q * step
    return y


def miru_scan_ref(xw: jax.Array, u_h: jax.Array, h0: jax.Array,
                  beta: float, lam: float
                  ) -> tuple[jax.Array, jax.Array]:
    """MiRU recurrence oracle.

    xw (B, T, H) = x@W_h + b_h precomputed; u_h (H, H); h0 (B, H).
    Returns (h_all (B,T,H), pre (B,T,H)).
    """
    def step(h, xw_t):
        pre = xw_t + (beta * h) @ u_h.astype(jnp.float32)
        h_new = lam * h + (1.0 - lam) * jnp.tanh(pre)
        return h_new, (h_new, pre)

    _, (h_all, pre) = jax.lax.scan(step, h0.astype(jnp.float32),
                                   jnp.swapaxes(xw, 0, 1).astype(jnp.float32))
    return jnp.swapaxes(h_all, 0, 1), jnp.swapaxes(pre, 0, 1)


def wbs_miru_scan_ref(drive: jax.Array, u_h: jax.Array, h0: jax.Array,
                      b_h: jax.Array, beta: float, lam: float,
                      n_bits: int, adc_bits: int | None = None,
                      adc_range: float = 4.0, w_scale: float = 1.0,
                      gains: jax.Array | None = None,
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Device-true fused MiRU recurrence oracle — the jnp path the CPU
    backends execute, bit-identical to the per-timestep ``device_vmm``
    scan (``analog/wbs.wbs_vmm`` semantics).

    drive (B, T, H) = the hoisted WBS input projection (no bias);
    u_h (H, H) recurrent weights *already divided* by the logical weight
    scale; ``w_scale`` re-applies the scale after the normalized read.
    ``gains`` is (T, n_bits) per-step plane gains, or None for ideal
    ratios — with ideal ratios Σ_k 2^{-k}·plane_k is the exact dyadic
    value code·2^{-n_b}, so the per-plane contraction collapses to a
    single matmul with no fp difference (XLA performs the same collapse
    on the per-step einsum; asserted in tests/test_fused_recurrence.py).

    Returns (h_all, h_prev, pre), each (B, T, H) f32.
    """
    top = float(2 ** n_bits - 1)
    norm = 2.0 ** n_bits / (2.0 ** n_bits - 1.0)
    u = u_h.astype(jnp.float32)
    shifts = jnp.arange(n_bits - 1, -1, -1, dtype=jnp.int32)  # MSB first

    def step(h, inp):
        d_t, g_t = inp
        bh = beta * h
        if g_t is None:
            # Ideal plane gains: the gain-weighted plane sum is exactly
            # the signed code scaled by 2^-n_b (dyadic, order-free).
            deq = jnp.clip(jnp.round(bh * top), -top, top) * (2.0 ** -n_bits)
        else:
            mag = jnp.clip(jnp.round(jnp.abs(bh) * top), 0.0, top)
            sign = jnp.sign(bh)
            planes = ((mag.astype(jnp.int32)[None]
                       >> shifts[:, None, None]) & 1).astype(jnp.float32)
            deq = jnp.einsum("k,kbi->bi", g_t, planes * sign[None])
        y = jnp.dot(deq, u, preferred_element_type=jnp.float32)
        y = y * norm * w_scale
        pre = (d_t + y) + b_h[0]
        if adc_bits is not None:
            from repro.analog.adc import adc_quantize
            pre = adc_quantize(pre, adc_bits, adc_range)
        h_new = lam * h + (1.0 - lam) * jnp.tanh(pre)
        return h_new, (h_new, h, pre)

    drive_t = jnp.swapaxes(drive, 0, 1).astype(jnp.float32)
    if gains is None:
        _, outs = jax.lax.scan(lambda h, d: step(h, (d, None)),
                               h0.astype(jnp.float32), drive_t)
    else:
        _, outs = jax.lax.scan(step, h0.astype(jnp.float32),
                               (drive_t, gains.astype(jnp.float32)))
    h_all, h_prev, pre = (jnp.swapaxes(o, 0, 1) for o in outs)
    return h_all, h_prev, pre


def kwta_ref(x: jax.Array, k: int) -> jax.Array:
    """Exact per-row k-WTA by magnitude (rows = leading dim)."""
    if k >= x.shape[-1]:
        return x
    mag = jnp.abs(x)
    kth = jax.lax.top_k(mag, k)[0][..., -1:]
    return jnp.where(mag >= kth, x, jnp.zeros_like(x))
