"""Pallas TPU kernel: flash attention forward (beyond-paper optimization).

The dry-run roofline shows every train/prefill cell's memory term is
dominated by materialized f32 score chunks — XLA cannot fuse through the
two dots of attention, so (B,H,Sq,chunk) buffers round-trip HBM ~5× per
layer. This kernel runs the whole online-softmax chain in VMEM: scores,
probabilities, and the running (m, l, acc) never leave the chip.
(EXPERIMENTS.md §Perf iteration 4 quantifies the removed traffic.)

Grid (BH, Sq/bq, Sk/bk), K innermost; (m, l, acc) carried in VMEM scratch
across the K sweep; epilogue normalizes and writes out + logsumexp
(the residual needed by the flash backward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, out_ref, lse_ref,
                      m_scr, l_scr, acc_scr, *,
                      causal: bool, bq: int, bk: int, n_k: int,
                      sk_true: int, scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                   # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                   # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                   # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = ki < sk_true
    if causal:
        qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        valid = valid & (ki <= qi)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # stays in VMEM
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_k - 1)
    def _epilogue():
        l = jnp.maximum(l_scr[...], 1e-30)
        out_ref[0] = (acc_scr[...] / l).astype(out_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l))[:, 0]


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------
# dq kernel: grid (BH, Sq/bq, Sk/bk), K innermost — dq block accumulates in
# VMEM scratch while streaming K/V chunks.
# dkv kernel: grid (BH, Sk/bk, Sq/bq), Q innermost — dk/dv blocks accumulate
# while streaming Q/dO chunks. Probabilities are recomputed from (q,k,lse);
# nothing score-shaped ever reaches HBM (the flash recipe).

def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dq_ref, dq_scr, *, causal, bq, bk, n_k, sk_true,
                     scale):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    qb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = ki < sk_true
    if causal:
        qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        valid = valid & (ki <= qi)
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    dq_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(kb == n_k - 1)
    def _write():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dk_ref, dv_ref, dk_scr, dv_scr, *, causal, bq, bk,
                      n_q, sk_true, scale):
    qb = pl.program_id(2)

    @pl.when(qb == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    kb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = ki < sk_true
    if causal:
        qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        valid = valid & (ki <= qi)
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - lse)                                 # (bq, bk)
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (bk, dv)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bk, dh)

    @pl.when(qb == n_q - 1)
    def _write():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "bq", "bk", "sk_true", "interpret"))
def flash_attention_bwd_pallas(q, k, v, out, lse, dout,
                               causal: bool = True, bq: int = 128,
                               bk: int = 128, sk_true: int | None = None,
                               interpret: bool = False):
    """Backward: q (BH,Sq,dh); k/v (BH,Sk,·); out/dout (BH,Sq,dv);
    lse (BH,Sq). Returns (dq, dk, dv)."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    dv_dim = v.shape[2]
    assert Sq % bq == 0 and Sk % bk == 0
    if sk_true is None:
        sk_true = Sk
    scale = dh ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                             # (BH, Sq)

    common_in = [
        pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),   # q
        pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),   # k
        pl.BlockSpec((1, bk, dv_dim), lambda b, i, j: (b, j, 0)),  # v
        pl.BlockSpec((1, bq, dv_dim), lambda b, i, j: (b, i, 0)),  # dout
        pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),          # lse
        pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),          # delta
    ]
    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, causal=causal, bq=bq, bk=bk,
                          n_k=Sk // bk, sk_true=sk_true, scale=scale),
        grid=(BH, Sq // bq, Sk // bk),
        in_specs=common_in,
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # dkv grid transposes the block roles: i ↔ KV block, j ↔ Q block.
    dkv_in = [
        pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, j, 0)),   # q
        pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, i, 0)),   # k
        pl.BlockSpec((1, bk, dv_dim), lambda b, i, j: (b, i, 0)),  # v
        pl.BlockSpec((1, bq, dv_dim), lambda b, i, j: (b, j, 0)),  # dout
        pl.BlockSpec((1, bq), lambda b, i, j: (b, j)),          # lse
        pl.BlockSpec((1, bq), lambda b, i, j: (b, j)),          # delta
    ]
    dk, dv = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, causal=causal, bq=bq, bk=bk,
                          n_q=Sq // bq, sk_true=sk_true, scale=scale),
        grid=(BH, Sk // bk, Sq // bq),
        in_specs=dkv_in,
        out_specs=[
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dv_dim), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sk, dh), k.dtype),
            jax.ShapeDtypeStruct((BH, Sk, dv_dim), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, dh), jnp.float32),
                        pltpu.VMEM((bk, dv_dim), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv


@functools.partial(jax.jit, static_argnames=(
    "causal", "bq", "bk", "sk_true", "q_heads", "kv_heads", "interpret"))
def flash_attention_fwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                               causal: bool = True, bq: int = 128,
                               bk: int = 128, sk_true: int | None = None,
                               q_heads: int | None = None,
                               kv_heads: int | None = None,
                               interpret: bool = False
                               ) -> tuple[jax.Array, jax.Array]:
    """q (B·H, Sq, dh); k/v (B·Kh, Sk, dh|dv), Sq % bq == Sk % bk == 0.

    GQA: with ``q_heads``/``kv_heads`` set, K/V carry only Kh heads and
    the kv→q head mapping is folded into the BlockSpec index maps — each
    query head's grid cells fetch their shared KV block directly from the
    un-repeated (B·Kh, …) arrays, instead of the caller materializing a
    rep×-repeated copy in HBM. Unset, K/V batch must equal q's.

    Returns (out (B·H, Sq, dv), lse (B·H, Sq)).
    """
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[2]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    if sk_true is None:
        sk_true = Sk
    if q_heads is not None and kv_heads is not None and \
            q_heads != kv_heads:
        assert q_heads % kv_heads == 0, (q_heads, kv_heads)
        assert BH % q_heads == 0, (BH, q_heads)
        assert k.shape[0] == BH // q_heads * kv_heads, (k.shape, BH)
        rep = q_heads // kv_heads

        def kv_batch(b):
            return (b // q_heads) * kv_heads + (b % q_heads) // rep
    else:
        assert k.shape[0] == BH, (k.shape, BH)

        def kv_batch(b):
            return b
    n_k = Sk // bk
    scale = dh ** -0.5
    grid = (BH, Sq // bq, n_k)
    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, bq=bq, bk=bk, n_k=n_k,
        sk_true=sk_true, scale=scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda b, i, j: (kv_batch(b), j, 0)),
            pl.BlockSpec((1, bk, dv),
                         lambda b, i, j: (kv_batch(b), j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse
