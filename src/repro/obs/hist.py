"""Streaming histogram — the latency/energy distribution primitive.

The serve engine records per-request wall-clock and metered pJ/request
into these; the fleet/serve reports read out p50/p99. Values are stored
exactly up to ``max_samples`` and reservoir-sampled past that (bounded
memory under millions-of-requests load), with a deterministic Xorshift-
style counter-hash replacement so two runs of the same request stream
produce the same percentiles.
"""
from __future__ import annotations

import numpy as np

__all__ = ["Histogram"]


def _mix(n: int) -> int:
    # splitmix64 finalizer — deterministic per-sample hash for the
    # reservoir replacement draw (no global RNG state involved).
    z = (n + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


class Histogram:
    """Bounded-memory value recorder with exact percentiles while under
    ``max_samples`` and reservoir-sampled ones past it."""

    def __init__(self, max_samples: int = 65536):
        self.max_samples = int(max_samples)
        self._values: list[float] = []
        self.count = 0
        self._sum = 0.0

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self._sum += v
        if len(self._values) < self.max_samples:
            self._values.append(v)
            return
        j = _mix(self.count) % self.count
        if j < self.max_samples:
            self._values[j] = v

    def extend(self, values) -> None:
        for v in values:
            self.add(v)

    @property
    def mean(self) -> float:
        return self._sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        if not self._values:
            return float("nan")
        return float(np.percentile(np.asarray(self._values), q))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.p50, "p95": self.p95, "p99": self.p99,
                "min": min(self._values) if self._values else float("nan"),
                "max": max(self._values) if self._values else float("nan")}

    def __len__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return (f"<Histogram n={self.count} mean={self.mean:.4g} "
                f"p50={self.p50:.4g} p99={self.p99:.4g}>")
