"""Host-side span tracing with Chrome/Perfetto ``trace.json`` export.

A :class:`Tracer` records nested wall-clock spans (``with
tracer.span("compile"): ...``) and exports them in the Chrome trace-event
format, so one run's structure — schedule materialization vs trace/
compile vs execute, per-cell sweep work, per-batch serve steps — opens
directly in ``chrome://tracing`` / Perfetto. The runners separate
*compile* from *execute* by AOT-lowering the jitted program under the
``compile`` span (``fn.lower(...).compile()``) and calling the compiled
executable under ``execute`` — without a tracer they keep the ordinary
dispatch path, so tracing is strictly opt-in.

Spans passed a ``step=`` also enter
``jax.profiler.StepTraceAnnotation`` where the installed jax provides it,
so a device-side profiler trace captured around the same region gets the
step markers lined up with the host spans.

Everything is wall-clock host timing (``time.perf_counter_ns``), threads
separated by ``tid``; nesting inside a thread is expressed the Chrome
way — containment of ``[ts, ts+dur]`` intervals of ``ph: "X"`` complete
events.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Optional

__all__ = ["Tracer"]


class Tracer:
    """Collects trace events; thread-safe; negligible cost per span
    (two clock reads and a dict append)."""

    def __init__(self, process_name: str = "repro"):
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter_ns()
        self._depth = threading.local()
        self._events.append({
            "name": "process_name", "ph": "M", "pid": os.getpid(),
            "tid": 0, "args": {"name": process_name}})

    # ------------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    @contextlib.contextmanager
    def span(self, name: str, step: Optional[int] = None, **args):
        """Record a nested wall-clock span. ``step`` additionally opens a
        ``jax.profiler.StepTraceAnnotation`` (ignored where jax lacks
        it); remaining kwargs land in the event's ``args``."""
        depth = getattr(self._depth, "n", 0)
        self._depth.n = depth + 1
        t0 = self._now_us()
        ann = contextlib.nullcontext()
        if step is not None:
            try:
                import jax
                ann = jax.profiler.StepTraceAnnotation(name, step_num=step)
            except Exception:
                pass
        try:
            with ann:
                yield self
        finally:
            dur = self._now_us() - t0
            self._depth.n = depth
            ev_args = dict(args)
            if step is not None:
                ev_args["step"] = step
            with self._lock:
                self._events.append({
                    "name": name, "ph": "X", "ts": t0, "dur": dur,
                    "pid": os.getpid(), "tid": threading.get_ident(),
                    "args": ev_args, "_depth": depth})

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker (Chrome ``ph: "i"``)."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "i", "ts": self._now_us(), "s": "t",
                "pid": os.getpid(), "tid": threading.get_ident(),
                "args": dict(args)})

    def counter(self, name: str, **values) -> None:
        """A counter sample (Chrome ``ph: "C"``) — e.g. queue depth or
        active serve slots over time."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "C", "ts": self._now_us(),
                "pid": os.getpid(), "tid": 0,
                "args": {k: float(v) for k, v in values.items()}})

    # ------------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._events]

    def summary(self) -> dict[str, dict]:
        """Per-name totals over *top-level occurrences* of each span name
        (re-entrant spans only count their outermost instance, so a
        recursive span's total is wall time, not a multiple of it)."""
        out: dict[str, dict] = {}
        spans = [e for e in self.events() if e["ph"] == "X"]
        spans.sort(key=lambda e: e["ts"])
        open_until: dict[str, float] = {}
        for e in spans:
            name = e["name"]
            agg = out.setdefault(name, {"count": 0, "total_s": 0.0})
            if e["ts"] < open_until.get(name, -1.0):
                continue  # nested inside an outer span of the same name
            open_until[name] = e["ts"] + e["dur"]
            agg["count"] += 1
            agg["total_s"] += e["dur"] / 1e6
        return out

    def total_s(self, name: str) -> float:
        return self.summary().get(name, {}).get("total_s", 0.0)

    def export_chrome(self, path) -> Path:
        """Write the Chrome trace-event JSON. Open in chrome://tracing or
        https://ui.perfetto.dev."""
        path = Path(path)
        events = []
        for e in self.events():
            e.pop("_depth", None)
            events.append(e)
        path.write_text(json.dumps(
            {"traceEvents": events, "displayTimeUnit": "ms"}, indent=None))
        return path
