"""``repro.obs`` — time-resolved observability.

Three layers on top of the PR-2 aggregate telemetry:

  * **Metric streams** (:mod:`repro.obs.runlog`): per-step scalars
    (loss, write pulses, ΔG magnitude, replay occupancy, drift ticks)
    threaded through the runners' ``lax.scan`` bodies and windowed into
    a :class:`RunLog` at a configurable cadence. Disabled (the default)
    is bitwise-free; enabled is bitwise-inert on results.
  * **Span tracing** (:mod:`repro.obs.tracer`): host-side nested spans
    separating schedule / compile / execute, exported as Chrome/Perfetto
    ``trace.json``.
  * **Sinks** (:mod:`repro.obs.sinks`, :mod:`repro.obs.hist`):
    schema-versioned JSONL run records, the perf-trajectory history
    under ``benchmarks/results/history/``, and the streaming
    :class:`Histogram` behind the serve engine's p50/p99.

See ``docs/observability.md``.
"""
from repro.obs.hist import Histogram
from repro.obs.runlog import (ObsSpec, RunLog, build_runlog, drift_stream,
                              sparkline, step_stats, timeline)
from repro.obs.sinks import RUN_RECORD_SCHEMA, JsonlSink, run_record
from repro.obs.tracer import Tracer

__all__ = [
    "ObsSpec", "RunLog", "build_runlog", "drift_stream", "step_stats",
    "timeline", "sparkline",
    "Tracer", "Histogram",
    "JsonlSink", "run_record", "RUN_RECORD_SCHEMA",
]
