"""Event/run-record sinks — JSONL emission with a stable schema.

Every gated benchmark and instrumented example can emit a *run record*:
one JSON object per run with a pinned ``schema`` version, enough
environment fingerprint to compare runs across commits, and the run's
headline metrics. Appended to ``benchmarks/results/history/<name>.jsonl``
(see :func:`benchmarks.common.append_history`) these turn the
``BENCH_*.json`` point-in-time gates into a queryable perf trajectory —
``jq`` over the history answers "when did the fused speedup regress".

Schema (version 1) — stable keys, additive evolution only:

  schema      int, bumped only on breaking changes
  kind        "bench" | "run" | "serve" | "fleet"
  name        the record family (e.g. "obs_bench", "continual")
  ts          ISO-8601 UTC wall time of record creation
  git_sha     current commit (best effort; absent outside a checkout)
  jax         {"version", "backend"}
  metrics     flat dict of the run's headline numbers
  gates       pass/fail booleans (benches only)
  counters    telemetry counter snapshot (optional)
  timeline    thinned RunLog view (optional; see RunLog.as_dict)
"""
from __future__ import annotations

import json
import subprocess
from datetime import datetime, timezone
from pathlib import Path
from typing import Optional

__all__ = ["RUN_RECORD_SCHEMA", "JsonlSink", "run_record"]

RUN_RECORD_SCHEMA = 1


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=Path(__file__).resolve().parent)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def run_record(kind: str, name: str, metrics: dict, *,
               gates: Optional[dict] = None,
               counters: Optional[dict] = None,
               timeline: Optional[dict] = None,
               extra: Optional[dict] = None) -> dict:
    """Build a schema-versioned run record. ``metrics`` should be flat
    name → number; nested payloads go in ``extra``."""
    rec: dict = {
        "schema": RUN_RECORD_SCHEMA,
        "kind": kind,
        "name": name,
        "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "metrics": {k: (float(v) if isinstance(v, (int, float)) else v)
                    for k, v in metrics.items()},
    }
    sha = _git_sha()
    if sha:
        rec["git_sha"] = sha
    try:
        import jax
        rec["jax"] = {"version": jax.__version__,
                      "backend": jax.default_backend()}
    except Exception:
        pass
    if gates is not None:
        rec["gates"] = {k: bool(v) for k, v in gates.items()}
    if counters is not None:
        rec["counters"] = {k: int(v) for k, v in counters.items()}
    if timeline is not None:
        rec["timeline"] = timeline
    if extra:
        rec["extra"] = extra
    return rec


class JsonlSink:
    """Append-only JSONL file — one JSON object per line. Creation is
    lazy (parent directories made on first emit) so a sink can be
    constructed unconditionally and never touch disk unless used."""

    def __init__(self, path):
        self.path = Path(path)

    def emit(self, record: dict) -> Path:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            f.write(json.dumps(record, default=float) + "\n")
        return self.path

    def read(self) -> list[dict]:
        if not self.path.exists():
            return []
        return [json.loads(line) for line in
                self.path.read_text().splitlines() if line.strip()]
