"""Time-resolved metric streams — the :class:`RunLog` and its builder.

PR-2 telemetry answers *how much* (whole-run aggregate counters); this
module answers *when*: write pulses burst at task boundaries, forgetting
lands at specific transitions, and the ζ write maps behind the lifetime
projection are a time-integral worth resolving. The runners
(:func:`repro.core.continual.run_continual`,
:func:`repro.scenarios.sweep.run_compiled`,
:func:`repro.fleet.run_fleet`) thread per-step observability scalars
through their ``lax.scan`` bodies as scan outputs and assemble them into
a :class:`RunLog` at a configurable cadence.

The contract, in order of importance:

  disabled is free   With no :class:`ObsSpec` (the default) the runners
                     emit exactly the pre-obs trace: no extra scan
                     outputs, no extra host work — outputs are bitwise
                     identical to a build without this module.
  enabled is inert   The streams are pure *reads* of values the training
                     step already computes (the loss, the applied update,
                     the replay-buffer fill), so R / params / losses stay
                     bitwise equal with obs on; only wall time may move
                     (gated ≤ 5 % in ``benchmarks/obs_bench.py``).
  loop ≡ compiled    ``run_continual`` computes the identical per-step
                     scalars with the same jitted :func:`step_stats` and
                     feeds them through the same numpy windowing. The
                     integer streams (write pulses, occupancy, drift
                     ticks) are bit-identical between the Python loop
                     and the scan-over-tasks; the float streams (loss,
                     Σ|ΔG|) agree to the same few-ulp tolerance the
                     repo's loop/compiled ``losses`` parity already has
                     (XLA fuses the step differently inside the scan).
                     Both asserted in tests/test_obs.py.
  streams sum exact  Window *sums* (``write_pulses``, ``drift_ticks``)
                     total exactly to the aggregate telemetry counters of
                     the same run — the time series is a lossless
                     disaggregation, not a sampled estimate.

Cadence semantics: the run's ``total_steps`` training steps are split
into ``ceil(total/cadence)`` contiguous windows; window ``i`` covers
steps ``[i·c, min((i+1)·c, total))`` (the last window may be partial —
its sums still count every step, which is what keeps the totals exact).
Counter streams are summed over the window; gauge streams
(``loss`` excepted — it is the window *mean*) sample the window's first
step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["ObsSpec", "RunLog", "step_stats", "build_runlog",
           "drift_stream", "timeline", "sparkline"]


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """What to observe. Passed as ``obs=`` to the runners.

    metrics   record the in-scan metric streams into a :class:`RunLog`
              (result key ``"runlog"``).
    cadence   window length in training steps (1 = every step). Applied
              host-side after the scan, so changing it never retraces.
    tracer    a :class:`repro.obs.Tracer`; the runners open
              ``schedule`` / ``compile`` / ``execute`` spans on it
              (compile separated from execute via AOT lowering), and the
              sweep/fleet/serve layers add their own.
    """
    metrics: bool = True
    cadence: int = 1
    tracer: Optional[object] = None

    def __post_init__(self):
        if self.cadence < 1:
            raise ValueError(f"cadence must be ≥ 1, got {self.cadence}")


def step_stats(applied, rstate):
    """Per-step observability scalars from values the train step already
    produced: (write_pulses int32, dg_mag float32, occupancy int32).

    ``write_pulses`` counts the nonzero entries of the applied update
    across the ≥2-D parameter tiles — the same device set the aggregate
    ``write_pulses`` telemetry counter and the endurance write maps use,
    so the stream sums exactly to the counter. ``dg_mag`` is Σ|ΔG| over
    the same tiles (the applied-update magnitude, post noise/levels/
    clip). ``occupancy`` reads the in-graph replay buffer's fill
    (``rstate["size"]``); host-materialized policies report 0 here and
    the runner substitutes the schedule-derived stream instead.

    One definition is traced inside the compiled scan body and jitted
    standalone by the Python loop, so both paths reduce in the same
    order — the loop/compiled bitwise-parity contract.
    """
    mats = [v for _, v in sorted(applied.items()) if jnp.ndim(v) >= 2]
    if mats:
        pulses = sum(jnp.sum((m != 0).astype(jnp.int32)) for m in mats)
        dg = sum(jnp.sum(jnp.abs(m).astype(jnp.float32)) for m in mats)
    else:
        pulses = jnp.zeros((), jnp.int32)
        dg = jnp.zeros((), jnp.float32)
    occ = (rstate["size"].astype(jnp.int32)
           if isinstance(rstate, dict) and "size" in rstate
           else jnp.zeros((), jnp.int32))
    return pulses, dg, occ


# ---------------------------------------------------------------------------
# Windowing (host-side, numpy — shared verbatim by loop and compiled)
# ---------------------------------------------------------------------------

def _window_starts(n_steps: int, cadence: int) -> np.ndarray:
    return np.arange(0, n_steps, cadence)


def _window_sum(a: np.ndarray, cadence: int) -> np.ndarray:
    if a.shape[-1] == 0:
        return a[..., :0]
    return np.add.reduceat(a, _window_starts(a.shape[-1], cadence),
                           axis=-1)


def _window_mean(a: np.ndarray, cadence: int) -> np.ndarray:
    n = a.shape[-1]
    if n == 0:
        return a[..., :0]
    starts = _window_starts(n, cadence)
    counts = np.diff(np.append(starts, n))
    return np.add.reduceat(a, starts, axis=-1) / counts


def _window_first(a: np.ndarray, cadence: int) -> np.ndarray:
    return a[..., ::cadence]


@dataclasses.dataclass
class RunLog:
    """Time-resolved metric streams for one run (or one fleet).

    Stream arrays share a trailing ``(n_windows,)`` axis; fleet /
    multi-seed runs carry a leading per-chip (per-seed) axis — shapes
    below write it as ``(...,)``. Everything is numpy, host-side.

      cadence           window length in training steps
      n_steps           total training steps covered
      steps             (n_windows,) global step index of each window start
      loss              (..., n_windows) window-mean training loss
      write_pulses      (..., n_windows) window-sum nonzero programmed
                        synapses — sums exactly to the telemetry counter
      dg_mag            (..., n_windows) window-sum Σ|ΔG| applied
      replay_occupancy  (..., n_windows) replay-buffer fill, gauge at the
                        window's first step
      drift_ticks       (..., n_windows) window-sum retention-drift ticks
      eval_steps        (n_tasks,) global step after which task t's eval
                        row was taken (the task boundary)
      task_acc          (..., n_tasks, n_tasks) per-task eval accuracy
                        after each task — R_full from the compiled
                        runners, the lower-triangular R from the loop
    """
    cadence: int
    n_steps: int
    steps: np.ndarray
    loss: np.ndarray
    write_pulses: np.ndarray
    dg_mag: np.ndarray
    replay_occupancy: np.ndarray
    drift_ticks: np.ndarray
    eval_steps: np.ndarray
    task_acc: np.ndarray

    @property
    def n_windows(self) -> int:
        return int(self.steps.shape[0])

    @property
    def total_write_pulses(self) -> int:
        """Exact aggregate — equals the run's ``write_pulses`` telemetry
        counter total (asserted in tests/test_obs.py)."""
        return int(self.write_pulses.sum())

    @property
    def total_drift_ticks(self) -> int:
        return int(self.drift_ticks.sum())

    def forgetting_after_task(self) -> np.ndarray:
        """(..., n_tasks) mean forgetting after each task boundary:
        ``f[t] = mean_{i<t}(max_{k≤t} A[k,i] − A[t,i])`` (0 at t=0) —
        the *when* of forgetting, per transition, not just the final
        scalar."""
        A = np.asarray(self.task_acc, np.float64)
        n = A.shape[-1]
        out = np.zeros(A.shape[:-1])
        run_max = A[..., 0, :].copy()
        for t in range(1, n):
            run_max = np.maximum(run_max, A[..., t, :])
            out[..., t] = (run_max[..., :t] - A[..., t, :t]).mean(axis=-1)
        return out

    def as_dict(self, max_points: Optional[int] = None) -> dict:
        """JSON-serializable view (leading axes reduced: sums for
        counters, means for gauges). ``max_points`` thins the streams by
        striding for compact run records."""
        tl = timeline(self)
        if max_points is not None and len(tl["steps"]) > max_points:
            stride = -(-len(tl["steps"]) // max_points)
            for k in ("steps", "loss", "write_pulses", "dg_mag",
                      "replay_occupancy", "drift_ticks"):
                tl[k] = tl[k][::stride]
            tl["thinned_stride"] = stride
        return tl


def drift_stream(total_steps: int, *, drifting: bool) -> np.ndarray:
    """Per-step retention-drift ticks. The ``analog_state`` backend
    meters exactly one (cadence-amortized) tick per weight update when
    drift is active, so the per-step series is the unit ramp — included
    so the stream's sum stays an exact disaggregation of the
    ``drift_ticks`` counter (stateless substrates never tick)."""
    return (np.ones(total_steps, np.int32) if drifting
            else np.zeros(total_steps, np.int32))


def build_runlog(*, cadence: int, steps_per_task, loss, write_pulses,
                 dg_mag, replay_occupancy, drift_ticks,
                 task_acc) -> RunLog:
    """Assemble a :class:`RunLog` from per-step arrays shaped
    ``(..., total_steps)`` (leading axes ride through — the fleet's
    per-chip axis, the sweep's per-seed axis). One definition consumed
    by all three runners, which is what keeps the loop/compiled/fleet
    RunLogs directly comparable."""
    steps_per_task = [int(s) for s in steps_per_task]
    total = sum(steps_per_task)

    def _flat(a, dtype):
        a = np.asarray(a)
        if a.shape[-1] != total:
            a = a.reshape(*a.shape[:a.ndim - 2], -1)
        if a.shape[-1] != total:
            raise ValueError(f"per-step stream has {a.shape[-1]} steps, "
                             f"schedule has {total}")
        return np.asarray(a, dtype)

    loss_f = _flat(loss, np.float32)
    pulses_f = _flat(write_pulses, np.int64)
    dg_f = _flat(dg_mag, np.float32)
    occ_f = _flat(replay_occupancy, np.int32)
    drift_f = _flat(drift_ticks, np.int64)
    return RunLog(
        cadence=int(cadence),
        n_steps=total,
        steps=_window_starts(total, cadence),
        loss=_window_mean(loss_f, cadence),
        write_pulses=_window_sum(pulses_f, cadence),
        dg_mag=_window_sum(dg_f, cadence),
        replay_occupancy=_window_first(occ_f, cadence),
        drift_ticks=_window_sum(drift_f, cadence),
        eval_steps=np.cumsum(steps_per_task) - 1,
        task_acc=np.asarray(task_acc, np.float64),
    )


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 48) -> str:
    """Eight-level unicode sparkline, down-sampled to ``width`` by
    window-maxima (bursts — the interesting part — survive thinning)."""
    v = np.asarray(values, np.float64).reshape(-1)
    if v.size == 0:
        return ""
    if v.size > width:
        pad = (-v.size) % width
        v = np.pad(v, (0, pad), constant_values=v.min())
        v = v.reshape(width, -1).max(axis=1)
    lo, hi = float(v.min()), float(v.max())
    if hi <= lo:
        return _SPARK[0] * v.size
    idx = ((v - lo) / (hi - lo) * (len(_SPARK) - 1)).round().astype(int)
    return "".join(_SPARK[i] for i in idx)


def timeline(log: RunLog) -> dict:
    """The report-facing view of a RunLog: leading (chip/seed) axes
    reduced — counters summed across the population, gauges averaged —
    plus the per-task forgetting series. Rendered by
    :func:`repro.telemetry.format_report`."""
    def _lead_sum(a):
        return a.reshape(-1, a.shape[-1]).sum(axis=0) if a.ndim > 1 else a

    def _lead_mean(a):
        return a.reshape(-1, a.shape[-1]).mean(axis=0) if a.ndim > 1 else a

    fg = log.forgetting_after_task()
    fg = fg.reshape(-1, fg.shape[-1]).mean(axis=0) if fg.ndim > 1 else fg
    return {
        "cadence": log.cadence,
        "n_steps": log.n_steps,
        "steps": log.steps.tolist(),
        "loss": _lead_mean(log.loss).tolist(),
        "write_pulses": _lead_sum(log.write_pulses).tolist(),
        "dg_mag": _lead_sum(log.dg_mag).tolist(),
        "replay_occupancy": _lead_mean(log.replay_occupancy).tolist(),
        "drift_ticks": _lead_sum(log.drift_ticks).tolist(),
        "eval_steps": log.eval_steps.tolist(),
        "forgetting_after_task": fg.tolist(),
        "total_write_pulses": log.total_write_pulses,
    }
