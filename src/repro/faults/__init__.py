"""Device-fault injection and graceful degradation (``docs/faults.md``).

Fault masks ride the device-state pytree (the ``"_faults"`` key) like
the fleet heterogeneity overlay: jit-traced, vmappable over a fleet
axis, scan-carried through compiled runs. Attach a :class:`FaultSpec`
to a backend's ``DeviceSpec(faults=...)`` to enable injection; leave it
None and every program is bitwise identical to a fault-free build.
"""
from repro.faults.mitigate import (calibration_drives, compensate_bias,
                                   march_recover, recalibrate,
                                   remap_columns)
from repro.faults.model import (FaultSpec, advance_wear, apply_cell_faults,
                                apply_read_upsets, effective_masks,
                                fault_state, mask_updates,
                                sample_fault_state, stuck_fraction)

__all__ = [
    "FaultSpec", "advance_wear", "apply_cell_faults", "apply_read_upsets",
    "calibration_drives", "compensate_bias", "effective_masks",
    "fault_state", "march_recover", "mask_updates", "recalibrate",
    "remap_columns", "sample_fault_state", "stuck_fraction",
]
