"""Device-fault models — jit-compatible fault masks for crossbar tiles.

The paper's 12.2-year lifetime number is an analytical projection from
per-cell write counts (``analog/endurance.lifespan_years``). This module
supplies the missing empirical half: what the network actually computes
when devices *fail*. Faults are represented as a pytree of per-tile masks
carried in the device-state dict under the ``"_faults"`` key — the same
vehicle the fleet heterogeneity overlay (``"_het"``) rides — so they are
traced, vmappable over a fleet axis, and scan-carried through a compiled
run.

Fault taxonomy (all rates are independent per-cell/row/column Bernoulli
probabilities, sampled once per device from a PRNG key):

  SA0   stuck-at-G_off — the cell reads logical 0 and rejects writes.
  SA1   stuck-at-G_on — the cell reads ``±sa1_value`` (the logical
        dynamic range) with a random sign, and rejects writes.
  dead row / dead column — driver or line failure: every cell on the
        line reads 0 (a short to the reference column current).
  transient read upsets — per-access, per-element ADC latch corruption:
        with probability ``upset_rate`` an output element is replaced by
        a uniform draw over the ADC full scale. Transient faults leave
        no state behind and force the per-step recurrence path (the
        fused kernel cannot draw per-step upsets).
  wear-out — endurance exhaustion: each cell carries a write counter and
        a lognormally-sampled endurance limit; when the counter crosses
        the limit mid-run the cell becomes stuck (mode-selectable), so a
        long training run produces an empirical accuracy-vs-age curve to
        hold against the ``lifespan_years`` projection.

Mask contract (enforced by tests and BENCH_faults gates):

  * zero-fault configurations (``DeviceSpec.faults is None``) never
    construct masks — the traced program is *byte-identical* to a build
    without this module;
  * a zero-rate :class:`FaultSpec` produces all-False masks whose
    application is bitwise identity;
  * applying a mask is idempotent (``where(stuck, v, ·)`` is a
    projection), so read-side and prepare-side masking may compose;
  * the same masked weight tensor feeds the per-step and fused
    recurrence paths, so fused-vs-per-step stays bitwise identical
    *under* faults.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Key-derivation salt for fault-mask sampling — folds the backend's
# device-state key into a stream disjoint from conductance programming
# (analog_state's split chain) and the fleet overlays.
_FAULT_SALT = 0xFA17


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Fault-injection knobs for a :class:`repro.backends.base.DeviceSpec`.

    Static-mask rates (sampled once per device at state init):
      sa0_rate        per-cell stuck-at-G_off probability.
      sa1_rate        per-cell stuck-at-G_on probability (random sign).
      dead_row_rate   per-row driver-failure probability.
      dead_col_rate   per-column line-failure probability (spare columns
                      included — spares can be born dead).
      n_spare_cols    redundant columns per tile available to the
                      remap mitigation (0 = no redundancy).

    Transient faults:
      upset_rate      per-access, per-element read-upset probability.

    Endurance wear-out:
      wearout             enable per-cell write counters + limits.
      wearout_endurance   mean endurance limit (writes per cell).
      wearout_spread      lognormal sigma of the per-cell limit draw.
      wearout_scale       age acceleration: one training write advances
                          the counter by this many physical writes, so a
                          short run sweeps a multi-year virtual age.
      wearout_mode        what a worn cell reads: "sa0" (G_off), "sa1"
                          (±range, sign of the last value) or "freeze"
                          (stuck at the last written value).

    Fleet propagation (consumed by ``fleet/heterogeneity.py``):
      rate_spread     lognormal sigma of a per-chip multiplier on the
                      static-mask rates (mean-preserving).
      dead_chip_rate  per-chip probability that the whole die is dead
                      (every cell stuck at 0).
    """
    sa0_rate: float = 0.0
    sa1_rate: float = 0.0
    dead_row_rate: float = 0.0
    dead_col_rate: float = 0.0
    n_spare_cols: int = 0
    upset_rate: float = 0.0
    wearout: bool = False
    wearout_endurance: float = 1e9
    wearout_spread: float = 0.3
    wearout_scale: float = 1.0
    wearout_mode: str = "sa0"
    rate_spread: float = 0.0
    dead_chip_rate: float = 0.0

    def any_static(self) -> bool:
        return (self.sa0_rate > 0 or self.sa1_rate > 0
                or self.dead_row_rate > 0 or self.dead_col_rate > 0
                or self.wearout)


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def _sample_tile(key: jax.Array, shape: tuple[int, ...], spec: FaultSpec,
                 sa1_value: float, rate_scale, dead) -> dict[str, jax.Array]:
    n_in, n_out = shape
    n_sp = spec.n_spare_cols
    scale = jnp.float32(1.0) if rate_scale is None \
        else jnp.asarray(rate_scale, jnp.float32)
    ku, ks, kr, kc, kw, kp, kq = jax.random.split(key, 7)
    # One uniform draw decides SA0 vs SA1 vs healthy per cell (disjoint).
    u = jax.random.uniform(ku, shape)
    p0 = spec.sa0_rate * scale
    p1 = spec.sa1_rate * scale
    sa0 = u < p0
    sa1 = (u >= p0) & (u < p0 + p1)
    row_dead = jax.random.uniform(kr, (n_in, 1)) \
        < spec.dead_row_rate * scale
    col_u = jax.random.uniform(kc, (1, n_out + n_sp))
    col_dead_all = col_u < spec.dead_col_rate * scale
    col_dead = col_dead_all[:, :n_out]
    line_dead = row_dead | col_dead
    sign = jnp.where(jax.random.uniform(ks, shape) < 0.5, -1.0, 1.0)
    stuck = sa0 | sa1 | line_dead
    value = jnp.where(sa1 & ~line_dead, sign * sa1_value,
                      0.0).astype(jnp.float32)
    if dead is not None:
        d = jnp.asarray(dead)
        stuck = stuck | d
        value = jnp.where(d, 0.0, value)
    tile = {"stuck": stuck, "value": value}
    if n_sp > 0:
        usp = jax.random.uniform(kp, (n_in, n_sp))
        sp_line = row_dead | col_dead_all[:, n_out:]
        sp1 = (usp >= p0) & (usp < p0 + p1)
        sp_stuck = (usp < p0 + p1) | sp_line
        sp_sign = jnp.where(jax.random.uniform(kq, (n_in, n_sp)) < 0.5,
                            -1.0, 1.0)
        sp_value = jnp.where(sp1 & ~sp_line, sp_sign * sa1_value,
                             0.0).astype(jnp.float32)
        if dead is not None:
            d = jnp.asarray(dead)
            sp_stuck = sp_stuck | d
            sp_value = jnp.where(d, 0.0, sp_value)
        tile["spare_stuck"] = sp_stuck
        tile["spare_value"] = sp_value
        tile["colmap"] = jnp.arange(n_out, dtype=jnp.int32)
    if spec.wearout:
        s = spec.wearout_spread
        z = jax.random.normal(kw, shape)
        # Mean-preserving lognormal endurance limits per cell.
        tile["wear_limit"] = (spec.wearout_endurance
                              * jnp.exp(s * z - 0.5 * s * s)
                              ).astype(jnp.float32)
        tile["wear_count"] = jnp.zeros(shape, jnp.float32)
    return tile


def sample_fault_state(params: dict, key: jax.Array, spec: FaultSpec, *,
                       sa1_value: float = 1.0, rate_scale=None,
                       dead=None) -> dict[str, dict[str, jax.Array]]:
    """Sample per-tile fault masks for every ≥2-D (crossbar) parameter.

    ``rate_scale`` (traced scalar) multiplies the static-mask rates —
    the fleet heterogeneity overlay's per-chip draw. ``dead`` (traced
    bool) forces the whole device stuck-at-0 (a dead chip). Both may be
    traced under vmap, so a fleet of chips samples in one program."""
    names = sorted(n for n, p in params.items() if jnp.ndim(p) >= 2)
    base = jax.random.fold_in(key, _FAULT_SALT)
    return {name: _sample_tile(jax.random.fold_in(base, i),
                               jnp.shape(params[name]), spec,
                               sa1_value, rate_scale, dead)
            for i, name in enumerate(names)}


# ---------------------------------------------------------------------------
# Mask application
# ---------------------------------------------------------------------------

def fault_state(state: Any) -> Optional[dict]:
    """The fault-mask pytree riding a device-state dict, or None."""
    return state.get("_faults") if isinstance(state, dict) else None


def effective_masks(tile: dict) -> tuple[jax.Array, jax.Array]:
    """(stuck, value) for a tile *after* column remapping: logical
    column j reads physical column ``colmap[j]``, which may be a spare.
    Without spares the primary masks apply directly (no gather)."""
    stuck, value = tile["stuck"], tile["value"]
    cm = tile.get("colmap")
    if cm is None:
        return stuck, value
    stuck = jnp.concatenate([stuck, tile["spare_stuck"]], axis=1)[:, cm]
    value = jnp.concatenate([value, tile["spare_value"]], axis=1)[:, cm]
    return stuck, value


def apply_cell_faults(w: jax.Array, tile: Optional[dict]) -> jax.Array:
    """Read a logical weight matrix through its stuck-cell mask.
    Idempotent (a projection); identity when the mask is all-False."""
    if tile is None:
        return w
    stuck, value = effective_masks(tile)
    return jnp.where(stuck, value.astype(w.dtype), w)


def mask_updates(updates: dict, fstate: dict) -> dict:
    """Zero write pulses aimed at stuck cells — a stuck device rejects
    programming, so it must not advance endurance counters either."""
    out = {}
    for name, u in updates.items():
        tile = fstate.get(name)
        if tile is None:
            out[name] = u
        else:
            stuck, _ = effective_masks(tile)
            out[name] = jnp.where(stuck, jnp.zeros((), u.dtype), u)
    return out


def apply_read_upsets(pre: jax.Array, key: jax.Array, rate: float,
                      scale: float) -> jax.Array:
    """Transient read upsets: each output element is independently
    replaced, with probability ``rate``, by a uniform draw over the ADC
    full scale ``[-scale, scale]`` — a corrupted ADC latch."""
    ku, kv = jax.random.split(key)
    hit = jax.random.uniform(ku, pre.shape) < rate
    garbage = jax.random.uniform(kv, pre.shape, minval=-scale,
                                 maxval=scale)
    return jnp.where(hit, garbage.astype(pre.dtype), pre)


# ---------------------------------------------------------------------------
# Endurance wear-out
# ---------------------------------------------------------------------------

def advance_wear(fstate: dict, applied: dict, spec: FaultSpec,
                 new_params: dict, *, sa1_value: float = 1.0) -> dict:
    """Advance per-cell write counters by the nonzero applied updates
    (scaled by the age-acceleration factor) and convert cells whose
    counter crossed its sampled endurance limit into stuck cells.

    Virtual device age after ``n`` updates is
    ``n * wearout_scale * update_period_s``; a cell written at the mean
    per-update rate fails at exactly the age ``lifespan_years`` projects
    for that rate — the acceleration factor cancels — which is what the
    BENCH_faults wear-out gate checks empirically."""
    out = {}
    for name, tile in fstate.items():
        if "wear_count" not in tile or name not in applied:
            out[name] = tile
            continue
        wrote = (applied[name] != 0) & ~tile["stuck"]
        count = tile["wear_count"] \
            + spec.wearout_scale * wrote.astype(jnp.float32)
        newly = (count >= tile["wear_limit"]) & ~tile["stuck"]
        p = new_params[name]
        if spec.wearout_mode == "freeze":
            worn_value = p.astype(jnp.float32)
        elif spec.wearout_mode == "sa1":
            worn_value = jnp.where(p >= 0, sa1_value,
                                   -sa1_value).astype(jnp.float32)
        else:  # "sa0"
            worn_value = jnp.zeros_like(tile["value"])
        out[name] = {**tile,
                     "stuck": tile["stuck"] | newly,
                     "value": jnp.where(newly, worn_value, tile["value"]),
                     "wear_count": count}
    return out


# ---------------------------------------------------------------------------
# Introspection helpers (host-side reporting)
# ---------------------------------------------------------------------------

def stuck_fraction(fstate: Optional[dict]) -> float:
    """Fraction of cells currently stuck across all tiles (effective,
    i.e. post-remap — what the network actually reads through)."""
    if not fstate:
        return 0.0
    tot = bad = 0
    for tile in fstate.values():
        stuck, _ = effective_masks(tile)
        tot += stuck.size
        bad += int(jnp.sum(stuck))
    return bad / max(tot, 1)
