"""Fault detection + graceful degradation for crossbar substrates.

Three composable layers, in the order a real controller would run them:

  march_recover     write/read-back self-test that *recovers* the
                    stuck-cell map without being told where the faults
                    are: program a test pattern, read it back through
                    the device stack, flag deviating cells.
  remap_columns     redundant-column repair: retire the worst faulty
                    logical columns onto the tile's spare columns
                    (``FaultSpec.n_spare_cols``) by rewriting the
                    column map. Pure metadata — no device writes.
  compensate_bias   compensation re-programming: fold each stuck cell's
                    expected pre-activation error (under calibration
                    drive statistics) into the healthy digital bias
                    registers, cancelling the fault's mean effect.
  recalibrate       a short burst of continued on-chip training with the
                    masks active, letting the healthy cells re-learn
                    around whatever remains.

``benchmarks/fault_bench.py`` gates the stack end to end: at 1 % stuck
cells the mitigated model must recover at least half of the accuracy the
unmitigated faulty model lost.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.model import effective_masks, fault_state


# ---------------------------------------------------------------------------
# Detection — march-style write/read-back self-test
# ---------------------------------------------------------------------------

def march_recover(backend, params: dict, state: Any, *,
                  probe: Optional[float] = None,
                  tol: Optional[float] = None) -> dict:
    """Recover the stuck-cell map of every crossbar tile by self-test.

    March element: program the whole tile to ``-probe``, read back with
    one-hot drives (each output element isolates one cell), then repeat
    at ``+probe``. A healthy cell tracks the programmed value through
    the WBS/ADC stack to within quantization tolerance; a stuck cell
    returns the same conductance both times, so it deviates on at least
    one read. The recovered per-cell value is the mean of the two reads
    — for a stuck cell, both reads *are* the stuck value.

    Reads go through ``device_vmm`` with a state that carries only the
    fault masks, so the probe pattern (not the programmed pairs) is what
    the substrate quantizes — this is the "write" half of the march for
    conductance-domain backends too. Deterministic: no PRNG key, so
    plane gains are ideal and read noise is off during the test."""
    fstate = fault_state(state)
    v = probe if probe is not None \
        else 0.5 * backend._fault_value_scale()
    if tol is None:
        tol = 0.25 * v
    probe_state = None if fstate is None else {"_faults": fstate}
    recovered = {}
    for name in sorted(params):
        p = params[name]
        if jnp.ndim(p) < 2:
            continue
        if fstate is not None and name not in fstate:
            continue
        eye = jnp.eye(p.shape[0], dtype=p.dtype)
        w_lo = jnp.full(p.shape, -v, p.dtype)
        w_hi = jnp.full(p.shape, +v, p.dtype)
        r_lo = backend.device_vmm(eye, w_lo, state=probe_state, tag=name)
        r_hi = backend.device_vmm(eye, w_hi, state=probe_state, tag=name)
        bad = (jnp.abs(r_lo + v) > tol) | (jnp.abs(r_hi - v) > tol)
        val = jnp.where(bad, 0.5 * (r_lo + r_hi), 0.0).astype(jnp.float32)
        recovered[name] = {"stuck": bad, "value": val}
    return recovered


# ---------------------------------------------------------------------------
# Mitigation 1 — redundant-column remap
# ---------------------------------------------------------------------------

def remap_columns(fstate: dict) -> dict:
    """Retire the faultiest logical columns onto spare columns.

    Greedy host-side assignment: columns ranked by stuck-cell count,
    spares ranked by their own (spares can be born faulty too); a column
    is remapped only onto a strictly healthier spare. Each spare is
    consumed at most once — the column map stays injective (property-
    tested). Tiles without spares pass through unchanged."""
    out = {}
    for name, tile in fstate.items():
        if "colmap" not in tile:
            out[name] = tile
            continue
        stuck = np.asarray(tile["stuck"])
        sp = np.asarray(tile["spare_stuck"])
        n_out = stuck.shape[1]
        col_bad = stuck.sum(axis=0)
        sp_bad = sp.sum(axis=0)
        spares = list(np.argsort(sp_bad, kind="stable"))
        colmap = np.arange(n_out, dtype=np.int32)
        for j in np.argsort(-col_bad, kind="stable"):
            if not spares or col_bad[j] == 0:
                break
            s = spares[0]
            if sp_bad[s] >= col_bad[j]:
                break
            spares.pop(0)
            colmap[j] = n_out + s
        out[name] = {**tile, "colmap": jnp.asarray(colmap)}
    return out


# ---------------------------------------------------------------------------
# Mitigation 2 — compensation re-programming (healthy bias registers)
# ---------------------------------------------------------------------------

def calibration_drives(backend, params: dict, cfg, x_calib: jax.Array,
                       key: jax.Array, state: Any = None) -> dict:
    """Mean drive vector per hidden tile under a calibration batch:
    the input stream's feature means for ``w_h`` and the faulty
    forward's mean recurrent drive (β·h) for ``u_h``."""
    _, h_prev, _ = backend.device_recurrence(params, cfg, x_calib, key,
                                             state=state)
    d_x = jnp.mean(x_calib.reshape(-1, x_calib.shape[-1]), axis=0)
    d_h = cfg.beta * jnp.mean(h_prev.reshape(-1, h_prev.shape[-1]),
                              axis=0)
    return {"w_h": d_x, "u_h": d_h}


def compensate_bias(params: dict, fstate: dict, drives: dict) -> dict:
    """Cancel each stuck cell's expected pre-activation contribution by
    re-programming the healthy digital bias registers:

        b_h[j] -= sum_i  d̄_i · (v_ij − w_ij)   over stuck cells (i, j)

    where d̄ is the tile's calibration drive mean and v the stuck value.
    First-order mean compensation — residual variance is what
    :func:`recalibrate` cleans up."""
    delta = jnp.zeros_like(params["b_h"])
    for tag, d in drives.items():
        tile = fstate.get(tag)
        if tile is None or tag not in params:
            continue
        stuck, value = effective_masks(tile)
        err = jnp.sum(jnp.where(stuck,
                                (value.astype(params[tag].dtype)
                                 - params[tag]) * d[:, None], 0.0),
                      axis=0)
        delta = delta + err
    out = dict(params)
    out["b_h"] = params["b_h"] - delta
    return out


# ---------------------------------------------------------------------------
# Mitigation 3 — recalibration (continued on-chip training under faults)
# ---------------------------------------------------------------------------

def recalibrate(cfg, trainer, backend, params: dict, state: Any, task, *,
                steps: int = 8, seed: int = 0):
    """Run ``steps`` continued training batches with the fault masks
    active. Writes aimed at stuck cells are rejected by the device layer
    (``mask_updates``), so only healthy cells move — the network learns
    around its faults. Returns (params, state)."""
    from repro.core.continual import _init_run, _make_raw_steps

    train_step, _, opt = _make_raw_steps(cfg, trainer, backend)
    _, _, psi, _ = _init_run(cfg, trainer, backend)
    opt_state = opt.init(params) if trainer.algo == "adam" \
        else {"psi": psi}
    k = jax.random.PRNGKey(seed)
    n = task.x_train.shape[0]
    B = min(trainer.batch_size, n)
    for _ in range(steps):
        k, k_step, k_batch = jax.random.split(k, 3)
        idx = jax.random.choice(k_batch, n, (B,), replace=False)
        x = jnp.asarray(task.x_train[np.asarray(idx)])
        y = jnp.asarray(task.y_train[np.asarray(idx)])
        params, opt_state, _, applied, state = train_step(
            params, opt_state, k_step, x, y, state)
        backend.record_endurance(jax.device_get(applied))
    return params, state
