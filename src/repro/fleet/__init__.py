"""repro.fleet — sharded device-fleet simulation.

Population-scale continual learning across heterogeneous simulated
M2RU chips: per-device parameter draws (:mod:`.heterogeneity`), a
``shard_map``-sharded runner wrapping the compiled per-seed program
(:mod:`.run`), and fleet-aggregate telemetry distributions
(:mod:`.aggregate`). See docs/fleet.md.
"""
from repro.fleet.aggregate import distribution, fleet_aggregate
from repro.fleet.heterogeneity import (HET_PROFILES, FleetSpec, HetProfile,
                                       device_seeds, draw_fleet_faults,
                                       draw_heterogeneity,
                                       supports_heterogeneity)
from repro.fleet.run import fleet_shard_count, run_fleet

__all__ = [
    "FleetSpec", "HetProfile", "HET_PROFILES",
    "device_seeds", "draw_heterogeneity", "draw_fleet_faults",
    "supports_heterogeneity",
    "run_fleet", "fleet_shard_count",
    "fleet_aggregate", "distribution",
]
