"""Sharded fleet runner: one compiled program, a population of chips.

:func:`run_fleet` wraps the exact per-seed program that
:func:`repro.scenarios.sweep.run_compiled` builds (`_build_seed_inputs`
→ `_make_run_fn`), lifts it over a device axis with ``vmap``, and
shards that axis across the host's accelerator mesh with ``shard_map``
— the same mesh/PartitionSpec idiom as :mod:`repro.distributed`, but
over a *fleet* axis instead of batch/expert axes. Each simulated chip
gets:

  * its own data-stream seed (``device_seeds`` — a Xorshift32 chain),
  * its own crossbar parameter draw (``draw_heterogeneity`` → the
    ``"_het"`` overlay the ``analog_state`` backend threads through
    read/write/drift),
  * its own per-cell G⁺/G⁻ initial programming (re-programmed under the
    chip's own ``prog_sigma`` with a chip-local key).

Telemetry stays jit-exact: the shard body is traced once under
``telemetry.scaled(n_local)`` (the per-shard device count), and the one
deferred ``io_callback`` fires once *per shard* at run time — k shards
× n_local-scaled deltas = the whole fleet's counters, independent of
mesh shape. Data-dependent write pulses come back as per-device count
maps, so lifetime projections keep their per-chip resolution.

With ``het_profile="none"`` nothing is attached to the device-state
pytree: the trace is identical to ``run_compiled``'s seed-vmapped path
and the results are bit-identical to it (the parity gate in
tests/test_fleet.py and benchmarks/fleet_bench.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.backends import DeviceBackend, get_backend
from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  _ingraph_replay_traffic, _make_raw_steps)
from repro.data.pipeline import shard_tasks
from repro.data.synthetic import TaskData
from repro.fleet.heterogeneity import (FleetSpec, device_seeds,
                                       draw_fleet_faults,
                                       draw_heterogeneity,
                                       overlay_device_states,
                                       overlay_fault_states)
from repro.replay import get_policy_class
from repro.scenarios.sweep import (_aggregate_seeds, _build_seed_inputs,
                                   _make_run_fn, _summarize_run)

__all__ = ["run_fleet", "fleet_shard_count"]


def fleet_shard_count(n_devices: int,
                      max_shards: Optional[int] = None) -> int:
    """Shards for a fleet of ``n_devices``: the largest divisor of the
    fleet size that fits the available accelerators (optionally capped).
    A divisor keeps every shard's local batch equal, so one trace serves
    all shards and the mesh shape never changes the arithmetic."""
    avail = len(jax.devices())
    if max_shards is not None:
        avail = min(avail, int(max_shards))
    avail = max(1, min(avail, n_devices))
    return max(d for d in range(1, avail + 1) if n_devices % d == 0)


def run_fleet(cfg, spec: TrainerSpec, tasks: list[TaskData],
              fleet: FleetSpec,
              replay: Optional[ReplaySpec] = None,
              device: Union[str, DeviceBackend, None] = None,
              *, baseline: bool = True,
              max_shards: Optional[int] = None,
              shard_data: bool = False,
              obs: Optional[Any] = None) -> dict[str, Any]:
    """Train ``fleet.n_devices`` heterogeneous chips through the task
    sequence inside one sharded compiled program.

    Same per-chip contract as ``run_compiled(..., seeds=...)`` — each
    device's cell in ``per_device`` has the R matrix, metrics and losses
    ``run_compiled`` would report for that seed — plus the fleet frame:

      per_device        one summary dict per chip (R_full, MA, metrics)
      device_seeds      the Xorshift32-derived data-stream seeds
      het               the per-chip crossbar draws (None for "none")
      wcounts           per-device write-pulse count maps
                        (name → (n_devices, *w.shape) int32), the input
                        to per-chip lifetime projection
      n_shards          mesh size actually used (largest divisor of the
                        fleet size that fits the available devices)
      metrics/metrics_std  fleet mean/std, as in the seed-vmapped path

    ``shard_data=True`` turns the fleet into a data-parallel consumer of
    one stream: chip ``d`` trains on shard ``d`` of ``n_devices`` from
    :func:`repro.data.pipeline.shard_tasks` — pairwise-disjoint strided
    training slices truncated to ``n_train // n_devices`` rows (one
    compile shape for the whole fleet) — while every chip evaluates the
    full shared test sets. The default (False) keeps every chip on the
    complete stream and preserves the bitwise ``run_compiled(seeds=...)``
    parity gate.

    ``obs`` is a :class:`repro.obs.ObsSpec`: the result gains a
    ``"runlog"`` whose streams carry a leading ``(n_devices,)`` chip
    axis (``timeline`` reduces it — counters summed across the fleet,
    gauges averaged), and the tracer records ``schedule`` / ``compile``
    / ``execute`` spans plus ``compile_s``/``execute_s`` keys.

    Raises on ragged task streams (the fleet axis needs one trace) and
    on heterogeneity profiles with a backend that has no conductance-
    domain state.
    """
    trainer = spec
    if not isinstance(trainer, TrainerSpec):
        raise TypeError("run_fleet takes a TrainerSpec")
    rspec = replay if replay is not None else ReplaySpec()
    backend = get_backend(device if device is not None else "ideal")
    tele = backend.telemetry
    obs_on = obs is not None and getattr(obs, "metrics", False)
    tracer = getattr(obs, "tracer", None) if obs is not None else None
    D = fleet.n_devices
    seeds = device_seeds(fleet)

    test_shapes = {(t.x_test.shape, t.y_test.shape) for t in tasks}
    if len(test_shapes) != 1:
        raise ValueError("run_fleet needs shape-uniform eval sets "
                         "(one trace serves the whole fleet)")

    _, _, opt = _make_raw_steps(cfg, trainer, backend)
    sched_scope = tracer.span("schedule", n_devices=D) \
        if tracer is not None else contextlib.nullcontext()
    inputs, scheds = [], []
    with sched_scope:
        for d, s in enumerate(seeds):
            tsp = dataclasses.replace(trainer, seed=int(s))
            # Per-chip data shard: disjoint strided training slices of
            # the one stream, equal-sized so one trace serves the fleet.
            chip_tasks = (shard_tasks(tasks, D, d) if shard_data
                          else tasks)
            inp, sched = _build_seed_inputs(cfg, tsp, rspec, backend,
                                            chip_tasks, opt)
            if inp is None:
                raise ValueError("run_fleet needs a shape-uniform task "
                                 "stream (ragged schedules cannot share "
                                 "the fleet trace)")
            inputs.append(inp)
            scheds.append(sched)

    n_tasks = len(tasks)
    S = inputs[0].xs.shape[1]
    track_writes = backend.tracker is not None or tele.enabled
    in_graph = get_policy_class(rspec.resolved_policy).in_graph
    if tele.enabled:
        # Host-side replay-traffic credit, once per chip's schedule —
        # the same accounting as run_compiled's seed loop.
        T, F = tasks[0].x_train.shape[1:]
        for sched in scheds:
            traffic = _ingraph_replay_traffic(
                rspec, trainer.batch_size, sched.steps_per_task,
                (T, F)) if in_graph else sched.replay_traffic
            if traffic:
                tele.record(traffic)
    run = _make_run_fn(cfg, trainer, backend, n_tasks, S, track_writes,
                       baseline, ingraph_rspec=rspec if in_graph else None,
                       obs_metrics=obs_on)

    eval_x = jnp.asarray(np.stack([t.x_test for t in tasks]))
    eval_y = jnp.asarray(np.stack([t.y_test for t in tasks]))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[i.as_arrays() for i in inputs])

    het = draw_heterogeneity(fleet)
    # Host copy up front: the draws alias the donated device-state
    # pytree ("_het" leaves), so the device buffers die with the run.
    het_np = ({k: np.asarray(v) for k, v in het.items()}
              if het is not None else None)
    if het is not None:
        # Replace the homogeneous device states with per-chip
        # programming under each chip's own parameter draw.
        dev_state = overlay_device_states(backend, stacked[0], seeds, het)
        stacked = stacked[:2] + (dev_state,) + stacked[3:]

    # Fleet-level fault severity: when the backend's FaultSpec carries a
    # per-chip rate spread or a dead-chip rate, re-sample every chip's
    # masks under its own draw (chip-local keys, traced multipliers).
    # Without those knobs the per-seed masks from _build_seed_inputs
    # stand, and this block leaves the program untouched.
    fspec = getattr(backend.spec, "faults", None)
    fault_scale, dead_chips = draw_fleet_faults(fleet, fspec)
    fault_scale_np = (np.asarray(fault_scale)
                      if fault_scale is not None else None)
    dead_np = np.asarray(dead_chips) if dead_chips is not None else None
    if fault_scale is not None:
        dev_state = stacked[2]
        new_masks = overlay_fault_states(backend, stacked[0], seeds,
                                         fault_scale, dead_chips, fspec)
        dev_state = {**dev_state, "_faults": new_masks}
        stacked = stacked[:2] + (dev_state,) + stacked[3:]

    n_shards = fleet_shard_count(D, max_shards)
    n_local = D // n_shards
    mesh = Mesh(np.array(jax.devices()[:n_shards]), (fleet.mesh_axis,))
    ax = P(fleet.mesh_axis)
    vrun = jax.vmap(run, in_axes=(0,) * 8 + (None, None))
    # Donate the mutated state buffers (params; the conductance pairs) —
    # the shard-local copies alias in place. The deferred telemetry
    # callback fires once per shard over the n_local-scaled deltas, so
    # the counter totals are mesh-shape invariant.
    fn = jax.jit(shard_map(vrun, mesh=mesh,
                           in_specs=(ax,) * 8 + (P(), P()),
                           out_specs=ax),
                 donate_argnums=(0, 2))
    t0 = time.perf_counter()
    compile_s = execute_s = None
    if tracer is not None:
        # AOT lowering separates compile from execute; the telemetry
        # scale scope wraps the lowering — that is when the per-shard
        # deltas are recorded.
        with tracer.span("compile", backend=backend.name, n_devices=D,
                         n_shards=n_shards):
            with tele.scaled(n_local):
                lowered = fn.lower(*stacked, eval_x, eval_y)
            compiled_fn = lowered.compile()
        compile_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        with tracer.span("execute", backend=backend.name, n_devices=D):
            res = compiled_fn(*stacked, eval_x, eval_y)
            res = jax.tree.map(np.asarray, res)
        execute_s = time.perf_counter() - t1
    else:
        with tele.scaled(n_local):
            res = fn(*stacked, eval_x, eval_y)
        res = jax.tree.map(np.asarray, res)
    wall_s = time.perf_counter() - t0
    obs_streams = res.pop("obs", None)

    # Host-side accounting of the scan-summed write pulses — fleet
    # totals into the meters/tracker, per-device maps kept for the
    # population lifetime distributions.
    wcounts = res.pop("wcounts")
    per_device_wcounts = None
    if track_writes and wcounts:
        per_device_wcounts = {k: np.asarray(v) for k, v in wcounts.items()}
        counts = {k: v.sum(axis=0) for k, v in per_device_wcounts.items()}
        total_steps = n_tasks * S * D
        tele.meter_write_counts(counts, total_steps)
        if backend.tracker is not None:
            backend.tracker.record_counts(counts, total_steps)

    per_device = [_summarize_run(res["R_full"][i], res["baseline_row"][i],
                                 res["losses"][i], baseline)
                  for i in range(D)]
    out: dict[str, Any] = dict(per_device[0])
    out.update(_aggregate_seeds(per_device, seeds))
    out["per_device"] = out.pop("per_seed")
    out["device_seeds"] = out.pop("seeds")
    out.update({
        "compiled": True,
        "fleet": fleet,
        "n_devices": D,
        "n_shards": n_shards,
        "n_local": n_local,
        "wall_s": wall_s,
        "steps_per_task": S,
        "updates_per_device": n_tasks * S,
        "het": het_np,
        "wcounts": per_device_wcounts,
        "params": jax.tree.map(lambda v: v[0], res["params"]),
        "params_fleet": res["params"],
    })
    if fspec is not None:
        out["faults"] = {"spec": fspec,
                         "rate_scale": fault_scale_np,
                         "dead_chips": dead_np}
    if compile_s is not None:
        out["compile_s"] = compile_s
        out["execute_s"] = execute_s
    if obs_on:
        from repro.obs.runlog import build_runlog, drift_stream

        def _ps(a):
            # Per-step stream (D, n_tasks, S) → (D, total).
            return np.asarray(a).reshape(D, -1)

        if in_graph:
            occ = _ps(obs_streams["replay_occupancy"])
        else:
            occ = np.stack([sc.occupancy_stream() for sc in scheds])
        cb = backend.spec.crossbar
        drifting = (inputs[0].dev_state is not None and cb is not None
                    and (getattr(cb, "drift_rate", 0.0) > 0
                         or (het_np is not None
                             and "drift_rate" in het_np)))
        drift = np.broadcast_to(
            drift_stream(n_tasks * S, drifting=drifting),
            (D, n_tasks * S))
        out["runlog"] = build_runlog(
            cadence=obs.cadence,
            steps_per_task=scheds[0].steps_per_task,
            loss=_ps(res["losses"]),
            write_pulses=_ps(obs_streams["write_pulses"]),
            dg_mag=_ps(obs_streams["dg_mag"]),
            replay_occupancy=occ,
            drift_ticks=drift,
            task_acc=res["R_full"])
    if backend.tracker is not None:
        out["endurance"] = backend.tracker
    if tele.enabled:
        out["telemetry"] = tele
    return out
