"""Fleet-aggregate telemetry: population distributions, not means.

A deployment decision about an edge fleet hinges on the *tail* chip,
not the average one: the die that drew the heavy write-variability
corner wears out first, the chip whose data stream happened to
interleave tasks adversarially forgets most. This module folds one
:func:`repro.fleet.run.run_fleet` result into per-device figures and
summarizes each as a distribution — p50/p95/p99 plus a hot-tail index
naming the worst chip.

Per-device energy books are synthesized from the fleet telemetry
snapshot: every counter the forward path meters (MACs, WBS phases, ADC
conversions, …) is exactly fleet-symmetric — each chip ran the same
program shape — so the static share is ``total / n_devices``; only the
data-dependent write pulses differ per chip, and those come back from
the run as per-device count maps. Each chip's synthesized counter dict
then goes through the same :class:`~repro.telemetry.energy.MeteredEnergy`
fold as a single-chip report, so the fleet numbers stay consistent with
the paper-calibrated cost model by construction.

Lifetime is projected per chip from its own write map
(:func:`~repro.telemetry.lifetime.project_lifetime`), preserving the
per-cell ζ write-rate percentiles within each chip as well as the
across-fleet spread.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.analog.costmodel import HardwareConstants
from repro.analog.endurance import EnduranceTracker
from repro.telemetry import meters
from repro.telemetry.energy import MeteredEnergy
from repro.telemetry.lifetime import project_lifetime

__all__ = ["fleet_aggregate", "distribution"]

#: Percentiles every fleet distribution reports (the bench gate's
#: schema contract).
PERCENTILES = (50, 95, 99)


def distribution(values) -> dict[str, float]:
    """Summary statistics of one per-device figure across the fleet."""
    arr = np.asarray(list(values), np.float64)
    out = {
        "mean": float(arr.mean()),
        "std": float(arr.std()),
        "min": float(arr.min()),
        "max": float(arr.max()),
    }
    for p in PERCENTILES:
        out[f"p{p}"] = float(np.percentile(arr, p))
    return out


def _per_device_counters(snapshot: dict[str, int], n_devices: int,
                         updates_per_device: int,
                         wcounts: Optional[dict[str, np.ndarray]],
                         device: int) -> dict[str, float]:
    """One chip's counter dict: the fleet-symmetric static share plus
    the chip's own data-dependent write pulses."""
    c: dict[str, float] = {
        k: v / n_devices for k, v in snapshot.items()
        if not k.startswith(meters.WRITE_PULSES)
        and k != meters.WRITE_EVENTS}
    c[meters.WRITE_EVENTS] = float(updates_per_device)
    for name, arr in (wcounts or {}).items():
        c[f"{meters.WRITE_PULSES}/{name}"] = float(
            np.asarray(arr[device]).sum())
    return c


def fleet_aggregate(result: dict[str, Any], *, model=None,
                    kind: str = "analog",
                    hw: Optional[HardwareConstants] = None,
                    update_period_s: float = 1e-3) -> dict[str, Any]:
    """Fold a ``run_fleet`` result into population distributions.

    Always reports the learning distributions (``average_accuracy``,
    ``forgetting``). Energy (``power_mw``, ``gops_per_w``, …) needs the
    run to have been metered (``result["telemetry"]``); lifetime needs
    the per-device write maps (``result["wcounts"]``) — sections whose
    inputs are missing are omitted rather than fabricated.

    ``hot_tail`` names the worst chip per axis (indices into
    ``result["per_device"]`` / ``result["device_seeds"]``).
    """
    D = int(result["n_devices"])
    per_device = result["per_device"]
    updates = int(result["updates_per_device"])
    wcounts = result.get("wcounts")

    acc = [p["metrics"]["average_accuracy"] for p in per_device]
    forg = [p["metrics"]["forgetting"] for p in per_device]
    out: dict[str, Any] = {
        "n_devices": D,
        "n_shards": int(result.get("n_shards", 1)),
        "het_profile": (result["fleet"].het_profile
                        if "fleet" in result else None),
        "updates_per_device": updates,
        "average_accuracy": distribution(acc),
        "forgetting": distribution(forg),
    }
    hot: dict[str, int] = {
        "min_accuracy_device": int(np.argmin(acc)),
        "max_forgetting_device": int(np.argmax(forg)),
    }

    finfo = result.get("faults")
    if finfo is not None:
        # The fault-stricken tail: accuracy's *lower* percentiles are
        # where stuck-cell damage shows (the standard distribution's
        # p95/p99 describe the healthy upper tail), plus the dead-chip
        # census and the severity spread the chips actually drew.
        acc_arr = np.asarray(acc, np.float64)
        dead = finfo.get("dead_chips")
        sec: dict[str, Any] = {
            "dead_chip_count": int(np.asarray(dead).sum())
            if dead is not None else 0,
            "stricken_tail_accuracy": {
                "p1": float(np.percentile(acc_arr, 1)),
                "p5": float(np.percentile(acc_arr, 5)),
                "min": float(acc_arr.min()),
            },
        }
        scale = finfo.get("rate_scale")
        if scale is not None:
            sec["rate_scale"] = distribution(scale)
            hot["max_fault_rate_device"] = int(np.argmax(scale))
        if dead is not None and np.asarray(dead).any():
            sec["dead_devices"] = [int(i) for i in
                                   np.flatnonzero(np.asarray(dead))]
        out["faults"] = sec

    tele = result.get("telemetry")
    if tele is not None and getattr(tele, "enabled", False):
        snap = tele.snapshot()
        me = MeteredEnergy(model)
        reports = [me.report(
            _per_device_counters(snap, D, updates, wcounts, d), kind=kind)
            for d in range(D)]
        out["power_mw"] = distribution(
            [r.power_w * 1e3 for r in reports])
        out["power_training_mw"] = distribution(
            [r.power_training_w * 1e3 for r in reports])
        out["gops_per_w"] = distribution([r.gops_per_w for r in reports])
        out["pj_per_op"] = distribution([r.pj_per_op for r in reports])
        out["energy_mj"] = distribution(
            [r.energy_j * 1e3 for r in reports])
        hot["max_power_device"] = int(np.argmax(
            [r.power_training_w for r in reports]))

    if wcounts:
        projections = []
        for d in range(D):
            tracker = EnduranceTracker()
            tracker.record_counts(
                {n: np.asarray(arr[d]) for n, arr in wcounts.items()},
                updates)
            projections.append(project_lifetime(
                tracker, hw, update_period_s).as_dict())
        out["lifetime_years"] = distribution(
            [p["years_mean"] for p in projections])
        out["lifetime_hot_tail_years"] = distribution(
            [p["years_hot_tail"] for p in projections])
        out["writes_per_device_update"] = distribution(
            [p["writes_per_device_update"] for p in projections])
        # Within-chip ζ write-rate percentiles, worst chip per cell
        # percentile: the fleet's wear picture at cell resolution.
        rp = [p["rate_percentiles"] for p in projections
              if p.get("rate_percentiles")]
        if rp:
            out["zeta_rate_percentiles"] = {
                k: distribution([r[k] for r in rp]) for k in rp[0]}
        hot["min_lifetime_device"] = int(np.argmin(
            [p["years_mean"] for p in projections]))
        out["per_device_lifetime"] = projections

    out["hot_tail"] = hot
    return out
