"""Per-device parameter draws for fleet simulation.

A fabricated population of M2RU chips is not M copies of one
:class:`~repro.analog.crossbar.CrossbarSpec`: programming variability,
read noise, write variability, and retention drift all vary chip to
chip (die position, forming stochasticity, line resistance). This
module materializes that population as data:

  FleetSpec            how many devices, which heterogeneity profile,
                       the fleet-level seed.
  device_seeds         per-device data-stream seeds derived through the
                       paper's Xorshift32 hardware RNG — each chip sees
                       its own draw of the task stream, exactly as if
                       its on-chip RNG seeded the sampler.
  draw_heterogeneity   per-chip crossbar-knob values as stacked f32
                       arrays of shape (n_devices,) — the ``"_het"``
                       overlay the ``analog_state`` backend threads
                       through its read/write/drift paths.

The draws are *absolute* per-chip sigma values (lognormal around the
profile mean), not multiplicative factors: they ride the device-state
pytree as traced scalars, so one compiled program serves every chip and
the fleet axis can be vmapped/sharded. The ``"none"`` profile attaches
nothing — the state pytree (and therefore the trace) is identical to a
plain :func:`repro.scenarios.sweep.run_compiled` run, which is what the
zero-heterogeneity bitwise-parity gate pins down.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.replay import Xorshift32

#: Domain separator folded into the fleet seed before the Xorshift32
#: chain that emits per-device data-stream seeds (keeps the stream
#: disjoint from any other consumer of the same fleet seed).
_SEED_STREAM_SALT = 0xF1EE7D0C

#: fold_in constant for the heterogeneity draw key.
_HET_FOLD = 0x48E7

#: fold_in constant for each device's re-programming key (applied when a
#: het overlay re-programs the G⁺/G⁻ pairs under the chip's own
#: prog_sigma).
_PROG_FOLD = 0xF1EE7

#: fold_in constant for the fleet-level fault draws (per-chip rate
#: multipliers, dead-chip coin flips) and each chip's mask-sampling key.
_FAULT_FOLD = 0xFA11


@dataclasses.dataclass(frozen=True)
class HetProfile:
    """Population statistics for one crossbar knob set.

    Each field is ``(mean, rel_spread)``: per-chip values are drawn as
    ``mean * exp(rel_spread * z - rel_spread**2 / 2)`` with ``z`` a unit
    normal — lognormal, mean-preserving, strictly positive (a negative
    sigma is not a physical device). ``None`` leaves the knob entirely
    alone (no traced override; the static spec value applies).
    """
    name: str
    prog_sigma: Optional[tuple[float, float]] = None
    read_sigma: Optional[tuple[float, float]] = None
    write_sigma: Optional[tuple[float, float]] = None
    drift_rate: Optional[tuple[float, float]] = None

    KNOBS = ("prog_sigma", "read_sigma", "write_sigma", "drift_rate")

    def fields(self) -> dict[str, tuple[float, float]]:
        return {k: getattr(self, k) for k in self.KNOBS
                if getattr(self, k) is not None}


#: The named profiles. "none" is the parity profile (no overlay at
#: all). "mild" is a well-centered fab corner; "harsh" a pessimistic
#: one with heavy chip-to-chip spread — both centered on the
#: analog_state default spec's noise scales.
HET_PROFILES: dict[str, HetProfile] = {
    "none": HetProfile("none"),
    "mild": HetProfile(
        "mild",
        prog_sigma=(0.10, 0.20),
        read_sigma=(0.02, 0.25),
        write_sigma=(0.10, 0.20),
        drift_rate=(1e-4, 0.50),
    ),
    "harsh": HetProfile(
        "harsh",
        prog_sigma=(0.15, 0.50),
        read_sigma=(0.05, 0.60),
        write_sigma=(0.15, 0.50),
        drift_rate=(1e-3, 1.00),
    ),
}


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A simulated device population.

    n_devices     fleet size (the sharded axis length).
    het_profile   key into :data:`HET_PROFILES` (or "none").
    seed          fleet-level seed: drives both the Xorshift32 chain of
                  per-device data-stream seeds and the heterogeneity
                  draws. Two fleets with the same spec are bit-identical.
    mesh_axis     name of the sharding mesh axis the runner builds.
    """
    n_devices: int = 8
    het_profile: str = "none"
    seed: int = 0
    mesh_axis: str = "fleet"

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError("FleetSpec.n_devices must be >= 1, got "
                             f"{self.n_devices}")
        if self.het_profile not in HET_PROFILES:
            raise ValueError(
                f"unknown het_profile {self.het_profile!r}; expected one "
                f"of {sorted(HET_PROFILES)}")

    @property
    def profile(self) -> HetProfile:
        return HET_PROFILES[self.het_profile]


def device_seeds(spec: FleetSpec) -> list[int]:
    """Per-device data-stream seeds: successive words of one Xorshift32
    chain keyed on the fleet seed. Xorshift32's state sequence is a
    permutation cycle over the nonzero 32-bit words, so the seeds are
    pairwise distinct for any fleet that fits in the period — each chip
    trains on its own draw of the task stream."""
    rng = Xorshift32((spec.seed ^ _SEED_STREAM_SALT) & 0xFFFFFFFF)
    return [rng.next() for _ in range(spec.n_devices)]


def draw_heterogeneity(spec: FleetSpec) -> Optional[dict[str, jax.Array]]:
    """The fleet's per-chip crossbar knobs: a dict of f32 arrays of shape
    ``(n_devices,)`` keyed by knob name, or ``None`` for the "none"
    profile (no overlay → trace-identical to the homogeneous run).

    Deterministic in ``spec`` alone; knob order is fixed (sorted) so the
    draw never depends on profile declaration order."""
    fields = spec.profile.fields()
    if not fields:
        return None
    base = jax.random.fold_in(jax.random.PRNGKey(spec.seed), _HET_FOLD)
    out = {}
    for i, name in enumerate(sorted(fields)):
        mean, spread = fields[name]
        z = jax.random.normal(jax.random.fold_in(base, i),
                              (spec.n_devices,))
        draws = mean * jnp.exp(spread * z - 0.5 * spread * spread)
        out[name] = draws.astype(jnp.float32)
    return out


def supports_heterogeneity(backend) -> bool:
    """True when the backend's ``init_device_state`` accepts the ``het``
    overlay (the conductance-domain ``analog_state`` substrate). Logical-
    weight backends have no per-cell state to perturb."""
    try:
        sig = inspect.signature(backend.init_device_state)
    except (TypeError, ValueError):  # pragma: no cover - exotic callables
        return False
    return "het" in sig.parameters


def overlay_device_states(backend, stacked_params, seeds: list[int],
                          het: dict[str, jax.Array]):
    """Re-program every chip's G⁺/G⁻ pairs under its own heterogeneity
    draw. ``stacked_params`` carries the device axis in front; each chip
    programs with a key folded from its *own data-stream seed*, so the
    per-cell initial-programming variation is as device-local as the
    data stream. Returns the stacked device-state pytree (device axis in
    front), with the ``"_het"`` overlay attached per chip."""
    if not supports_heterogeneity(backend):
        raise ValueError(
            f"backend {getattr(backend, 'name', backend)!r} has no "
            "conductance-domain device state; heterogeneity profiles "
            "other than 'none' need the 'analog_state' backend")
    prog_keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(s), _PROG_FOLD)
        for s in seeds])

    def one(params, key, het_slice):
        return backend.init_device_state(params, key, het=het_slice)

    return jax.vmap(one)(stacked_params, prog_keys, het)


# ---------------------------------------------------------------------------
# Fleet-level fault draws (repro.faults)
# ---------------------------------------------------------------------------

def draw_fleet_faults(fleet: FleetSpec, fspec):
    """Per-chip fault severity for a :class:`repro.faults.FaultSpec`.

    Returns ``(rate_scale, dead)`` — a mean-preserving lognormal
    multiplier on the static-mask rates per chip (``fspec.rate_spread``)
    and a Bernoulli whole-chip-death draw (``fspec.dead_chip_rate``) —
    or ``(None, None)`` when the spec has no fleet-level spread (every
    chip then samples its masks at the base rates from its own seed,
    and the fleet program is untouched)."""
    if fspec is None or (fspec.rate_spread <= 0
                         and fspec.dead_chip_rate <= 0):
        return None, None
    base = jax.random.fold_in(jax.random.PRNGKey(fleet.seed), _FAULT_FOLD)
    k_rate, k_dead = jax.random.split(base)
    D = fleet.n_devices
    s = fspec.rate_spread
    if s > 0:
        z = jax.random.normal(k_rate, (D,))
        scale = jnp.exp(s * z - 0.5 * s * s).astype(jnp.float32)
    else:
        scale = jnp.ones((D,), jnp.float32)
    if fspec.dead_chip_rate > 0:
        dead = jax.random.uniform(k_dead, (D,)) < fspec.dead_chip_rate
    else:
        dead = jnp.zeros((D,), bool)
    return scale, dead


def overlay_fault_states(backend, stacked_params, seeds: list[int],
                         scale: jax.Array, dead: jax.Array, fspec):
    """Re-sample every chip's fault masks under its fleet-level draw.

    Each chip's masks come from a key folded off its *own data-stream
    seed* (chip-local, like the programming keys), with the chip's rate
    multiplier and dead-chip flag applied as traced scalars — one vmapped
    sampling program covers the whole fleet. Returns the stacked
    ``"_faults"`` pytree (device axis in front), structurally identical
    to the per-seed masks it replaces."""
    from repro.faults.model import sample_fault_state

    fkeys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(s), _FAULT_FOLD)
        for s in seeds])
    sa1 = backend._fault_value_scale()

    def one(params, key, sc, dd):
        return sample_fault_state(params, key, fspec, sa1_value=sa1,
                                  rate_scale=sc, dead=dd)

    return jax.vmap(one)(stacked_params, fkeys, scale, dead)
