"""Fault tolerance: preemption handling, failure detection, stragglers.

On a real fleet these hook SIGTERM (preemption notice), per-step
all-reduce health checks, and the coordinator's slow-worker detector.
Here the mechanisms are implemented host-side and driven by the trainer;
tests inject failures deterministically.

  * PreemptionGuard — converts SIGTERM/SIGINT into a "checkpoint at the
    next step boundary, then exit cleanly" request (no torn steps).
  * HealthMonitor   — step-duration EWMA; a step slower than
    ``straggler_factor``× the EWMA flags a straggler (on TPU fleets the
    remedy is re-sharding around the slow host; here we surface the event
    and the trainer records it).
  * retry           — bounded-retry wrapper for transient infra errors.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:           # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class HealthMonitor:
    def __init__(self, straggler_factor: float = 3.0, ewma: float = 0.9):
        self.factor = straggler_factor
        self.ewma_coef = ewma
        self.mean_step_s: Optional[float] = None
        self.straggler_events: list[tuple[int, float]] = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is a straggler.

        Flagged steps are excluded from the EWMA: folding a straggler's
        duration into the very baseline it was judged against inflates
        the mean, so a run of moderate stragglers would progressively
        raise the bar and mask later ones.
        """
        is_straggler = (self.mean_step_s is not None
                        and duration_s > self.factor * self.mean_step_s)
        if is_straggler:
            self.straggler_events.append((step, duration_s))
            return True
        if self.mean_step_s is None:
            self.mean_step_s = duration_s
        else:
            self.mean_step_s = (self.ewma_coef * self.mean_step_s
                                + (1 - self.ewma_coef) * duration_s)
        return is_straggler


def retry(fn: Callable, attempts: int = 3, backoff_s: float = 0.1,
          retriable=(OSError, RuntimeError)):
    """Bounded retry for transient failures (I/O, collectives timeouts)."""
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    for i in range(attempts):
        try:
            return fn()
        except retriable as e:
            last = e
            if i + 1 >= attempts:
                break               # exhausted: re-raise without sleeping
            time.sleep(backoff_s * (2 ** i))
    raise last
