"""The training loop: gradient accumulation, checkpoint/restart, fault
tolerance, logging. Mesh-agnostic: pass shardings for a production mesh
or nothing for single-device runs (tests, examples).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import optim as optim_mod
from repro.configs.base import ModelConfig
from repro.data.pipeline import ShardedBatcher
from repro.models import lm
from repro.train.checkpoint import CheckpointManager
from repro.train.faults import HealthMonitor, PreemptionGuard
from repro.utils import tree_size

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    grad_accum: int = 1
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    seed: int = 0
    # Paper-derived options:
    kwta_grad_keep: Optional[float] = None    # ζ sparsification
    grad_compression_keep: Optional[float] = None  # cross-pod top-k + EF


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 batcher: ShardedBatcher,
                 params: Optional[PyTree] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.batcher = batcher
        # Resolve the quantized execution mode through the device-backend
        # registry up front: an unknown name fails here, not mid-trace.
        # (models/layers.dense builds the actual inference-specced backend.)
        if cfg.quant_mode != "none":
            from repro.backends import get_backend
            get_backend(cfg.quant_mode)
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = params if params is not None \
            else lm.init_params(key, cfg)

        schedule = optim_mod.warmup_cosine(tcfg.lr, tcfg.warmup_steps,
                                           tcfg.steps)
        opt = optim_mod.adamw(schedule, weight_decay=tcfg.weight_decay,
                              max_grad_norm=tcfg.max_grad_norm)
        if tcfg.kwta_grad_keep is not None:
            opt = optim_mod.kwta_sparsify(opt, tcfg.kwta_grad_keep)
        if tcfg.grad_compression_keep is not None:
            opt = optim_mod.topk_compress_error_feedback(
                opt, tcfg.grad_compression_keep)
        self.optimizer = opt
        self.opt_state = opt.init(self.params)

        self.step = 0
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints) \
            if tcfg.checkpoint_dir else None
        self.monitor = HealthMonitor()
        self.history: list[dict] = []
        self._jit_step = self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self) -> Callable:
        cfg = self.cfg
        accum = self.tcfg.grad_accum
        optimizer = self.optimizer

        def one_grad(params, batch):
            return jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, batch))(params)

        def train_step(params, opt_state, batch):
            if accum == 1:
                loss, grads = one_grad(params, batch)
            else:
                # Microbatch split along the batch axis.
                def micro(carry, mb):
                    loss_sum, g_sum = carry
                    l, g = one_grad(params, mb)
                    return (loss_sum + l,
                            jax.tree.map(jnp.add, g_sum, g)), None

                micro_batches = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:]), batch)
                zeros = jax.tree.map(jnp.zeros_like, params)
                (loss, grads), _ = jax.lax.scan(
                    micro, (jnp.zeros(()), zeros), micro_batches)
                loss = loss / accum
                grads = jax.tree.map(lambda g: g / accum, grads)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = optim_mod.apply_updates(params, updates)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(
                g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
            return params, opt_state, loss, gnorm

        return jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def maybe_restore(self) -> bool:
        """Auto-restore from the latest checkpoint (restart-after-failure
        path). Returns True if restored."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        step, tree, extra = self.ckpt.restore()
        self.params = _cast_tree(tree["params"], self.params)
        self.opt_state = _cast_tree(tree["opt"], self.opt_state)
        self.step = step
        if "data" in extra:
            self.batcher.load_state_dict(extra["data"])
        return True

    def save(self, async_: bool = True) -> None:
        if self.ckpt is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        extra = {"data": self.batcher.state_dict()}
        if async_:
            self.ckpt.save_async(self.step, tree, extra)
        else:
            self.ckpt.save(self.step, tree, extra)

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            guard: Optional[PreemptionGuard] = None) -> list[dict]:
        target = self.step + (steps if steps is not None
                              else self.tcfg.steps)
        while self.step < target:
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in
                     self.batcher.next().items()}
            self.params, self.opt_state, loss, gnorm = self._jit_step(
                self.params, self.opt_state, batch)
            loss = float(loss)
            dt = time.time() - t0
            self.step += 1
            straggler = self.monitor.record(self.step, dt)
            rec = {"step": self.step, "loss": loss,
                   "grad_norm": float(gnorm), "sec": round(dt, 4),
                   "straggler": straggler}
            self.history.append(rec)
            if self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d}  loss {loss:.4f}  "
                      f"gnorm {float(gnorm):.3f}  {dt*1e3:.0f} ms",
                      flush=True)
            if self.ckpt and self.step % self.tcfg.checkpoint_every == 0:
                self.save()
            if guard is not None and guard.requested:
                self.save(async_=False)
                print(f"preempted at step {self.step}; checkpoint saved",
                      flush=True)
                break
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    @property
    def n_params(self) -> int:
        return tree_size(self.params)


def _cast_tree(loaded: PyTree, like: PyTree) -> PyTree:
    """Match restored host arrays to the live tree's dtypes/structure."""
    flat_like, treedef = jax.tree.flatten(like)
    flat_loaded = jax.tree.leaves(loaded)
    assert len(flat_like) == len(flat_loaded), \
        (len(flat_like), len(flat_loaded))
    cast = [jnp.asarray(a, dtype=b.dtype)
            for a, b in zip(flat_loaded, flat_like)]
    return jax.tree.unflatten(treedef, cast)
