"""Training loop, checkpointing, fault tolerance."""
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainConfig

__all__ = ["CheckpointManager", "Trainer", "TrainConfig"]
