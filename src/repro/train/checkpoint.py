"""Sharded, atomic, mesh-elastic checkpointing.

Design for 1000+ nodes (DESIGN.md §6):
  * Step-atomic: write to ``step_N.tmp/``, fsync, rename to ``step_N/`` —
    a crash mid-save never corrupts the latest checkpoint.
  * Sharded: each host writes only the shards it owns (here: single
    process writes everything, but the layout is per-leaf files keyed by
    logical path, so multi-host writers don't contend).
  * Mesh-elastic: files store *logical* arrays + dtype + the PartitionSpec
    they were saved under. Restore re-shards onto whatever mesh the new
    job brings up — a 512-chip checkpoint restores onto 256 chips (or a
    differently-shaped mesh) without conversion.
  * Async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next
    training steps.
  * Self-describing: ``manifest.json`` records step, tree structure,
    data-pipeline state, and mesh metadata for audit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.analog.endurance import EnduranceTracker
from repro.utils import flatten_dict, unflatten_dict

PyTree = Any


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # Save
    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree,
             extra: Optional[dict] = None) -> Path:
        """Synchronous atomic save."""
        flat = self._to_host(tree)
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[dict] = None) -> None:
        """Snapshot now, write in the background."""
        self.wait()
        flat = self._to_host(tree)      # device→host copy happens here

        def work():
            self._write(step, flat, extra or {})

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _to_host(tree: PyTree) -> dict[str, np.ndarray]:
        flat = flatten_dict(_as_dict(tree))
        return {k: np.asarray(v) for k, v in flat.items()}

    def _write(self, step: int, flat: dict[str, np.ndarray],
               extra: dict) -> Path:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        arrays = tmp / "arrays.npz"
        np.savez(arrays, **{k.replace("/", "__"): v
                            for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        # fsync the directory entry before the atomic rename.
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    # Restore
    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in self.dir.glob("step_*"):
            if d.is_dir() and not d.name.endswith(".tmp"):
                try:
                    out.append(int(d.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[PyTree] = None
                ) -> tuple[int, dict, dict]:
        """Returns (step, tree, extra). With ``shardings`` (a pytree of
        NamedSharding matching the flat keys' structure) each leaf is
        device_put onto the *current* mesh — elastic restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        npz = np.load(d / "arrays.npz")
        flat = {k.replace("__", "/"): npz[k] for k in npz.files}
        tree = unflatten_dict(flat)
        if shardings is not None:
            shard_flat = flatten_dict(_as_dict(shardings))
            tree = unflatten_dict({
                k: jax.device_put(v, shard_flat[k]) if k in shard_flat
                else v for k, v in flat.items()})
        return manifest["step"], _revive(tree), manifest.get("extra", {})


def _as_dict(tree: PyTree) -> dict:
    """Convert NamedTuples / lists in a pytree to plain dicts for
    path-stable serialization. Stateful host-side objects that know how
    to serialize themselves (the endurance tracker — so lifetime
    projections survive restarts) are converted via ``state_dict`` and
    revived by :func:`_revive` on restore."""
    if isinstance(tree, EnduranceTracker):
        return _as_dict(tree.state_dict())
    if isinstance(tree, dict):
        return {str(k): _as_dict(v) for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {f: _as_dict(v) for f, v in zip(tree._fields, tree)}
    if isinstance(tree, (list, tuple)):
        return {str(i): _as_dict(v) for i, v in enumerate(tree)}
    return tree


def _revive(tree):
    """Inverse of the ``_as_dict`` type conversions: rebuild tagged
    subtrees (``_tree_type_`` sentinel) into their host-side objects."""
    if isinstance(tree, dict):
        tag = tree.get("_tree_type_")
        if tag is not None and str(np.asarray(tag)) == \
                EnduranceTracker.TYPE_TAG:
            return EnduranceTracker.from_state_dict(tree)
        return {k: _revive(v) for k, v in tree.items()}
    return tree
