"""repro.obs — time-resolved observability.

Pins the subsystem's contracts:

  * **disabled is absent, enabled is inert**: ``obs=None`` and an
    enabled ObsSpec produce bitwise-identical training results (R,
    params, losses) on both ``run_compiled`` and ``run_fleet`` — the
    streams are pure reads of values the step already computes;
  * **loop ≡ compiled streams**: integer streams (write pulses, replay
    occupancy, drift ticks) are bit-identical between ``run_continual``
    and ``run_compiled``; float streams (loss, Σ|ΔG|) agree to float32
    tolerance (XLA fuses the step differently inside the scan — same
    contract as the losses parity the scenario tests pin);
  * **streams sum exact**: the write-pulse series totals exactly to the
    aggregate ``write_pulses`` telemetry counter of the same run, and
    the drift-tick series to ``drift_ticks`` — on both the quantized
    and the drifting stateful substrate;
  * windowing/units of RunLog, Tracer span nesting + Chrome export,
    Histogram determinism, run-record schema, serve request stats.
"""
import json

import numpy as np
import pytest

from repro.backends import get_backend
from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
from repro.obs import (Histogram, JsonlSink, ObsSpec, RunLog,
                       RUN_RECORD_SCHEMA, Tracer, build_runlog,
                       drift_stream, run_record, sparkline, step_stats,
                       timeline)
from repro.scenarios import build_scenario, run_compiled
from repro.scenarios.sweep import scenario_miru_config


@pytest.fixture(scope="module")
def small_setup():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    return cfg, TrainerSpec(algo="dfa", epochs_per_task=1), tasks


def _total(tele, prefix):
    return sum(v for k, v in tele.snapshot().items()
               if k == prefix or k.startswith(prefix + "/"))


# ---------------------------------------------------------------------------
# ObsSpec / RunLog units
# ---------------------------------------------------------------------------

def test_obsspec_validates_cadence():
    assert ObsSpec().cadence == 1
    assert ObsSpec(cadence=7).metrics
    with pytest.raises(ValueError, match="cadence"):
        ObsSpec(cadence=0)
    with pytest.raises(ValueError, match="cadence"):
        ObsSpec(cadence=-3)


def test_runlog_windowing_partial_last_window():
    # 7 steps at cadence 3 → windows [0:3], [3:6], [6:7].
    loss = np.arange(7, dtype=np.float32)
    pulses = np.ones(7, dtype=np.int64)
    log = build_runlog(cadence=3, steps_per_task=[7], loss=loss,
                       write_pulses=pulses, dg_mag=loss,
                       replay_occupancy=np.arange(7),
                       drift_ticks=np.zeros(7, np.int64),
                       task_acc=np.ones((1, 1)))
    assert log.n_steps == 7 and log.n_windows == 3
    np.testing.assert_array_equal(log.steps, [0, 3, 6])
    # Counters window-sum; loss window-means; occupancy samples the
    # window start.
    np.testing.assert_array_equal(log.write_pulses, [3, 3, 1])
    np.testing.assert_array_equal(log.dg_mag, [3.0, 12.0, 6.0])
    np.testing.assert_allclose(log.loss, [1.0, 4.0, 6.0])
    np.testing.assert_array_equal(log.replay_occupancy, [0, 3, 6])
    assert log.total_write_pulses == 7


def test_runlog_empty_streams():
    log = build_runlog(cadence=5, steps_per_task=[],
                       loss=np.zeros(0, np.float32),
                       write_pulses=np.zeros(0, np.int64),
                       dg_mag=np.zeros(0, np.float32),
                       replay_occupancy=np.zeros(0, np.int64),
                       drift_ticks=np.zeros(0, np.int64),
                       task_acc=np.ones((0, 0)))
    assert log.n_windows == 0
    assert log.total_write_pulses == 0


def test_step_stats_matches_numpy_reference():
    import jax.numpy as jnp
    applied = {"w_h": jnp.asarray([[0.5, 0.0], [-0.25, 1.0]]),
               "b_h": jnp.asarray([1.0, 2.0]),        # ndim<2: excluded
               "w_o": jnp.zeros((2, 2))}
    rstate = {"size": jnp.asarray(17)}
    pulses, dg, occ = step_stats(applied, rstate)
    assert int(pulses) == 3                 # nonzeros of w_h + w_o
    np.testing.assert_allclose(float(dg), 1.75)
    assert int(occ) == 17
    pulses0, dg0, occ0 = step_stats({"w": jnp.zeros((2, 2))}, None)
    assert int(pulses0) == 0 and float(dg0) == 0.0 and int(occ0) == 0


def test_drift_stream_shapes():
    np.testing.assert_array_equal(drift_stream(4, drifting=True),
                                  [1, 1, 1, 1])
    np.testing.assert_array_equal(drift_stream(3, drifting=False),
                                  [0, 0, 0])


def test_forgetting_after_task_running_max():
    # Task-0 accuracy decays after training task 1 → forgetting 0.2.
    acc = np.array([[0.9, 0.1], [0.7, 0.8]])
    log = build_runlog(cadence=1, steps_per_task=[1, 1],
                       loss=np.zeros(2, np.float32),
                       write_pulses=np.zeros(2, np.int64),
                       dg_mag=np.zeros(2, np.float32),
                       replay_occupancy=np.zeros(2, np.int64),
                       drift_ticks=np.zeros(2, np.int64), task_acc=acc)
    f = log.forgetting_after_task()
    np.testing.assert_allclose(f, [0.0, 0.2], atol=1e-7)


def test_timeline_and_sparkline():
    log = build_runlog(cadence=2, steps_per_task=[4],
                       loss=np.linspace(1, 0, 4).astype(np.float32),
                       write_pulses=np.ones(4, np.int64),
                       dg_mag=np.ones(4, np.float32),
                       replay_occupancy=np.arange(4),
                       drift_ticks=np.zeros(4, np.int64),
                       task_acc=np.ones((1, 1)))
    tl = timeline(log)
    assert tl["total_write_pulses"] == 4
    assert len(tl["write_pulses"]) == log.n_windows
    s = sparkline([0.0, 0.5, 1.0])
    assert isinstance(s, str) and len(s) == 3
    assert sparkline([]) == ""
    d = log.as_dict(max_points=1)
    assert len(d["loss"]) == 1


# ---------------------------------------------------------------------------
# Bitwise neutrality + stream/counter exactness
# ---------------------------------------------------------------------------

def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a["R"]), np.asarray(b["R"]))
    assert a["losses"] == b["losses"]
    for k in a["params"]:
        np.testing.assert_array_equal(np.asarray(a["params"][k]),
                                      np.asarray(b["params"][k]))


def test_run_compiled_obs_is_bitwise_neutral(small_setup):
    cfg, trainer, tasks = small_setup
    base = run_compiled(cfg, trainer, tasks, replay=ReplaySpec(capacity=32),
                        device="ideal")
    res = run_compiled(cfg, trainer, tasks, replay=ReplaySpec(capacity=32),
                       device="ideal", obs=ObsSpec(cadence=2))
    _assert_bitwise(base, res)
    assert "runlog" not in base
    log = res["runlog"]
    assert isinstance(log, RunLog)
    assert log.n_steps == 2 * len(base["losses"]) // 2  # total steps
    assert log.task_acc.shape == (2, 2)


def test_run_fleet_obs_is_bitwise_neutral(small_setup):
    from repro.fleet import FleetSpec, run_fleet
    cfg, trainer, tasks = small_setup
    fleet = FleetSpec(n_devices=2, het_profile="none")
    base = run_fleet(cfg, trainer, tasks, fleet, device="ideal")
    res = run_fleet(cfg, trainer, tasks, fleet, device="ideal",
                    obs=ObsSpec(cadence=2))
    for i in range(2):
        np.testing.assert_array_equal(
            np.asarray(base["per_device"][i]["R_full"]),
            np.asarray(res["per_device"][i]["R_full"]))
        assert base["per_device"][i]["losses"] == \
            res["per_device"][i]["losses"]
    # Per-chip leading axis on every stream.
    log = res["runlog"]
    assert log.write_pulses.shape[0] == 2
    assert log.loss.shape[0] == 2
    assert log.task_acc.shape == (2, 2, 2)
    assert "runlog" not in base


def test_loop_vs_compiled_runlog_parity(small_setup):
    cfg, trainer, tasks = small_setup
    obs = ObsSpec(cadence=3)
    lres = run_continual(cfg, trainer, tasks,
                         replay=ReplaySpec(capacity=32), device="ideal",
                         obs=obs)
    cres = run_compiled(cfg, trainer, tasks,
                        replay=ReplaySpec(capacity=32), device="ideal",
                        obs=obs)
    ll, cl = lres["runlog"], cres["runlog"]
    assert ll.n_steps == cl.n_steps and ll.cadence == cl.cadence
    # Integer streams: bit-identical between the Python loop and the
    # scan-over-tasks program.
    np.testing.assert_array_equal(ll.write_pulses, cl.write_pulses)
    np.testing.assert_array_equal(ll.replay_occupancy,
                                  cl.replay_occupancy)
    np.testing.assert_array_equal(ll.drift_ticks, cl.drift_ticks)
    # Float streams: same contract as losses parity — float32 tolerance
    # (XLA fuses the step differently inside the scan).
    np.testing.assert_allclose(ll.loss, cl.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ll.dg_mag, cl.dg_mag, rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("backend_name", ["wbs", "analog_state"])
def test_write_stream_sums_to_counter(small_setup, backend_name):
    from repro.analog.crossbar import CrossbarSpec
    from repro.backends import DeviceSpec
    cfg, trainer, tasks = small_setup
    if backend_name == "analog_state":
        # A drifting stateful substrate (default drift_rate is 0).
        spec = CrossbarSpec(write_sigma=0.0, prog_sigma=0.0,
                            read_sigma=0.0, drift_rate=0.05, w_clip=1.0)
        backend = get_backend("analog_state",
                              spec=DeviceSpec(input_bits=8, adc_bits=8,
                                              weight_clip=1.0,
                                              crossbar=spec))
    else:
        backend = get_backend(backend_name)
    backend.telemetry.enable()
    try:
        res = run_compiled(cfg, trainer, tasks,
                           replay=ReplaySpec(capacity=32), device=backend,
                           obs=ObsSpec(cadence=4))
        log = res["runlog"]
        assert log.total_write_pulses == _total(backend.telemetry,
                                                "write_pulses")
        assert log.total_write_pulses > 0
        if backend_name == "analog_state":
            # The stateful analog substrate drifts: one tick per applied
            # update, and the unit-ramp stream totals to the counter.
            assert log.total_drift_ticks == _total(backend.telemetry,
                                                   "drift_ticks")
            assert log.total_drift_ticks == log.n_steps
    finally:
        backend.telemetry.disable()


def test_ingraph_occupancy_stream(small_setup):
    cfg, trainer, tasks = small_setup
    res = run_compiled(cfg, trainer, tasks,
                       replay=ReplaySpec(capacity=16, policy="loss_aware"),
                       device="ideal", obs=ObsSpec(cadence=1))
    occ = res["runlog"].replay_occupancy
    # Device-resident buffer: occupancy is read in-scan — it never
    # exceeds capacity and is monotone nondecreasing.
    assert occ.max() <= 16
    assert np.all(np.diff(occ) >= 0)
    assert occ[-1] > 0


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_nesting_summary_and_export(tmp_path):
    tr = Tracer(process_name="t")
    with tr.span("outer", tag=1):
        with tr.span("inner"):
            pass
        tr.instant("mark")
    tr.counter("queue", depth=3)
    evs = tr.events()
    names = [e["name"] for e in evs]
    assert "outer" in names and "inner" in names and "mark" in names
    summ = tr.summary()
    # inner's time is contained in outer's: top-level totals don't
    # double-count.
    assert summ["outer"]["total_s"] >= summ["inner"]["total_s"]
    p = tr.export_chrome(tmp_path / "trace.json")
    data = json.loads(p.read_text())
    assert isinstance(data["traceEvents"], list)
    x = [e for e in data["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in x} >= {"outer", "inner"}
    for e in x:
        assert e["ts"] >= 0 and e["dur"] >= 0


def test_tracer_span_exception_still_closes():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert any(e["name"] == "boom" for e in tr.events())


def test_run_compiled_tracer_spans(small_setup):
    cfg, trainer, tasks = small_setup
    tr = Tracer()
    res = run_compiled(cfg, trainer, tasks,
                       replay=ReplaySpec(capacity=32), device="ideal",
                       obs=ObsSpec(cadence=2, tracer=tr))
    names = {e["name"] for e in tr.events()}
    assert {"schedule", "compile", "execute"} <= names
    assert res["compile_s"] > 0 and res["execute_s"] > 0
    # AOT separation: the compile span dominates this tiny run.
    summ = tr.summary()
    assert summ["compile"]["total_s"] > summ["execute"]["total_s"]


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------

def test_histogram_small_exact():
    h = Histogram()
    h.extend([5.0, 1.0, 3.0])
    np.testing.assert_allclose(h.mean, 3.0)
    np.testing.assert_allclose(h.percentile(50), 3.0)
    s = h.summary()
    assert {"count", "mean", "p50", "p95", "p99", "min",
            "max"} <= set(s)
    assert s["count"] == 3
    assert s["min"] == 1.0 and s["max"] == 5.0


def test_histogram_reservoir_deterministic():
    h1, h2 = Histogram(max_samples=64), Histogram(max_samples=64)
    vals = [float(i % 97) for i in range(1000)]
    h1.extend(vals)
    h2.extend(vals)
    assert h1.summary()["count"] == h2.summary()["count"] == 1000
    assert h1.percentile(99) == h2.percentile(99)
    assert h1.mean == h2.mean            # mean is exact, not sampled
    assert Histogram().summary()["count"] == 0


# ---------------------------------------------------------------------------
# Sinks / run records
# ---------------------------------------------------------------------------

def test_run_record_schema_and_jsonl_roundtrip(tmp_path):
    rec = run_record("run", "unit", {"MA": 0.9},
                     gates={"ok": True}, counters={"macs/w_h": 4},
                     timeline={"loss": [1.0]}, extra={"note": "t"})
    assert rec["schema"] == RUN_RECORD_SCHEMA
    assert rec["kind"] == "run" and rec["name"] == "unit"
    assert "ts" in rec and "jax" in rec
    sink = JsonlSink(tmp_path / "sub" / "h.jsonl")   # dir auto-created
    p = sink.emit(rec)
    p2 = sink.emit(run_record("run", "unit", {"MA": 0.8}))
    assert p == p2
    rows = sink.read()
    assert len(rows) == 2
    assert rows[0]["metrics"]["MA"] == 0.9
    assert rows[1]["metrics"]["MA"] == 0.8


def test_bench_history_append(tmp_path, monkeypatch):
    import benchmarks.common as bc
    monkeypatch.setattr(bc, "HISTORY", tmp_path / "history")
    p = bc.append_history("unit_bench", {"us": 1.5},
                          gates={"g": True})
    rows = [json.loads(l) for l in p.read_text().splitlines()]
    assert rows[0]["kind"] == "bench"
    assert rows[0]["gates"] == {"g": True}


# ---------------------------------------------------------------------------
# Serve request stats
# ---------------------------------------------------------------------------

def test_serve_request_stats_latency_and_energy():
    from repro.analog.costmodel import M2RUCostModel
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve import ServeConfig, ServeEngine
    import jax

    cfg = get_smoke_config("qwen2-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tr = Tracer()
    eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=32,
                                       eos_token=-1, device="wbs",
                                       meter=True, tracer=tr), params)
    for _ in range(3):
        eng.submit([1, 2, 3], max_new=4)
    eng.run_until_drained()
    stats = eng.request_stats(model=M2RUCostModel())
    assert stats["requests"] == 3
    assert stats["latency_ms"]["count"] == 3
    assert stats["latency_ms"]["p99"] >= stats["latency_ms"]["p50"] > 0
    assert stats["sequences_per_s"] > 0
    assert stats["tokens_generated"] == 12
    en = stats["energy"]
    assert en["total_j"] > 0
    assert en["pj_per_request"]["count"] == 3
    assert en["pj_per_request"]["p50"] > 0
    names = {e["name"] for e in tr.events()}
    assert {"serve.prefill", "serve.step"} <= names
    eng.backend.telemetry.disable()
