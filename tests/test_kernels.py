"""Per-kernel sweeps: Pallas (interpret mode on CPU) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# WBS matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (33, 70, 19), (128, 128, 128),
                                   (130, 257, 64), (1, 5, 300)])
@pytest.mark.parametrize("n_bits", [4, 8])
def test_wbs_matmul_shapes(m, k, n, n_bits):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + k + n))
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = jax.random.normal(kw, (k, n))
    sign, code = ops.quantize_inputs(x, n_bits)
    gains = 2.0 ** (-jnp.arange(1, n_bits + 1, dtype=jnp.float32))
    got = ops.wbs_matmul(sign, code, w, gains)
    want = ref.wbs_matmul_ref(sign, code, w, gains)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_wbs_matmul_read_sigma_zero_parity():
    """The read-noise plumbing must be a bit-exact no-op at sigma=0 —
    same kernel code path, no PRNG touched."""
    x = jax.random.uniform(jax.random.PRNGKey(0), (16, 24),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (24, 8))
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    base = ops.wbs_matmul(sign, code, w, gains)
    noised = ops.wbs_matmul(sign, code, w, gains, read_sigma=0.0,
                            read_key=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(noised))


def test_wbs_matmul_read_sigma_requires_key():
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 8),
                           minval=-1, maxval=1)
    w = jnp.ones((8, 4))
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    with pytest.raises(ValueError, match="read_key"):
        ops.wbs_matmul(sign, code, w, gains, read_sigma=0.1)


def test_wbs_dense_read_sigma_perturbs_unbiased():
    """Per-access read noise (jnp fallback on CPU): output differs per
    key, is mean-preserving, and scales with sigma."""
    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 32),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.2
    clean = ops.wbs_dense(x, w, adc_bits=None)
    ys = np.stack([
        np.asarray(ops.wbs_dense(x, w, adc_bits=None, read_sigma=0.1,
                                 read_key=jax.random.PRNGKey(10 + i)))
        for i in range(32)])
    assert not np.array_equal(ys[0], ys[1])             # fresh draw per key
    np.testing.assert_allclose(ys.mean(0), np.asarray(clean),
                               atol=0.05)               # zero-mean noise
    spread = ys.std(0).mean()
    assert spread > 1e-4


@pytest.mark.parametrize("w_dtype", [jnp.float32, jnp.bfloat16])
def test_wbs_matmul_dtypes(w_dtype):
    x = jax.random.uniform(jax.random.PRNGKey(0), (32, 48),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 24)).astype(w_dtype)
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    got = ops.wbs_matmul(sign, code, w, gains)
    want = ref.wbs_matmul_ref(sign, code, w, gains)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    assert got.dtype == jnp.float32


def test_wbs_matmul_adc():
    x = jax.random.uniform(jax.random.PRNGKey(0), (16, 32),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.2
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    got = ops.wbs_matmul(sign, code, w, gains, adc_bits=8, adc_range=4.0)
    want = ref.wbs_matmul_ref(sign, code, w, gains, adc_bits=8,
                              adc_range=4.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Output lands on the ADC grid.
    step = 2 * 4.0 / 256
    np.testing.assert_allclose(got / step, np.round(got / step), atol=1e-4)


def test_wbs_approximates_float_matmul():
    """Ideal gains ⇒ WBS == fixed-point matmul; error bounded by input
    quantization (the paper's ≤5 % VMM error claim at 4-bit, Fig. 5a)."""
    x = jax.random.uniform(jax.random.PRNGKey(2), (64, 100),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(3), (100, 32))
    exact = x @ w
    for n_bits, tol in [(8, 0.01), (4, 0.10)]:
        y = ops.wbs_dense(x, w, n_bits=n_bits, adc_bits=None)
        rel = float(jnp.abs(y - exact).max() / jnp.abs(exact).max())
        assert rel < tol, (n_bits, rel)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 60), st.integers(1, 30))
def test_wbs_matmul_property(m, k, n):
    kx = jax.random.PRNGKey(m + 100 * k + 10000 * n)
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = jax.random.normal(kx, (k, n))
    sign, code = ops.quantize_inputs(x, 6)
    gains = 2.0 ** (-jnp.arange(1, 7, dtype=jnp.float32))
    got = ops.wbs_matmul(sign, code, w, gains)
    want = ref.wbs_matmul_ref(sign, code, w, gains)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MiRU fused recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h", [(1, 1, 8), (4, 28, 100), (8, 16, 128),
                                   (3, 5, 200), (16, 32, 64)])
def test_miru_scan_shapes(b, t, h):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b + t + h), 3)
    xw = jax.random.normal(k1, (b, t, h))
    u = jax.random.normal(k2, (h, h)) * 0.3
    h0 = jax.random.normal(k3, (b, h)) * 0.5
    got_h, got_p = ops.miru_scan(xw, u, h0, beta=0.8, lam=0.5)
    want_h, want_p = ref.miru_scan_ref(xw, u, h0, beta=0.8, lam=0.5)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("beta,lam", [(1.0, 0.0), (0.5, 0.9), (0.05, 0.5)])
def test_miru_scan_coefficients(beta, lam):
    xw = jax.random.normal(jax.random.PRNGKey(0), (4, 10, 32))
    u = jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.3
    h0 = jnp.zeros((4, 32))
    got_h, _ = ops.miru_scan(xw, u, h0, beta=beta, lam=lam)
    want_h, _ = ref.miru_scan_ref(xw, u, h0, beta=beta, lam=lam)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-5, atol=1e-5)


def test_miru_scan_matches_cell_semantics():
    """Kernel == the core library's lax.scan forward (same recurrence)."""
    from repro.core.miru import MiRUConfig, init_miru_params, miru_forward
    cfg = MiRUConfig(n_x=12, n_h=48, n_y=5, beta=0.7, lam=0.4)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (6, 9, 12))
    logits_ref, aux_ref = miru_forward(params, cfg, x, use_fused=False)
    logits_fused, aux_fused = miru_forward(params, cfg, x, use_fused=True)
    np.testing.assert_allclose(logits_fused, logits_ref, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(aux_fused["h_all"], aux_ref["h_all"],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Flash attention forward (Pallas)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,h,dh,kh", [(16, 16, 2, 8, 2),
                                           (40, 40, 4, 16, 2),
                                           (128, 256, 2, 32, 1),
                                           (33, 65, 4, 16, 4)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_vs_full(sq, sk, h, dh, kh, causal):
    from repro.models.attention import full_attention
    ks = jax.random.split(jax.random.PRNGKey(sq + sk + h), 3)
    q = jax.random.normal(ks[0], (2, sq, h, dh))
    k = jax.random.normal(ks[1], (2, sk, kh, dh))
    v = jax.random.normal(ks[2], (2, sk, kh, dh))
    if causal and sk != sq:
        pytest.skip("causal requires square here")
    want = full_attention(q, k, v, causal)
    got, lse = ops.flash_attention_fwd(q, k, v, causal, bq=16, bk=16)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    assert lse.shape == (2, h, sq)
    assert bool(jnp.isfinite(lse).all())


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,sk", [(32, 32), (64, 128)])
def test_flash_bwd_kernels_vs_autodiff(causal, sq, sk):
    """dq/dkv Pallas kernels == jax.grad through full attention."""
    from repro.kernels.flash_attention import (flash_attention_bwd_pallas,
                                               flash_attention_fwd_pallas)
    from repro.models.attention import full_attention
    if causal and sq != sk:
        pytest.skip("causal requires square")
    BH, dh = 3, 16
    ks = jax.random.split(jax.random.PRNGKey(sq + sk), 4)
    q = jax.random.normal(ks[0], (BH, sq, dh))
    k = jax.random.normal(ks[1], (BH, sk, dh))
    v = jax.random.normal(ks[2], (BH, sk, dh))
    do = jax.random.normal(ks[3], (BH, sq, dh))
    out, lse = flash_attention_fwd_pallas(q, k, v, causal=causal, bq=16,
                                          bk=16, interpret=True)
    dq, dk, dv = flash_attention_bwd_pallas(q, k, v, out, lse, do,
                                            causal=causal, bq=16, bk=16,
                                            interpret=True)

    def f(q_, k_, v_):
        o = full_attention(q_[:, :, None, :], k_[:, :, None, :],
                           v_[:, :, None, :], causal)
        return jnp.sum(o[:, :, 0, :] * do)

    want = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for got, ref_g in zip((dq, dk, dv), want):
        np.testing.assert_allclose(got, ref_g, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("h,kh", [(4, 2), (8, 2), (4, 1)])
def test_flash_fwd_gqa_index_map_vs_repeat(h, kh):
    """GQA KV sharing folded into the BlockSpec index map must equal the
    old jnp.repeat route through the same kernel — bit-for-bit (same
    blocks, same math, no rep× HBM materialization)."""
    ks = jax.random.split(jax.random.PRNGKey(h * 10 + kh), 3)
    q = jax.random.normal(ks[0], (2, 32, h, 16))
    k = jax.random.normal(ks[1], (2, 32, kh, 16))
    v = jax.random.normal(ks[2], (2, 32, kh, 16))
    got, lse = ops.flash_attention_fwd(q, k, v, True, bq=16, bk=16)
    rep = h // kh
    got_rep, lse_rep = ops.flash_attention_fwd(
        q, jnp.repeat(k, rep, axis=2), jnp.repeat(v, rep, axis=2),
        True, bq=16, bk=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got_rep))
    np.testing.assert_array_equal(np.asarray(lse), np.asarray(lse_rep))


def test_flash_fwd_gqa_rejects_non_divisible_heads():
    """6 query heads over 4 KV heads has no uniform sharing — must fail
    loudly (the old repeat path raised at reshape; the index-map fold
    keeps an explicit guard)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 32, 6, 16))
    k = jax.random.normal(ks[1], (2, 32, 4, 16))
    v = jax.random.normal(ks[2], (2, 32, 4, 16))
    with pytest.raises(AssertionError):
        ops.flash_attention_fwd(q, k, v, True, bq=16, bk=16)


def test_flash_fwd_dtypes():
    from repro.models.attention import full_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 32, 2, 16)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 32, 2, 16)).astype(jnp.bfloat16)
    want = full_attention(q, k, v, True)
    got, _ = ops.flash_attention_fwd(q, k, v, True, bq=16, bk=16)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=3e-2,
                               atol=3e-2)
    assert got.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# k-WTA
# ---------------------------------------------------------------------------

def _separated(key, r, n):
    """Rows with well-separated distinct magnitudes (no bisection ties)."""
    base = jnp.linspace(0.1, 10.0, n)
    perm = jax.vmap(lambda k: jax.random.permutation(k, base))(
        jax.random.split(key, r))
    signs = jnp.where(
        jax.random.bernoulli(key, 0.5, (r, n)), 1.0, -1.0)
    return perm * signs


@pytest.mark.parametrize("r,n,k", [(1, 16, 4), (8, 100, 57), (5, 333, 1),
                                   (16, 64, 63), (3, 128, 128)])
def test_kwta_exact_on_separated(r, n, k):
    x = _separated(jax.random.PRNGKey(r * n + k), r, n)
    got = ops.kwta(x, k)
    want = ref.kwta_ref(x, k)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    if k < n:
        assert (np.count_nonzero(np.asarray(got), axis=1) == k).all()


def test_kwta_1d_and_preserves_values():
    x = _separated(jax.random.PRNGKey(0), 1, 50)[0]
    y = ops.kwta(x, 7)
    nz = np.nonzero(np.asarray(y))[0]
    assert len(nz) == 7
    np.testing.assert_array_equal(np.asarray(y)[nz], np.asarray(x)[nz])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(2, 200), st.data())
def test_kwta_property(r, n, data):
    k = data.draw(st.integers(1, n))
    x = _separated(jax.random.PRNGKey(r * 7919 + n), r, n)
    got = ops.kwta(x, k)
    # Winners are the top-k magnitudes; nonzeros preserved from input.
    mag = np.abs(np.asarray(x))
    got_np = np.asarray(got)
    for row in range(r):
        nz = np.nonzero(got_np[row])[0]
        assert len(nz) == min(k, n)
        kth = np.sort(mag[row])[-k]
        assert (mag[row][nz] >= kth - 1e-6).all()


def test_kwta_core_vs_kernel():
    """core.kwta (exact jnp) and the kernel agree on separated inputs."""
    from repro.core.kwta import kwta as core_kwta
    x = _separated(jax.random.PRNGKey(5), 4, 80)
    np.testing.assert_allclose(ops.kwta(x, 20),
                               core_kwta(x, k=20, axis=-1), atol=0)
