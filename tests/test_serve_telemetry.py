"""ServeEngine telemetry: submit→drain counters match hand-computed
VMM/readout counts (closes the PR-2 "metered serving path" gap at the
counter layer).

The decode path runs the layer stack under ``lax.scan``, so per-trace
meter deltas must be multiplied by the layer count
(``models/blocks._quant_scope``); these tests hand-compute the expected
totals from the model config and the engine's execution protocol and
would catch both a missing scale scope (n_layers× undercount) and a
double-flush (overcount).
"""
import jax
import numpy as np
import pytest

from repro.backends import register_backend, unregister_backend
from repro.backends.wbs import WBSBackend
from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine

NAME = "wbs_serve_meter_test"


@pytest.fixture
def quant_backend():
    # A private registry name so the shared per-name inference instance —
    # and its telemetry accumulator — is isolated from other tests.
    register_backend(NAME, WBSBackend)
    from repro.backends import inference_backend
    yield inference_backend(NAME)
    unregister_backend(NAME)


def _engine(slots: int, max_len: int = 32):
    cfg = get_smoke_config("qwen2-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(batch_slots=slots, max_len=max_len, eos_token=-1,
                       device=NAME, meter=True)
    return ServeEngine(cfg, scfg, params), cfg


def _per_execution(cfg, slots: int) -> dict:
    """Hand-computed per-decode-step counts: every quantized projection in
    one token step. qwen2 smoke is a dense GQA stack — per layer the
    quantized denses are wq, wk, wv, wo and the SwiGLU gate/up/down; the
    tied lm_head is an (unquantized) embedding einsum. Idle slots stream
    pad tokens — rows = batch_slots (physically accurate: the crossbar
    evaluates every wordline group driven, occupied or not)."""
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.hd()
    q, kv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    denses = [(D, q), (D, kv), (D, kv), (q, D), (D, F), (D, F), (F, D)]
    rows = slots  # (B, 1) token slab → B rows per projection
    L = cfg.n_layers
    input_bits = 8  # registry inference spec
    return {
        "macs": rows * L * sum(i * o for i, o in denses),
        "vmm_rows": rows * L * len(denses),
        "bit_pulses": rows * L * input_bits * sum(i for i, _ in denses),
        "wbs_phases": rows * L * input_bits * len(denses),
    }


def _drain(eng):
    eng.run_until_drained()
    jax.effects_barrier()


def test_counters_match_hand_computed(quant_backend):
    slots = 2
    eng, cfg = _engine(slots)
    tele = eng.telemetry
    assert tele is quant_backend.telemetry
    tele.reset()

    req = eng.submit([1, 2, 3], max_new=4)   # prompt 3 → 2 prefill steps
    _drain(eng)
    assert req.done and len(req.tokens) == 4

    # Executions: prefill = len(prompt) − 1 = 2, decode = max_new = 4.
    executions = 2 + 4
    per = _per_execution(cfg, slots)
    snap = tele.snapshot()
    assert snap["macs/dense"] == executions * per["macs"]
    assert snap["vmm_rows/dense"] == executions * per["vmm_rows"]
    assert snap["bit_pulses/dense"] == executions * per["bit_pulses"]
    assert snap["wbs_phases/dense"] == executions * per["wbs_phases"]
    # Inference spec has no readout ADC → no conversions metered.
    assert tele.total("adc_conversions") == 0


def test_counters_scale_with_workload(quant_backend):
    """Doubling the drained workload exactly doubles every counter —
    the per-execution flush fires once per compiled step, no more."""
    eng, _ = _engine(slots=2)
    tele = eng.telemetry
    tele.reset()
    eng.submit([1, 2, 3], max_new=4)
    _drain(eng)
    first = tele.snapshot()
    assert first["macs/dense"] > 0

    eng.submit([1, 2, 3], max_new=4)
    _drain(eng)
    second = tele.snapshot()
    for k, v in first.items():
        assert second[k] == 2 * v, (k, v, second[k])


def test_unmetered_engine_counts_nothing(quant_backend):
    cfg = get_smoke_config("qwen2-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    quant_backend.telemetry.reset()
    quant_backend.telemetry.disable()
    eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=32,
                                       eos_token=-1, device=NAME),
                      params)
    eng.submit([1, 2], max_new=3)
    _drain(eng)
    assert eng.telemetry.snapshot() == {}
