"""Optional-hypothesis shim for the property-based tests.

hypothesis is a test-only dependency (pip install .[test]). Where it is
absent the suite must still *run* the property tests, so this module
provides a miniature deterministic property runner with the same calling
convention: ``@settings(max_examples=N) @given(st.integers(...), ...)``.
The fallback draws a fixed number of pseudo-random examples per test
(seeded from the test name — reproducible across runs and processes),
always including the strategy bounds first, and supports the strategy
subset the suite uses: ``integers``, ``floats``, ``sampled_from``,
``booleans`` and ``data()`` (with ``data.draw``). With hypothesis
installed, the real library (shrinking, edge-case database) is used
unchanged. Import ``given``, ``settings``, ``st`` from here instead of
from hypothesis directly.
"""
import functools
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _Strategy:
        """A draw function plus the boundary examples tried first."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self.edges = tuple(edges)

        def draw(self, rng, example_idx):
            if example_idx < len(self.edges):
                return self.edges[example_idx]
            return self._draw(rng)

    class _DataMarker:
        """Stands in for ``st.data()``."""

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.draw(self._rng, len(strategy.edges))

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: int(r.integers(min_value, max_value + 1)),
                edges=(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda r: float(r.uniform(min_value, max_value)),
                edges=(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: seq[int(r.integers(len(seq)))],
                             edges=(seq[0],))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(2)),
                             edges=(False, True))

        @staticmethod
        def data():
            return _DataMarker()

    st = _St()

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 12)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng(
                        np.random.SeedSequence([base, i]))
                    pos = [(_Data(rng) if isinstance(s, _DataMarker)
                            else s.draw(rng, i)) for s in strategies]
                    kw = {k: (_Data(rng) if isinstance(s, _DataMarker)
                              else s.draw(rng, i))
                          for k, s in kw_strategies.items()}
                    fn(*args, *pos, **kwargs, **kw)
            # pytest follows __wrapped__ to the original signature and
            # would treat the strategy parameters as fixtures; the
            # wrapper's own (*args, **kwargs) signature requests none.
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples: int = 12, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
