"""Optional-hypothesis shim for the property-based tests.

hypothesis is a test-only dependency (pip install .[test]); where it is
absent the suite must degrade gracefully — the fixed-shape tests keep
running and only the @given sweeps are skipped. Import ``given``,
``settings``, ``st`` from here instead of from hypothesis directly.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    settings = given

    class _AnyStrategy:
        """Stands in for hypothesis.strategies: every strategy call returns
        None — fine, since the test is skip-marked before setup."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
