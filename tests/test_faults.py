"""repro.faults — device-fault injection and graceful degradation.

Pins the subsystem's contracts:

  * zero-fault parity: ``DeviceSpec.faults=None`` builds the exact
    program it always did, and a zero-rate :class:`FaultSpec` is
    bitwise identity end to end (run_compiled included);
  * fused-vs-per-step recurrence stays bitwise identical *under*
    faults (both paths read the same masked weight tensor);
  * stuck cells reject writes (no parameter motion, no endurance
    pulses) and transient read upsets are keyed, deterministic, and
    force the per-step path;
  * wear-out converts cells to stuck mid-run, monotonically;
  * the mitigation stack: march self-test recovers the exact stuck
    map, column remap strictly reduces effective damage, bias
    compensation touches only ``b_h``, recalibration learns around
    the masks;
  * fleet propagation: per-chip severity draws, dead chips at chance
    accuracy, and the ``faults`` aggregate section.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import DeviceSpec, get_backend
from repro.core.continual import ReplaySpec, TrainerSpec
from repro.core.miru import MiRUConfig, init_miru_params
from repro.faults import (FaultSpec, apply_cell_faults, compensate_bias,
                          effective_masks, fault_state, march_recover,
                          recalibrate, remap_columns, sample_fault_state,
                          stuck_fraction)
from repro.fleet import (FleetSpec, draw_fleet_faults, fleet_aggregate,
                         run_fleet)
from repro.scenarios import build_scenario, run_compiled
from repro.scenarios.sweep import scenario_miru_config

CFG = MiRUConfig(n_x=8, n_h=20, n_y=4)
WBS = dict(input_bits=8, adc_bits=8, weight_clip=1.0)
FAULTY = FaultSpec(sa0_rate=0.03, sa1_rate=0.01, dead_row_rate=0.02,
                   dead_col_rate=0.02)


@pytest.fixture(scope="module")
def params():
    return init_miru_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def x_seq():
    return jax.random.normal(jax.random.PRNGKey(1), (2, 12, CFG.n_x))


def _wbs(faults=None):
    return get_backend("wbs", spec=DeviceSpec(**WBS, faults=faults))


def _recur(backend, params, x, state, *, fused=None, seed=3):
    h, hp, pre = backend.device_recurrence(
        params, CFG, x, jax.random.PRNGKey(seed), state=state, fused=fused)
    return np.asarray(h)


# ---------------------------------------------------------------------------
# Zero-fault parity
# ---------------------------------------------------------------------------

def test_faults_none_builds_no_state(params):
    be = _wbs()
    assert be.spec.faults is None
    assert be.init_device_state(params, jax.random.PRNGKey(0)) is None


def test_zero_rate_spec_is_bitwise_identity(params, x_seq):
    """All-zero rates sample all-False masks; the masked recurrence is
    bitwise the unfaulted one, fused and per-step."""
    base = _wbs()
    zb = _wbs(FaultSpec())
    zs = zb.init_device_state(params, jax.random.PRNGKey(0))
    assert set(zs) == {"_faults"}
    for fused in (None, False):
        np.testing.assert_array_equal(
            _recur(base, params, x_seq, None, fused=fused),
            _recur(zb, params, x_seq, zs, fused=fused))


def test_zero_rate_run_compiled_parity():
    """End to end through run_compiled — forward, update, scan carry —
    a zero-rate FaultSpec changes no bit of the training run."""
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=20)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1)
    kw = dict(replay=ReplaySpec(capacity=32))
    r0 = run_compiled(cfg, tr, tasks, device=_wbs(), **kw)
    r1 = run_compiled(cfg, tr, tasks, device=_wbs(FaultSpec()), **kw)
    np.testing.assert_array_equal(r0["R_full"], r1["R_full"])
    for name, v in r0["params"].items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(r1["params"][name]), name)
    assert r0["metrics"] == r1["metrics"]


# ---------------------------------------------------------------------------
# Static masks
# ---------------------------------------------------------------------------

def test_masks_sampled_per_crossbar_param(params):
    fs = sample_fault_state(params, jax.random.PRNGKey(2), FAULTY)
    assert set(fs) == {n for n, p in params.items() if jnp.ndim(p) >= 2}
    for name, tile in fs.items():
        assert tile["stuck"].shape == params[name].shape
        assert tile["value"].dtype == jnp.float32
    assert 0.0 < stuck_fraction(fs) < 0.5
    # deterministic in the key
    fs2 = sample_fault_state(params, jax.random.PRNGKey(2), FAULTY)
    np.testing.assert_array_equal(np.asarray(fs["w_h"]["stuck"]),
                                  np.asarray(fs2["w_h"]["stuck"]))


def test_faults_change_forward_and_respect_mask(params, x_seq):
    """Stuck cells actually bite, and the masked weights are exactly
    what both recurrence paths read (fused ≡ per-step under faults)."""
    be = _wbs(FAULTY)
    st = be.init_device_state(params, jax.random.PRNGKey(5))
    clean = _recur(_wbs(), params, x_seq, None)
    h_fused = _recur(be, params, x_seq, st, fused=None)
    h_step = _recur(be, params, x_seq, st, fused=False)
    assert not np.array_equal(clean, h_fused), "masks must bite"
    np.testing.assert_array_equal(h_fused, h_step)


def test_analog_state_pairs_read_through_masks(params, x_seq):
    """The conductance-domain backend masks the differential-pair
    effective weights; zero-rate stays bitwise clean."""
    mk = lambda f: get_backend(
        "analog_state", spec=DeviceSpec(**WBS, faults=f))
    clean, faulty, zero = mk(None), mk(FAULTY), mk(FaultSpec())
    s0 = clean.init_device_state(params, jax.random.PRNGKey(4))
    sf = faulty.init_device_state(params, jax.random.PRNGKey(4))
    sz = zero.init_device_state(params, jax.random.PRNGKey(4))
    assert "_faults" in sf and "_faults" not in s0
    np.testing.assert_array_equal(_recur(clean, params, x_seq, s0),
                                  _recur(zero, params, x_seq, sz))
    assert not np.array_equal(_recur(clean, params, x_seq, s0),
                              _recur(faulty, params, x_seq, sf))


def test_stuck_cells_reject_writes(params):
    be = _wbs(FAULTY)
    st = be.init_device_state(params, jax.random.PRNGKey(5))
    ups = {n: jnp.full(p.shape, 0.05, p.dtype) for n, p in params.items()}
    new_p, applied, _ = be.device_apply_update(params, ups, state=st)
    for name, tile in st["_faults"].items():
        stuck = np.asarray(effective_masks(tile)[0])
        assert stuck.any()
        np.testing.assert_array_equal(
            np.asarray(applied[name])[stuck], 0.0, name)
        np.testing.assert_array_equal(
            np.asarray(new_p[name])[stuck],
            np.asarray(params[name])[stuck], name)


# ---------------------------------------------------------------------------
# Transient read upsets
# ---------------------------------------------------------------------------

def test_read_upsets_keyed_deterministic_and_unfused(params, x_seq):
    be = _wbs(FaultSpec(upset_rate=0.05))
    st = be.init_device_state(params, jax.random.PRNGKey(0))
    clean = _recur(_wbs(), params, x_seq, None)
    a = _recur(be, params, x_seq, st, fused=None)
    b = _recur(be, params, x_seq, st, fused=None)
    c = _recur(be, params, x_seq, st, fused=False)
    np.testing.assert_array_equal(a, b)       # keyed, reproducible
    np.testing.assert_array_equal(a, c)       # fusion silently declined
    assert not np.array_equal(a, clean)       # upsets bite
    assert not np.array_equal(
        a, _recur(be, params, x_seq, st, seed=4))   # per-key draws


# ---------------------------------------------------------------------------
# Endurance wear-out
# ---------------------------------------------------------------------------

def test_wearout_accumulates_and_sticks(params):
    be = _wbs(FaultSpec(wearout=True, wearout_endurance=3.0,
                        wearout_spread=0.2))
    st = be.init_device_state(params, jax.random.PRNGKey(1))
    ups = {n: jnp.full(p.shape, 0.05, p.dtype) for n, p in params.items()}
    p, fracs = params, []
    for _ in range(6):
        p, _, st = be.device_apply_update(p, ups, state=st)
        fracs.append(stuck_fraction(st["_faults"]))
    assert fracs == sorted(fracs), "stuck fraction must be monotone"
    assert fracs[0] == 0.0 and fracs[-1] > 0.9, fracs
    counts = np.asarray(st["_faults"]["w_h"]["wear_count"])
    stuck = np.asarray(st["_faults"]["w_h"]["stuck"])
    # counters freeze once a cell sticks (no pulses reach it)
    assert counts[stuck].max() <= 6.0


def test_wearout_freeze_mode_holds_last_value(params):
    be = _wbs(FaultSpec(wearout=True, wearout_endurance=1.0,
                        wearout_spread=0.0, wearout_mode="freeze"))
    st = be.init_device_state(params, jax.random.PRNGKey(1))
    ups = {n: jnp.full(p.shape, 0.05, p.dtype) for n, p in params.items()}
    p1, _, st = be.device_apply_update(params, ups, state=st)
    tile = st["_faults"]["w_h"]
    stuck = np.asarray(tile["stuck"])
    assert stuck.all()                         # endurance 1, no spread
    np.testing.assert_array_equal(np.asarray(tile["value"]),
                                  np.asarray(p1["w_h"], np.float32))


# ---------------------------------------------------------------------------
# Mitigation stack
# ---------------------------------------------------------------------------

def test_march_recovers_exact_stuck_map(params):
    fs = dataclasses.replace(FAULTY, n_spare_cols=3)
    be = _wbs(fs)
    st = be.init_device_state(params, jax.random.PRNGKey(7))
    rec = march_recover(be, params, st)
    for name, tile in st["_faults"].items():
        stuck, value = (np.asarray(a) for a in effective_masks(tile))
        np.testing.assert_array_equal(
            np.asarray(rec[name]["stuck"]), stuck, name)
        # recovered stuck values match to ADC quantization tolerance
        np.testing.assert_allclose(
            np.asarray(rec[name]["value"])[stuck], value[stuck],
            atol=2 / 255, err_msg=name)


def test_march_on_clean_device_finds_nothing(params):
    rec = march_recover(_wbs(), params, None)
    for name, r in rec.items():
        assert not np.asarray(r["stuck"]).any(), name


def test_remap_reduces_effective_damage(params):
    fs = dataclasses.replace(FAULTY, n_spare_cols=4)
    fstate = sample_fault_state(params, jax.random.PRNGKey(9), fs)
    remapped = remap_columns(fstate)
    improved = 0
    for name in fstate:
        before = int(np.asarray(effective_masks(fstate[name])[0]).sum())
        after = int(np.asarray(effective_masks(remapped[name])[0]).sum())
        assert after <= before, name
        improved += before - after
        cm = np.asarray(remapped[name]["colmap"])
        assert len(np.unique(cm)) == len(cm), "colmap must stay injective"
    assert improved > 0, "spares must absorb some damage"


def test_compensate_bias_touches_only_bias(params):
    fstate = sample_fault_state(params, jax.random.PRNGKey(9), FAULTY)
    drives = {"w_h": jnp.full((CFG.n_x,), 0.1),
              "u_h": jnp.full((CFG.n_h,), 0.05)}
    p2 = compensate_bias(params, fstate, drives)
    assert not np.array_equal(np.asarray(p2["b_h"]),
                              np.asarray(params["b_h"]))
    for k in params:
        if k != "b_h":
            np.testing.assert_array_equal(np.asarray(p2[k]),
                                          np.asarray(params[k]), k)


def test_recalibrate_moves_only_healthy_cells():
    tasks = build_scenario("permuted", seed=0, n_tasks=1, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=20)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1)
    p0 = init_miru_params(jax.random.PRNGKey(1), cfg)
    be = _wbs(FAULTY)
    st = be.init_device_state(p0, jax.random.PRNGKey(3))
    p1, st1 = recalibrate(cfg, tr, be, p0, st, tasks[0], steps=4)
    assert not np.array_equal(np.asarray(p1["w_h"]), np.asarray(p0["w_h"]))
    for name, tile in st1["_faults"].items():
        stuck = np.asarray(effective_masks(tile)[0])
        np.testing.assert_array_equal(np.asarray(p1[name])[stuck],
                                      np.asarray(p0[name])[stuck], name)


# ---------------------------------------------------------------------------
# Fleet propagation
# ---------------------------------------------------------------------------

def test_draw_fleet_faults_gating_and_determinism():
    fleet = FleetSpec(n_devices=16, seed=3)
    assert draw_fleet_faults(fleet, None) == (None, None)
    assert draw_fleet_faults(fleet, FAULTY) == (None, None)  # no knobs
    fs = dataclasses.replace(FAULTY, rate_spread=0.5, dead_chip_rate=0.2)
    scale, dead = draw_fleet_faults(fleet, fs)
    assert scale.shape == (16,) and dead.shape == (16,)
    assert np.all(np.asarray(scale) > 0)
    s2, d2 = draw_fleet_faults(fleet, fs)
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(dead), np.asarray(d2))


def test_fleet_run_reports_faults_and_dead_chips_at_chance():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1)
    fs = FaultSpec(sa0_rate=0.02, rate_spread=0.5, dead_chip_rate=0.3)
    fl = run_fleet(cfg, tr, tasks, FleetSpec(n_devices=4, seed=2),
                   replay=ReplaySpec(capacity=32), device=_wbs(fs))
    assert fl["faults"]["rate_scale"].shape == (4,)
    dead = np.asarray(fl["faults"]["dead_chips"])
    assert dead.any(), "seed chosen to include dead chips"
    accs = [p["metrics"]["average_accuracy"] for p in fl["per_device"]]
    for i in np.flatnonzero(dead):
        assert accs[i] < 0.3, (i, accs[i])    # a dead chip can't learn
    agg = fleet_aggregate(fl)
    sec = agg["faults"]
    assert sec["dead_chip_count"] == int(dead.sum())
    assert sec["dead_devices"] == [int(i) for i in np.flatnonzero(dead)]
    assert sec["stricken_tail_accuracy"]["min"] == min(accs)
    assert "rate_scale" in sec
    assert "max_fault_rate_device" in agg["hot_tail"]


def test_fleet_without_fault_spec_omits_section():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1)
    fl = run_fleet(cfg, tr, tasks, FleetSpec(n_devices=2, seed=0),
                   replay=ReplaySpec(capacity=32), device="ideal")
    assert "faults" not in fl
    assert "faults" not in fleet_aggregate(fl)
