"""Trainer, checkpointing (atomic/async/elastic), fault tolerance."""
import json
import os


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import ShardedBatcher
from repro.data.synthetic import lm_token_batch
from repro.train import CheckpointManager, TrainConfig, Trainer
from repro.train.faults import HealthMonitor, PreemptionGuard, retry


def _mk_trainer(tmp_path, steps=20, seed=0, checkpoint_every=5, **kw):
    cfg = get_smoke_config("qwen2-0.5b")

    def gen(rng, step):
        return lm_token_batch(rng, 4, 16, cfg.vocab)

    tcfg = TrainConfig(steps=steps, lr=1e-3, warmup_steps=2,
                       checkpoint_every=checkpoint_every, log_every=1000,
                       checkpoint_dir=str(tmp_path), seed=seed, **kw)
    return Trainer(cfg, tcfg, ShardedBatcher(gen, seed=seed))


def test_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path, steps=40)
    hist = t.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_endurance_tracker_checkpoint_roundtrip(tmp_path):
    """Lifetime projections survive restarts: the tracker serializes
    inside any checkpointed tree and is revived on restore."""
    from repro.analog.endurance import EnduranceTracker
    tracker = EnduranceTracker(endurance=5e8)
    rng = np.random.default_rng(0)
    for _ in range(3):
        tracker.record_update({"w_h": rng.random((4, 6)) < 0.5,
                               "u_h": rng.random((6, 6)) < 0.5})
    mgr = CheckpointManager(tmp_path / "ck")
    mgr.save(7, {"params": {"w": np.ones((2, 2))}, "endurance": tracker})
    step, tree, _ = mgr.restore()
    assert step == 7
    restored = tree["endurance"]
    assert isinstance(restored, EnduranceTracker)
    assert restored.endurance == tracker.endurance
    assert restored.updates_applied == tracker.updates_applied
    np.testing.assert_array_equal(restored.all_counts(),
                                  tracker.all_counts())
    # Lifetime projection identical across the restart boundary.
    from repro.telemetry import project_lifetime
    assert project_lifetime(restored).years_mean == \
        project_lifetime(tracker).years_mean
    # And it keeps counting after the restart.
    restored.record_update({"w_h": np.ones((4, 6), bool)})
    assert restored.updates_applied == 4


def test_checkpoint_restart_bit_identical(tmp_path):
    """Crash/restart: the restored trainer reproduces the uninterrupted
    run exactly (deterministic data pipeline + exact state restore)."""
    # checkpoint_every large: the explicit save at step 10 is the only
    # checkpoint, so the restored twin resumes exactly there.
    a = _mk_trainer(tmp_path / "a", steps=20, checkpoint_every=1000)
    a.run(steps=10)
    a.save(async_=False)
    a.run(steps=10)
    uninterrupted = [h["loss"] for h in a.history[10:]]

    b = _mk_trainer(tmp_path / "a", steps=20, checkpoint_every=1000)
    assert b.maybe_restore()
    assert b.step == 10
    b.run(steps=10)
    restarted = [h["loss"] for h in b.history]
    np.testing.assert_allclose(restarted, uninterrupted, rtol=1e-6)


def test_checkpoint_atomicity(tmp_path):
    ck = CheckpointManager(tmp_path, keep=2)
    tree = {"params": {"w": jnp.arange(8.0)}}
    ck.save(1, tree)
    ck.save(2, tree)
    ck.save(3, tree)
    assert ck.all_steps() == [2, 3]          # keep=2 GC'd step 1
    assert not list(tmp_path.glob("*.tmp"))  # no torn state left
    step, restored, _ = ck.restore()
    assert step == 3
    np.testing.assert_array_equal(restored["params"]["w"],
                                  np.arange(8.0))


def test_checkpoint_async(tmp_path):
    ck = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((1024, 64))}
    ck.save_async(7, tree, extra={"note": "async"})
    ck.wait()
    step, restored, extra = ck.restore()
    assert step == 7 and extra["note"] == "async"


def test_checkpoint_elastic_reshard(tmp_path):
    """Checkpoints are mesh-agnostic logical arrays: restore onto a
    different sharding (here: the 1-device mesh with a new layout)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = CheckpointManager(tmp_path)
    ck.save(1, {"w": jnp.arange(16.0).reshape(4, 4)})
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    _, restored, _ = ck.restore(shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]).reshape(-1),
                                  np.arange(16.0))


def test_preemption_guard_checkpoint_and_stop(tmp_path):
    """SIGTERM mid-run → finish the in-flight step, checkpoint, exit."""
    t = _mk_trainer(tmp_path, steps=100, checkpoint_every=1000)
    guard = PreemptionGuard(install=False)
    t.run(steps=3)
    guard.requested = True                  # deterministic "signal"
    t.run(guard=guard)                      # runs exactly one more step
    assert t.step == 4                      # stopped at the boundary
    assert t.ckpt.latest_step() == 4        # checkpoint saved on exit


def test_health_monitor_straggler():
    mon = HealthMonitor(straggler_factor=3.0)
    for s in range(10):
        assert not mon.record(s, 0.1)
    assert mon.record(10, 1.0)               # 10× the EWMA
    assert mon.straggler_events[0][0] == 10


def test_health_monitor_excludes_stragglers_from_ewma():
    """A flagged step must not poison the baseline it was judged
    against: after stragglers the EWMA is unchanged, so a subsequent
    moderate straggler is still caught."""
    mon = HealthMonitor(straggler_factor=3.0, ewma=0.9)
    for s in range(3):
        assert not mon.record(s, 1.0)
    assert mon.mean_step_s == pytest.approx(1.0)
    assert mon.record(3, 4.0)                # straggler: 4 > 3×1.0
    # the 4.0 did NOT fold into the mean (old code inflated it to 1.3,
    # after which 3.5 < 3×1.3 slipped through)
    assert mon.mean_step_s == pytest.approx(1.0)
    assert mon.record(4, 3.5)                # still caught
    assert [e[0] for e in mon.straggler_events] == [3, 4]


def test_retry_rejects_nonpositive_attempts():
    with pytest.raises(ValueError, match="attempts"):
        retry(lambda: 1, attempts=0)


def test_retry_success_and_no_sleep_after_last_attempt(monkeypatch):
    sleeps = []
    monkeypatch.setattr("repro.train.faults.time.sleep", sleeps.append)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=3, backoff_s=0.1) == "ok"
    assert sleeps == [pytest.approx(0.1), pytest.approx(0.2)]

    sleeps.clear()
    with pytest.raises(RuntimeError, match="always"):
        retry(lambda: (_ for _ in ()).throw(RuntimeError("always")),
              attempts=2, backoff_s=0.1)
    # the final failed attempt re-raises immediately — no trailing
    # full-backoff sleep
    assert sleeps == [pytest.approx(0.1)]


def test_kwta_and_compression_in_trainer(tmp_path):
    t = _mk_trainer(tmp_path, steps=10, kwta_grad_keep=0.5,
                    grad_compression_keep=0.5)
    hist = t.run()
    assert all(np.isfinite(h["loss"]) for h in hist)
