"""HLO analyzer: loop-multiplied FLOPs / bytes / collective counting."""
import numpy as np

from repro.launch.hlo_analysis import Analysis, analyze, parse_module

HLO = """\
HloModule jit_step, entry_computation_layout={(f32[8,4]{1,0})->f32[8,4]{1,0}}

%inner.body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p = (s32[], f32[8,4]) parameter(0)
  %gte0 = s32[] get-tuple-element(%p), index=0
  %gte1 = f32[8,4]{1,0} get-tuple-element(%p), index=1
  %w = f32[4,4]{1,0} constant({...})
  %dot.1 = f32[8,4]{1,0} dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,4]{1,0} all-reduce(%dot.1), replica_groups={}
  %one = s32[] constant(1)
  %next = s32[] add(%gte0, %one)
  ROOT %tup = (s32[], f32[8,4]) tuple(%next, %ar)
}

%inner.cond (pc: (s32[], f32[8,4])) -> pred[] {
  %pc = (s32[], f32[8,4]) parameter(0)
  %g = s32[] get-tuple-element(%pc), index=0
  %lim = s32[] constant(6)
  ROOT %cmp = pred[] compare(%g, %lim), direction=LT
}

ENTRY %main (arg: f32[8,4]) -> f32[8,4] {
  %arg = f32[8,4]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[8,4]) tuple(%zero, %arg)
  %loop = (s32[], f32[8,4]) while(%t), condition=%inner.cond, body=%inner.body, backend_config={"known_trip_count":{"n":"6"}}
  %ag = f32[16,4]{1,0} all-gather(%arg), dimensions={0}
  %red = f32[8,4]{1,0} slice(%ag), slice={[0:8], [0:4]}
  ROOT %out = f32[8,4]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_parse_structure():
    comps, shapes = parse_module(HLO)
    assert set(comps) == {"inner.body", "inner.cond", "main"}
    assert shapes["dot.1"] == ("f32", "8,4")


def test_flops_multiplied_by_trip_count():
    a = analyze(HLO)
    # dot: 2 · 8·4 out · 4 contracted = 256 flops × 6 trips.
    assert a.flops == 256 * 6


def test_collectives_multiplied():
    a = analyze(HLO)
    # all-reduce (8·4·4 B = 128 B) × 6 + all-gather 16·4·4 = 256 B × 1.
    assert a.per_collective["all-reduce"]["bytes"] == 128 * 6
    assert a.per_collective["all-reduce"]["count"] == 6
    assert a.per_collective["all-gather"]["bytes"] == 256
    assert a.collective_bytes == 128 * 6 + 256


def test_bytes_exclude_aliases():
    a = analyze(HLO)
    # Counted: dot (128) + all-reduce (128) + add (4) per body trip ×6,
    # compare (1 B) per cond trip ×6, + all-gather 256 + slice 128.
    # tuples/GTE/params/constants excluded.
    expected = 6 * (128 + 128 + 4 + 1) + 256 + 128
    assert a.bytes_accessed == expected


def test_real_module_sanity():
    """Analyzer on a real compiled module: flops within 2.5× of 6·N·D
    (extra = attention + remat recompute)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import lm as lm_mod

    cfg = get_smoke_config("internlm2-1.8b")
    params = lm_mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32),
             "mask": jnp.ones((2, 16), jnp.float32)}

    def train(p):
        return jax.value_and_grad(
            lambda q: lm_mod.loss_fn(q, cfg, batch))(p)

    hlo = jax.jit(train).lower(params).compile().as_text()
    a = analyze(hlo)
    from repro.utils import tree_size
    n = tree_size(params)
    model_flops = 6 * n * 2 * 16
    assert a.flops > 0.8 * model_flops
    assert a.flops < 4.0 * model_flops
    assert a.bytes_accessed > 0
