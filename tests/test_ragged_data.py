"""Ragged data pipeline (docs/data.md): the PadPolicy contract, the
masked compiled program's parity gates, real-stream adapters with the
offline surrogate policy, per-chip fleet data sharding, and the
batcher's ragged round-trip through state_dict/restore."""
import dataclasses
import hashlib

import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  build_batch_schedule, run_continual)
from repro.core.replay import ReplayBuffer
from repro.data.pipeline import ShardedBatcher, shard_tasks
from repro.data.ragged import (PadPolicy, bucket_size, eval_masks,
                               needs_masked_program, pad_tasks)
from repro.data.synthetic import TaskData, make_permuted_tasks
from repro.scenarios import (build_scenario, get_scenario, run_compiled,
                             scenario_miru_config)

# Losses pass through different-but-equivalent reduction orders in the
# loop vs the compiled scan — the repo-wide tolerance from
# tests/test_scenarios.py. R matrices are compared exactly.
LOSS_TOL = dict(rtol=2e-5, atol=1e-6)


def _ragged_tasks(seed=0, t_max=12, f=6, n_cls=4,
                  sizes=((48, 24), (36, 20), (28, 24))):
    """A stream ragged in n_train, n_test, and per-example length."""
    rng = np.random.default_rng(seed)
    tasks = []
    for tid, (ntr, nte) in enumerate(sizes):
        def draw(n):
            x = rng.uniform(0, 1, size=(n, t_max, f)).astype(np.float32)
            y = rng.integers(0, n_cls, size=n).astype(np.int32)
            L = rng.integers(t_max // 2, t_max + 1, size=n).astype(np.int32)
            for i in range(n):
                x[i, L[i]:] = 0.0
            return x, y, L
        xtr, ytr, ltr = draw(ntr)
        xte, yte, lte = draw(nte)
        tasks.append(TaskData(xtr, ytr, xte, yte, task_id=tid,
                              train_lengths=ltr, test_lengths=lte))
    return tasks


def _aligned_tasks(n_tasks=2, n_train=96, n_test=48):
    return build_scenario("permuted", seed=0, n_tasks=n_tasks,
                          n_train=n_train, n_test=n_test)


# ---------------------------------------------------------------------------
# PadPolicy / pad_tasks basics
# ---------------------------------------------------------------------------

def test_bucket_size():
    assert bucket_size(28, "max") == 28
    assert bucket_size(28, "pow2") == 32
    assert bucket_size(32, "pow2") == 32
    assert bucket_size(1, "pow2") == 1


def test_pad_policy_validates_modes():
    with pytest.raises(ValueError, match="bucket"):
        PadPolicy(bucket="median")
    with pytest.raises(ValueError, match="last_batch"):
        PadPolicy(last_batch="wrap")


def test_pad_tasks_aligned_stream_is_identity():
    tasks = _aligned_tasks()
    out, padded = pad_tasks(tasks, PadPolicy())
    assert not padded
    for a, b in zip(tasks, out):
        assert_array_equal(a.x_train, b.x_train)
        assert_array_equal(a.x_test, b.x_test)
        assert b.train_lengths is None and b.test_valid is None


def test_pad_tasks_ragged_stream():
    tasks = _ragged_tasks()
    out, padded = pad_tasks(tasks, PadPolicy())
    assert padded
    ne_max = max(t.x_test.shape[0] for t in tasks)
    for src, t in zip(tasks, out):
        assert t.x_test.shape[0] == ne_max
        ne = src.x_test.shape[0]
        if ne == ne_max:
            # Already at the bucketed size: no row mask is attached.
            assert t.test_valid is None
            continue
        assert t.test_valid.sum() == ne
        assert_array_equal(t.test_valid[:ne], np.ones(ne, bool))
        # Pad rows are zero and carry an in-range dummy length.
        assert not t.x_test[ne:].any()
        assert (t.test_lengths[ne:] == 1).all()
    assert any(t.test_valid is not None for t in out)


def test_pad_tasks_pow2_buckets_time_axis():
    tasks = _ragged_tasks(t_max=12)
    out, _ = pad_tasks(tasks, PadPolicy(bucket="pow2"))
    assert all(t.x_train.shape[1] == 16 for t in out)
    # The padded tail is zeros; true lengths are preserved.
    for src, t in zip(tasks, out):
        assert not t.x_train[:, 12:].any()
        assert_array_equal(t.train_lengths, src.train_lengths)


def test_needs_masked_program_predicate():
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=32, seed=0)
    rp = ReplaySpec(capacity=32)
    aligned = _aligned_tasks()
    sched = build_batch_schedule(tr, rp, aligned, pad=PadPolicy())
    assert not needs_masked_program(PadPolicy(), False, sched)
    assert needs_masked_program(PadPolicy(force=True), False, sched)
    assert needs_masked_program(PadPolicy(), True, sched)
    ragged, _ = pad_tasks(_ragged_tasks(), PadPolicy())
    rsched = build_batch_schedule(tr, rp, ragged, pad=PadPolicy())
    assert needs_masked_program(PadPolicy(), False, rsched)


def test_eval_masks_shapes():
    tasks, _ = pad_tasks(_ragged_tasks(), PadPolicy())
    valid, lengths = eval_masks(tasks)
    assert valid.shape == lengths.shape == (3, 24)
    assert valid.dtype == bool and lengths.dtype == np.int32


# ---------------------------------------------------------------------------
# Parity gates: the masked program vs the historical one
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["dfa", "adam"])
def test_pad_attached_but_aligned_is_bitwise_identical(algo):
    """The hard contract: a PadPolicy on an already-aligned stream
    builds the exact pre-refactor program — bitwise, not just close."""
    tasks = _aligned_tasks()
    cfg = scenario_miru_config(tasks, n_h=24)
    tr = TrainerSpec(algo=algo, epochs_per_task=1, batch_size=32, seed=0)
    rp = ReplaySpec(capacity=48)
    base = run_compiled(cfg, tr, tasks, rp, "ideal")
    pad = run_compiled(cfg, tr, tasks, rp, "ideal",
                       pad=PadPolicy(last_batch="drop"))
    assert base["compiled"] and pad["compiled"]
    assert_array_equal(np.asarray(base["R_full"]), np.asarray(pad["R_full"]))
    assert_array_equal(np.asarray(base["losses"]), np.asarray(pad["losses"]))
    import jax
    leaves_a = jax.tree.leaves(base["params"])
    leaves_b = jax.tree.leaves(pad["params"])
    for a, b in zip(leaves_a, leaves_b):
        assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("algo", ["dfa", "adam"])
def test_forced_masked_program_matches_within_ulp(algo):
    """force=True builds the masked program on an aligned stream; XLA's
    mask-into-reduction fusion may reassociate sums by ±1 ulp, so the
    cross-program comparison is ulp-level, not bitwise."""
    tasks = _aligned_tasks()
    cfg = scenario_miru_config(tasks, n_h=24)
    tr = TrainerSpec(algo=algo, epochs_per_task=1, batch_size=32, seed=0)
    rp = ReplaySpec(capacity=48)
    base = run_compiled(cfg, tr, tasks, rp, "ideal")
    forced = run_compiled(cfg, tr, tasks, rp, "ideal",
                          pad=PadPolicy(force=True))
    assert forced["compiled"]
    assert_allclose(np.asarray(forced["R_full"]),
                    np.asarray(base["R_full"]), atol=1e-6)
    assert_allclose(np.asarray(forced["losses"]),
                    np.asarray(base["losses"]), **LOSS_TOL)


@pytest.mark.parametrize("algo", ["dfa", "adam"])
@pytest.mark.parametrize("last_batch", ["pad", "drop"])
def test_ragged_loop_vs_compiled(algo, last_batch):
    """A genuinely ragged stream through the one compiled program holds
    the repo's loop-vs-compiled standard: R exactly equal, losses within
    float32 tolerance."""
    tasks = _ragged_tasks()
    cfg = scenario_miru_config(tasks, n_h=16)
    tr = TrainerSpec(algo=algo, epochs_per_task=1, batch_size=16, seed=0)
    rp = ReplaySpec(capacity=32)
    pol = PadPolicy(last_batch=last_batch)
    comp = run_compiled(cfg, tr, tasks, rp, "ideal", uniform=False, pad=pol)
    loop = run_continual(cfg, tr, tasks, rp, "ideal", pad=pol)
    assert comp["compiled"]
    assert_array_equal(np.asarray(comp["R"]), np.asarray(loop["R"]))
    assert_allclose(np.asarray(comp["losses"]), np.asarray(loop["losses"]),
                    **LOSS_TOL)


def test_last_partial_batch_audit():
    """n_train=40, batch=16: "drop" discards the 8-row tail (2 steps per
    epoch, the historical behavior), "pad" keeps it as a masked third
    step — and both stay loop-vs-compiled consistent."""
    tasks = _ragged_tasks(sizes=((40, 16), (40, 16)))
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=16, seed=0)
    rp = ReplaySpec(capacity=32)
    drop = build_batch_schedule(tr, rp, tasks, pad=PadPolicy())
    keep = build_batch_schedule(tr, rp, tasks,
                                pad=PadPolicy(last_batch="pad"))
    assert drop.steps_per_task == [2, 2]
    assert keep.steps_per_task == [3, 3]
    # The padded tail step trains on 8 real + 8 invalid rows.
    assert keep.row_valid[0][-1].sum() == 8

    cfg = scenario_miru_config(tasks, n_h=16)
    for pol in (PadPolicy(), PadPolicy(last_batch="pad")):
        comp = run_compiled(cfg, tr, tasks, rp, "ideal",
                            uniform=False, pad=pol)
        loop = run_continual(cfg, tr, tasks, rp, "ideal", pad=pol)
        assert_array_equal(np.asarray(comp["R"]), np.asarray(loop["R"]))


def test_multi_seed_vmap_on_ragged_stream():
    tasks = _ragged_tasks()
    cfg = scenario_miru_config(tasks, n_h=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=16, seed=0)
    rp = ReplaySpec(capacity=32)
    pol = PadPolicy(last_batch="pad")
    multi = run_compiled(cfg, tr, tasks, rp, "ideal", seeds=[0, 1],
                         uniform=False, pad=pol)
    single = run_compiled(cfg, tr, tasks, rp, "ideal",
                          uniform=False, pad=pol)
    assert_array_equal(np.asarray(multi["per_seed"][0]["R_full"]),
                       np.asarray(single["R_full"]))


def test_in_graph_replay_rejects_padding():
    """loss_aware replay lives on the scan carry; it has no valid-mask
    story yet, so combining it with a PadPolicy is a loud error in both
    runners rather than silently rehearsing pad rows."""
    tasks = _aligned_tasks()
    cfg = scenario_miru_config(tasks, n_h=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=32, seed=0)
    rp = ReplaySpec(capacity=32, policy="loss_aware")
    with pytest.raises(ValueError, match="in-graph|loss_aware"):
        run_compiled(cfg, tr, tasks, rp, "ideal", pad=PadPolicy(force=True))
    with pytest.raises(ValueError, match="in-graph|loss_aware"):
        run_continual(cfg, tr, tasks, rp, "ideal", pad=PadPolicy(force=True))


# ---------------------------------------------------------------------------
# Masked replay insertion
# ---------------------------------------------------------------------------

def test_add_batch_valid_mask_gates_rows():
    """Padded rows never enter the buffer and consume no sampler or
    quantizer RNG: a zero-padded batch with its mask leaves the buffer
    bit-identical to the unpadded batch."""
    rng = np.random.default_rng(0)
    xs = rng.uniform(0, 1, size=(8, 6, 4)).astype(np.float32)
    ys = rng.integers(0, 3, size=8).astype(np.int32)
    pad_xs = np.concatenate([xs, np.zeros((4, 6, 4), np.float32)])
    pad_ys = np.concatenate([ys, np.zeros(4, np.int32)])
    valid = np.concatenate([np.ones(8, bool), np.zeros(4, bool)])

    a = ReplayBuffer(capacity=16, feature_shape=(6, 4), seed=7)
    b = ReplayBuffer(capacity=16, feature_shape=(6, 4), seed=7)
    n_a = a.add_batch(xs, ys)
    n_b = b.add_batch(pad_xs, pad_ys, valid=valid)
    assert n_a == n_b
    assert_array_equal(a._feat, b._feat)
    assert_array_equal(a._label, b._label)
    assert_array_equal(np.asarray(a._qkey), np.asarray(b._qkey))
    assert a.size == b.size


def test_all_invalid_batch_is_a_noop():
    buf = ReplayBuffer(capacity=8, feature_shape=(4,), seed=3)
    key0 = np.asarray(buf._qkey).copy()
    n = buf.add_batch(np.zeros((3, 4), np.float32),
                      np.zeros(3, np.int32), valid=np.zeros(3, bool))
    assert n == 0 and buf.size == 0
    assert_array_equal(np.asarray(buf._qkey), key0)


# ---------------------------------------------------------------------------
# Real-stream adapters (repro.data.real)
# ---------------------------------------------------------------------------

def test_offline_surrogate_is_deterministic():
    from repro.data.real import load_mnist
    a = load_mnist(offline=True)
    b = load_mnist(offline=True)
    assert a[4] == b[4] == "surrogate"
    assert_array_equal(a[0], b[0])
    assert_array_equal(a[1], b[1])
    assert a[0].shape[1:] == (28, 28) and a[0].dtype == np.float32
    assert float(a[0].min()) >= 0.0 and float(a[0].max()) <= 1.0


def test_env_var_pins_offline(monkeypatch):
    from repro.data import real
    monkeypatch.setenv("REPRO_DATA_OFFLINE", "1")
    x_tr, y_tr, x_te, y_te, src = real.load_cifar10()
    assert src == "surrogate"
    assert x_tr.shape[1:] == (32, 32, 3)


def test_checksum_mismatch_raises(tmp_path):
    from repro.data.real import _fetch
    bad = tmp_path / "train-images-idx3-ubyte.gz"
    bad.write_bytes(b"not the dataset")
    want = hashlib.sha256(b"something else").hexdigest()
    with pytest.raises(ValueError, match="checksum mismatch"):
        _fetch("https://invalid.example/never-contacted", want, bad)


def test_fetch_serves_verified_cache(tmp_path):
    from repro.data.real import _fetch
    blob = b"cached payload"
    dest = tmp_path / "blob.bin"
    dest.write_bytes(blob)
    got = _fetch("https://invalid.example/never-contacted",
                 hashlib.sha256(blob).hexdigest(), dest)
    assert got == dest


def test_seq_mnist_builder_offline():
    from repro.data.real import make_seq_mnist_tasks
    tasks = make_seq_mnist_tasks(seed=0, n_tasks=3, n_train=64, n_test=32,
                                 offline=True)
    assert len(tasks) == 3
    for t in tasks:
        assert t.x_train.shape == (64, 28, 28)
        assert t.x_test.shape == (32, 28, 28)
    # Task 0 is the identity permutation of one shared subsample; later
    # tasks permute the same rows.
    assert not np.array_equal(tasks[0].x_train, tasks[1].x_train)
    assert_array_equal(np.sort(tasks[0].x_train, axis=None),
                       np.sort(tasks[1].x_train, axis=None))


def test_seq_cifar10_builder_offline():
    from repro.data.real import make_seq_cifar10_tasks
    tasks = make_seq_cifar10_tasks(seed=0, n_tasks=2, n_train=48, n_test=24,
                                   offline=True)
    for t in tasks:
        assert t.x_train.shape == (48, 32, 96)
        assert set(np.unique(t.y_train)) <= {0, 1}
    with pytest.raises(ValueError, match="at most 5"):
        make_seq_cifar10_tasks(seed=0, n_tasks=6, offline=True)


def test_keyword_fewshot_is_ragged_and_deterministic():
    from repro.data.real import make_keyword_fewshot_tasks
    a = make_keyword_fewshot_tasks(seed=0, n_tasks=3)
    b = make_keyword_fewshot_tasks(seed=0, n_tasks=3)
    shots = [t.x_train.shape[0] for t in a]
    assert shots == [64, 32, 16]  # decreasing few-shot counts
    for t, u in zip(a, b):
        assert_array_equal(t.x_train, u.x_train)
        assert t.train_lengths is not None
        assert t.train_lengths.min() >= 16
        # Zero-padded past each utterance's true length.
        for i in (0, len(t.x_train) - 1):
            assert not t.x_train[i, t.train_lengths[i]:].any()


def test_real_scenarios_registered_with_pads():
    for name in ("seq_mnist", "seq_cifar10", "keyword_fewshot"):
        sc = get_scenario(name)
        assert isinstance(sc.pad, PadPolicy)
        assert sc.pad.last_batch == "pad"
    assert not get_scenario("keyword_fewshot").uniform
    assert get_scenario("permuted").pad is None


def test_seq_mnist_through_compiled_sweep(monkeypatch):
    monkeypatch.setenv("REPRO_DATA_OFFLINE", "1")
    sc = get_scenario("seq_mnist")
    tasks = build_scenario("seq_mnist", seed=0, n_tasks=2, n_train=72,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=32, seed=0)
    rp = ReplaySpec(capacity=32)
    res = run_compiled(cfg, tr, tasks, rp, "ideal",
                       uniform=sc.uniform, pad=sc.pad)
    # 72 % 32 != 0 → the registered "pad" policy keeps the tail batch
    # through the masked program.
    assert res["compiled"]
    assert np.isfinite(res["MA"])
    loop = run_continual(cfg, tr, tasks, rp, "ideal", pad=sc.pad)
    assert_array_equal(np.asarray(res["R"]), np.asarray(loop["R"]))


# ---------------------------------------------------------------------------
# Fleet data sharding
# ---------------------------------------------------------------------------

def test_shard_tasks_disjoint_equal_shards():
    tasks = _aligned_tasks(n_tasks=2, n_train=96, n_test=48)
    shards = [shard_tasks(tasks, 3, i) for i in range(3)]
    for t in range(2):
        rows = [s[t].x_train for s in shards]
        assert all(r.shape == (32, 28, 28) for r in rows)
        flat = np.concatenate([r.reshape(32, -1) for r in rows])
        # Pairwise disjoint: no training row appears in two shards.
        assert len(np.unique(flat, axis=0)) == len(flat)
        # Test sets are shared untouched.
        assert_array_equal(shards[0][t].x_test, tasks[t].x_test)
    with pytest.raises(ValueError, match="out of range"):
        shard_tasks(tasks, 3, 3)
    with pytest.raises(ValueError, match="fewer than"):
        shard_tasks(tasks, 200, 0)


def test_shard_tasks_carries_lengths():
    tasks = _ragged_tasks(sizes=((40, 16),))
    s0 = shard_tasks(tasks, 2, 0)[0]
    s1 = shard_tasks(tasks, 2, 1)[0]
    assert s0.train_lengths.shape == (20,)
    assert_array_equal(s0.train_lengths, tasks[0].train_lengths[0::2][:20])
    assert_array_equal(s1.train_lengths, tasks[0].train_lengths[1::2][:20])
    assert s0.test_lengths is tasks[0].test_lengths


def test_fleet_shard_data():
    from repro.fleet.heterogeneity import FleetSpec
    from repro.fleet.run import run_fleet
    tasks = _aligned_tasks(n_tasks=2, n_train=64, n_test=32)
    cfg = scenario_miru_config(tasks, n_h=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=16, seed=0)
    rp = ReplaySpec(capacity=32)
    fleet = FleetSpec(n_devices=2, het_profile="none", seed=7)
    res = run_fleet(cfg, tr, tasks, fleet, rp, "ideal", shard_data=True)
    # Each chip trains on its 32-row shard: 2 steps/task instead of 4.
    assert res["updates_per_device"] == 4
    full = run_fleet(cfg, tr, tasks, fleet, rp, "ideal")
    assert full["updates_per_device"] == 8
    # Disjoint shards → the chips genuinely trained on different data.
    import jax
    pf = res["params_fleet"]
    assert any(not np.array_equal(np.asarray(l)[0], np.asarray(l)[1])
               for l in jax.tree.leaves(pf))


# ---------------------------------------------------------------------------
# Batcher ragged round-trip
# ---------------------------------------------------------------------------

def _ragged_gen(rng, step):
    n = 4
    lens = rng.integers(2, 7, size=n)
    return {"tokens": [rng.integers(0, 50, size=(int(L),)).astype(np.int32)
                       for L in lens],
            "dense": rng.standard_normal((n, 3)).astype(np.float32)}


def test_batcher_collates_ragged_keys():
    b = ShardedBatcher(_ragged_gen, seed=11)
    batch = b.next()
    assert batch["tokens"].shape[0] == 4
    assert batch["tokens_lengths"].dtype == np.int32
    assert batch["tokens"].shape[1] == batch["tokens_lengths"].max()
    for i, L in enumerate(batch["tokens_lengths"]):
        assert not batch["tokens"][i, L:].any()
    assert batch["dense"].shape == (4, 3)
    assert "dense_lengths" not in batch


def test_batcher_ragged_state_dict_roundtrip():
    """Restart-safety through ragged collation: a restored batcher
    replays every step bit-identically — padding is recomputed from the
    regenerated rows, never checkpointed."""
    a = ShardedBatcher(_ragged_gen, seed=5)
    for _ in range(3):
        a.next()
    state = a.state_dict()
    want = [a.next() for _ in range(2)]

    b = ShardedBatcher(_ragged_gen, seed=0)
    b.load_state_dict(state)
    got = [b.next() for _ in range(2)]
    for w, g in zip(want, got):
        assert sorted(w) == sorted(g)
        for k in w:
            assert_array_equal(w[k], g[k])


def test_batcher_pad_to_pins_compile_shape():
    a = ShardedBatcher(_ragged_gen, seed=5, pad_to=8)
    shapes = {a.next()["tokens"].shape[1] for _ in range(4)}
    assert shapes == {8}
    with pytest.raises(ValueError, match="exceeds pad_to"):
        ShardedBatcher(_ragged_gen, seed=5, pad_to=3).next()
