"""Property-based tests for the WBS sign-magnitude quantizer and its
straight-through estimator (satellite of the scenarios PR).

``analog/wbs.quantize_signed`` feeds every quantized substrate's drive
path; the STE wrappers in ``backends/wbs.py`` are what make those
substrates differentiable (exact quantized forward, exact *linear*
backward). Properties, on random shapes and bit-widths:

  round-trip   |clip(x) − sign·mag/top| ≤ 1/(2·top)
  monotone     reconstruction is order-preserving
  symmetric    quantize(−x) = (−sign, mag)
  STE          d/d(drive), d/d(weights) of the quantized VMM are exactly
               the plain linear matmul's gradients (bitwise)
"""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.analog.wbs import quantize_signed
from repro.backends import DeviceSpec, get_backend


def _recon(x, n_bits):
    sign, mag = quantize_signed(x, n_bits)
    top = 2.0 ** n_bits - 1.0
    return sign.astype(jnp.float32) * mag.astype(jnp.float32) / top


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_roundtrip_bound(n_bits, n, seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n,),
                           minval=-1.2, maxval=1.2)
    err = jnp.abs(jnp.clip(x, -1, 1) - _recon(x, n_bits))
    assert float(err.max()) <= 0.5 / (2 ** n_bits - 1) + 1e-7, \
        (n_bits, float(err.max()))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(2, 200))
def test_monotone(n_bits, n):
    x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(n), (n,),
                                    minval=-1.0, maxval=1.0))
    r = np.asarray(_recon(x, n_bits))
    assert (np.diff(r) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_sign_symmetry(n_bits, n, seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n,),
                           minval=-1.0, maxval=1.0)
    s_pos, m_pos = quantize_signed(x, n_bits)
    s_neg, m_neg = quantize_signed(-x, n_bits)
    np.testing.assert_array_equal(np.asarray(m_pos), np.asarray(m_neg))
    np.testing.assert_array_equal(np.asarray(s_pos), -np.asarray(s_neg))


def test_endpoints_and_zero():
    sign, mag = quantize_signed(jnp.array([-1.0, 0.0, 1.0, 2.0]), 8)
    np.testing.assert_array_equal(np.asarray(sign), [-1, 0, 1, 1])
    np.testing.assert_array_equal(np.asarray(mag), [255, 0, 255, 255])


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 12), st.integers(1, 24), st.integers(1, 8),
       st.integers(2, 8))
def test_ste_backward_is_exact_linear(m, k, n, n_bits):
    """The quantized VMM's VJP equals the plain matmul's analytic
    gradients (g·Wᵀ, xᵀ·g) — quantization must be invisible to the
    optimizer. Tolerance covers only XLA op-ordering ulps; a leaked
    quantization derivative would be ~2⁻ⁿ, orders of magnitude larger."""
    backend = get_backend("wbs", spec=DeviceSpec(input_bits=n_bits,
                                                 adc_bits=None,
                                                 weight_clip=1.0))
    kx, kw, kg = jax.random.split(jax.random.PRNGKey(m * 37 + k), 3)
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = jax.random.normal(kw, (k, n)) * 0.4
    ct = jax.random.normal(kg, (m, n))

    def quantized(d, wt):
        return jnp.vdot(backend.vmm(d, wt), ct)

    def linear(d, wt):
        return jnp.vdot(d @ wt, ct)

    gq = jax.grad(quantized, argnums=(0, 1))(x, w)
    gl = jax.grad(linear, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gq[0]), np.asarray(gl[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gq[1]), np.asarray(gl[1]),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 10), st.integers(1, 16), st.integers(1, 6))
def test_ste_backward_independent_of_bit_width(m, k, n):
    """Bitwise: the backward is the *same program* at every precision —
    gradients at 2 and 8 drive bits are identical, though the quantized
    forwards differ."""
    kx, kw, kg = jax.random.split(jax.random.PRNGKey(m + 41 * k), 3)
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = jax.random.normal(kw, (k, n)) * 0.4
    ct = jax.random.normal(kg, (m, n))
    grads = {}
    for bits in (2, 8):
        backend = get_backend("wbs", spec=DeviceSpec(input_bits=bits,
                                                     adc_bits=None,
                                                     weight_clip=1.0))
        grads[bits] = jax.grad(
            lambda d, wt: jnp.vdot(backend.vmm(d, wt), ct),
            argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(grads[2][0]),
                                  np.asarray(grads[8][0]))
    np.testing.assert_array_equal(np.asarray(grads[2][1]),
                                  np.asarray(grads[8][1]))


def test_ste_forward_is_quantized_not_linear():
    """The STE changes only the backward: the forward stays the exact
    quantized value (differs from the float matmul)."""
    backend = get_backend("wbs", spec=DeviceSpec(input_bits=3,
                                                 adc_bits=None,
                                                 weight_clip=1.0))
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 6),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (6, 3)) * 0.4
    y = np.asarray(backend.vmm(x, w))
    exact = np.asarray(x @ w)
    assert not np.array_equal(y, exact)              # 3-bit error visible
    assert np.abs(y - exact).max() < 0.5             # but bounded


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 8), st.integers(1, 32))
def test_ste_readout_backward_is_identity(adc_bits, n):
    """quantize_readout: forward = fused ADC, backward = exact identity."""
    backend = get_backend("wbs", spec=DeviceSpec(input_bits=8,
                                                 adc_bits=adc_bits,
                                                 adc_range=4.0,
                                                 weight_clip=1.0))
    pre = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 2.0
    ct = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
    g = jax.grad(lambda p: jnp.vdot(backend.quantize_readout(p), ct))(pre)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(ct))
