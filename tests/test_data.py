"""Data pipeline: determinism, restart-safety, task streams."""
import numpy as np

from repro.data.pipeline import ShardedBatcher
from repro.data.synthetic import (lm_token_batch, make_permuted_tasks,
                                  make_split_tasks)


def test_batcher_deterministic():
    def gen(rng, step):
        return {"x": rng.integers(0, 100, 8)}

    a = ShardedBatcher(gen, seed=3)
    b = ShardedBatcher(gen, seed=3)
    for _ in range(5):
        np.testing.assert_array_equal(a.next()["x"], b.next()["x"])


def test_batcher_restart_resumes_exactly():
    def gen(rng, step):
        return {"x": rng.integers(0, 1000, 4)}

    a = ShardedBatcher(gen, seed=0)
    seq = [a.next()["x"] for _ in range(6)]
    state = a.state_dict()

    b = ShardedBatcher(gen, seed=99)         # wrong seed on purpose
    b.load_state_dict({"step": 3, "seed": 0})
    for i in range(3, 6):
        np.testing.assert_array_equal(b.next()["x"], seq[i])
    assert state["step"] == 6


def test_batches_differ_across_steps():
    def gen(rng, step):
        return {"x": rng.integers(0, 10**6, 16)}

    a = ShardedBatcher(gen, seed=0)
    x0 = a.next()["x"]
    x1 = a.next()["x"]
    assert not np.array_equal(x0, x1)


def test_permuted_tasks_structure():
    tasks = make_permuted_tasks(0, n_tasks=3, n_train=50, n_test=20)
    assert len(tasks) == 3
    t0 = tasks[0]
    assert t0.x_train.shape == (50, 28, 28)
    assert t0.x_train.min() >= 0 and t0.x_train.max() <= 1
    # Same underlying data, different pixel permutations.
    a = tasks[0].x_train.reshape(50, -1)
    b = tasks[1].x_train.reshape(50, -1)
    assert not np.allclose(a, b)
    np.testing.assert_allclose(np.sort(a, axis=1), np.sort(b, axis=1),
                               atol=1e-6)


def test_split_tasks_binary_head():
    tasks = make_split_tasks(0, n_tasks=4, n_train=40, n_test=10)
    for t in tasks:
        assert set(np.unique(t.y_train)) <= {0, 1}
        assert t.x_train.shape[1:] == (16, 32)


def test_lm_token_batch_shapes_and_structure():
    rng = np.random.default_rng(0)
    b = lm_token_batch(rng, 4, 32, vocab=1000)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert b["mask"][:, -1].sum() == 0
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000
