"""Crossbar, WBS, ADC, endurance models (§IV, §V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analog.adc import adc_quantize, total_hold_droop
from repro.analog.crossbar import CrossbarSpec, program, update, vmm
from repro.analog.endurance import (EnduranceTracker, lifespan_years,
                                    paper_lifespan_check)
from repro.analog.wbs import (WBSSpec, bit_planes, ideal_gains,
                              quantize_signed, wbs_vmm)


# ---------------------------------------------------------------------------
# Crossbar
# ---------------------------------------------------------------------------

def test_conductance_window():
    spec = CrossbarSpec()
    assert spec.g_on == pytest.approx(1 / 2e6)
    assert spec.g_off == pytest.approx(1 / 20e6)
    assert spec.g_ref == pytest.approx(0.5 * (spec.g_on + spec.g_off))


def test_program_roundtrip_ideal():
    spec = CrossbarSpec(write_sigma=0.0, read_sigma=0.0)
    w = jnp.array([[0.5, -0.5], [1.0, -1.0]])
    state = program(jax.random.PRNGKey(0), w, spec)
    np.testing.assert_allclose(state.to_weights(), w, rtol=1e-6)


def test_program_clips_to_window():
    spec = CrossbarSpec(write_sigma=0.0)
    w = jnp.array([[5.0, -5.0]])          # beyond w_clip
    state = program(jax.random.PRNGKey(0), w, spec)
    np.testing.assert_allclose(jnp.abs(state.to_weights()), 1.0, rtol=1e-6)


def test_write_variability_magnitude():
    spec = CrossbarSpec(write_sigma=0.10)
    w = jnp.full((64, 64), 0.5)
    state = program(jax.random.PRNGKey(0), w, spec)
    got = state.to_weights()
    # 10 % conductance noise maps to weight-domain spread around 0.5.
    assert 0.01 < float(jnp.std(got)) < 0.3
    assert abs(float(got.mean()) - 0.5) < 0.05


def test_vmm_matches_matmul_ideal():
    spec = CrossbarSpec(write_sigma=0.0, read_sigma=0.0)
    w = 0.8 * jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    w = jnp.clip(w, -1, 1)
    state = program(jax.random.PRNGKey(1), w, spec)
    x = jax.random.uniform(jax.random.PRNGKey(2), (4, 16))
    np.testing.assert_allclose(vmm(None, x, state), x @ w, rtol=1e-4,
                               atol=1e-5)


def test_update_only_writes_nonzero():
    spec = CrossbarSpec(write_sigma=0.0)
    w = jnp.zeros((4, 4))
    state = program(jax.random.PRNGKey(0), w, spec)
    dw = jnp.zeros((4, 4)).at[1, 2].set(0.25)
    new = update(jax.random.PRNGKey(1), state, dw)
    diff = new.to_weights() - state.to_weights()
    assert float(jnp.abs(diff).sum()) == pytest.approx(
        float(jnp.abs(diff[1, 2])), rel=1e-6)


# ---------------------------------------------------------------------------
# WBS (eqs. 11-19)
# ---------------------------------------------------------------------------

def test_bit_planes_reconstruct():
    code = jnp.arange(256, dtype=jnp.uint8)
    planes = bit_planes(code, 8)
    weights = 2.0 ** jnp.arange(7, -1, -1)
    rec = jnp.einsum("k,k...->...", weights, planes)
    np.testing.assert_array_equal(rec, code.astype(jnp.float32))


def test_gains_geometric_series():
    """Σ 2^-k = 1 − 2^-nb (eq. 18)."""
    for nb in (4, 8):
        g = ideal_gains(nb)
        assert float(g.sum()) == pytest.approx(1 - 2.0 ** -nb)


def test_wbs_vmm_ideal_equals_fixed_point():
    spec = WBSSpec(n_bits=8, gain_sigma=0.0, adc_bits=None)
    x = jax.random.uniform(jax.random.PRNGKey(0), (16, 32),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = wbs_vmm(x, w, spec)
    sign, code = quantize_signed(x, 8)
    x_hat = sign.astype(jnp.float32) * code.astype(jnp.float32) / 255.0
    np.testing.assert_allclose(y, x_hat @ w, rtol=1e-4, atol=1e-5)


def test_wbs_gain_noise_perturbs():
    spec = WBSSpec(n_bits=8, gain_sigma=0.05, adc_bits=None)
    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 16),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    y0 = wbs_vmm(x, w, WBSSpec(n_bits=8, adc_bits=None))
    y1 = wbs_vmm(x, w, spec, key=jax.random.PRNGKey(2))
    rel = float(jnp.abs(y1 - y0).max() / jnp.abs(y0).max())
    assert 0 < rel < 0.2


# ---------------------------------------------------------------------------
# ADC / integrator
# ---------------------------------------------------------------------------

def test_adc_quantize_grid():
    v = jnp.linspace(-3, 3, 77)
    q = adc_quantize(v, 8, 4.0)
    step = 8.0 / 256
    np.testing.assert_allclose(q / step, jnp.round(q / step), atol=1e-5)
    assert float(jnp.abs(q - v).max()) <= step / 2 + 1e-6


def test_hold_droop_below_paper_budget():
    """Paper: ΔV < 10.5 µV (< 0.1 LSB) over 200 ns."""
    assert total_hold_droop() < 10.5e-6


# ---------------------------------------------------------------------------
# Endurance / lifespan (§VI-B)
# ---------------------------------------------------------------------------

def test_tracker_counts_and_cdf():
    t = EnduranceTracker(endurance=100)
    t.record_update({"w": np.array([[1, 0], [1, 1]], bool)})
    t.record_update({"w": np.array([[1, 0], [0, 0]], bool)})
    assert t.mean_writes() == pytest.approx((2 + 0 + 1 + 1) / 4)
    xs, cdf = t.write_cdf(n_points=4)
    assert cdf[-1] == 1.0
    assert t.overstressed_fraction(1000) > 0  # rate 1/update × 1000 > 100


def test_lifespan_scaling_matches_paper():
    """Write-rate halving ≈ doubles lifetime: 6.9 → ~12-13 yr (§VI-B)."""
    chk = paper_lifespan_check()
    assert 11.0 < chk["sparse_years_scaling"] < 14.0
    assert abs(chk["write_reduction"] - 0.47) < 0.02
    # Absolute anchor: uniform writes at 1 kHz with 1e9 endurance.
    yrs = lifespan_years(1.0, endurance=1e9, update_period_s=1e-3)
    assert yrs == pytest.approx(1e9 * 1e-3 / (365.25 * 24 * 3600),
                                rel=1e-6)
