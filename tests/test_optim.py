"""Optimizer stack: adamw/sgd, 8-bit moments, ζ sparsifier, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def _quad_problem(seed=0, dim=16):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (dim, dim))
    params = {"w": jnp.zeros((dim, dim)), "b": jnp.zeros((dim,))}

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    return params, loss_fn


@pytest.mark.parametrize("make_opt", [
    lambda: optim.sgd(0.1, momentum=0.9),
    lambda: optim.adam(0.05),
    lambda: optim.adamw(0.05, weight_decay=0.0),
    lambda: optim.adam_8bit(0.05, weight_decay=0.0),
    lambda: optim.kwta_sparsify(optim.adam(0.05), keep_frac=0.5,
                                min_size=4),
    lambda: optim.topk_compress_error_feedback(optim.adam(0.05),
                                               keep_frac=0.25, min_size=4),
])
def test_optimizers_converge(make_opt):
    params, loss_fn = _quad_problem()
    opt = make_opt()
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(loss_fn)(p)
        u, s = opt.update(g, s, p)
        return optim.apply_updates(p, u), s, loss

    l0 = float(loss_fn(params))
    for _ in range(150):
        params, state, loss = step(params, state)
    assert float(loss) < 0.2 * l0


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.full((4,), 100.0)}
    u, _ = opt.update(g, opt.init(g))
    norm = float(jnp.linalg.norm(u["a"]))
    assert norm == pytest.approx(1.0, rel=1e-4)
    g_small = {"a": jnp.full((4,), 0.01)}
    u, _ = opt.update(g_small, ())
    np.testing.assert_allclose(u["a"], g_small["a"], rtol=1e-5)


def test_adam_8bit_state_is_int8():
    params = {"w": jnp.zeros((300, 256))}
    opt = optim.adam_8bit(0.01)
    state = opt.init(params)
    from repro.optim.qstate import Adam8bitState
    adam_state = next(s for s in state if isinstance(s, Adam8bitState))
    assert adam_state.mu["w"].codes.dtype == jnp.int8
    # Shape-preserving: codes keep the param rank (last dim padded to the
    # 128 block) so they inherit the param PartitionSpec under pjit.
    assert adam_state.mu["w"].codes.shape == (300, 256)
    assert adam_state.mu["w"].scales.shape == (300, 2)


def test_adam_8bit_tracks_fp32_adam():
    params, loss_fn = _quad_problem(dim=8)
    opt32 = optim.adam(0.05)
    opt8 = optim.adam_8bit(0.05, weight_decay=0.0, max_grad_norm=None)
    p32, p8 = params, params
    s32, s8 = opt32.init(params), opt8.init(params)
    for _ in range(60):
        _, g = jax.value_and_grad(loss_fn)(p32)
        u, s32 = opt32.update(g, s32, p32)
        p32 = optim.apply_updates(p32, u)
        _, g = jax.value_and_grad(loss_fn)(p8)
        u, s8 = opt8.update(g, s8, p8)
        p8 = optim.apply_updates(p8, u)
    # Same basin, close loss.
    assert abs(float(loss_fn(p8)) - float(loss_fn(p32))) < 0.1


def test_kwta_sparsify_masks_updates():
    inner = optim.sgd(1.0)
    opt = optim.kwta_sparsify(inner, keep_frac=0.25, min_size=4)
    g = {"w": jnp.arange(1.0, 17.0).reshape(4, 4)}
    state = opt.init(g)
    u, _ = opt.update(g, state, g)
    assert int((u["w"] != 0).sum()) == 4       # 25 % of 16


def test_error_feedback_accumulates():
    """Dropped gradient mass reappears via the residual (unbiased)."""
    inner = optim.scale(-1.0)                   # identity-ish
    opt = optim.topk_compress_error_feedback(inner, keep_frac=0.5,
                                             min_size=0)
    g = {"w": jnp.array([[4.0, 1.0], [3.0, 2.0]])}
    state = opt.init(g)
    rounds = 16
    sent_total = jnp.zeros((2, 2))
    for _ in range(rounds):
        u, state = opt.update(g, state, g)
        sent_total = sent_total + (-u["w"])
    # Cesàro sense: mean transmitted → true gradient (unbiased over time);
    # residual stays bounded.
    np.testing.assert_allclose(sent_total / rounds, g["w"], rtol=0.4)
    resid = state[0]["w"] if isinstance(state[0], dict) else None


def test_schedules():
    s = optim.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.asarray(100))) <= 0.06
    c = optim.cosine_schedule(2.0, 50)
    assert float(c(jnp.asarray(0))) == pytest.approx(2.0)
