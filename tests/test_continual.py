"""Continual-learning protocol: the paper's §VI-A claims end-to-end.

Marked ``slow`` (~2 min total — full tier / main CI only), but this is
the paper's core experiment. The fast tier covers the same machinery
through tests/test_scenarios.py's compiled-parity runs.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core.continual import ContinualConfig, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks

CFG = MiRUConfig(n_x=28, n_h=100, n_y=10)


@pytest.fixture(scope="module")
def tasks():
    return make_permuted_tasks(0, n_tasks=3, n_train=500, n_test=200)


@pytest.fixture(scope="module")
def results(tasks):
    out = {}
    for trainer in ("adam", "dfa", "dfa_hw"):
        # DFA uses plain SGD (Algorithm 1) and needs more passes than
        # Adam to converge — matching optimization effort, not steps.
        epochs = 6 if trainer == "adam" else 14
        ccfg = ContinualConfig(trainer=trainer, epochs_per_task=epochs,
                               batch_size=32, replay_capacity=512)
        out[trainer] = run_continual(CFG, ccfg, tasks)
    return out


def test_all_backends_learn(results):
    for name, res in results.items():
        assert res["acc_after_each"][0] > 0.75, (name, res["acc_after_each"])


def test_replay_prevents_catastrophic_forgetting(results, tasks):
    """With replay, task-0 accuracy stays well above chance after
    training through all tasks (graceful, not catastrophic)."""
    for name, res in results.items():
        task0_final = res["R"][-1, 0]
        assert task0_final > 0.25, (name, task0_final)
    # Without replay, forgetting is far worse (control).
    ccfg = ContinualConfig(trainer="dfa", epochs_per_task=6,
                           batch_size=32, replay_ratio=0.0,
                           replay_capacity=4)
    no_replay = run_continual(CFG, ccfg, tasks)
    with_replay = results["dfa"]["R"][-1, 0]
    assert with_replay > no_replay["R"][-1, 0] + 0.1


def test_hw_within_5pct_of_software(results):
    """The paper's headline: mixed-signal model within ~5 % of software
    (Fig. 4; 4.93 % at n_h=100)."""
    gap = results["dfa"]["MA"] - results["dfa_hw"]["MA"]
    assert gap < 0.06, gap


def test_dfa_competitive_with_adam(results):
    """Paper: DFA within 1-2 points of Adam (Fig. 4, real MNIST). This
    claim transfers only partially to the synthetic stream — Adam
    exploits its higher linear separability under replay faster than
    DFA's fixed-Ψ hidden updates. Weak-form gate (documented as a
    partial transfer in EXPERIMENTS.md §Repro): DFA learns every task
    and stays within 25 points under continual replay."""
    gap = results["adam"]["MA"] - results["dfa"]["MA"]
    assert results["dfa"]["MA"] > 0.45
    assert gap < 0.25, (results["adam"]["MA"], results["dfa"]["MA"])


def test_r_matrix_shape_and_monotone_tasks(results):
    R = results["dfa"]["R"]
    assert R.shape == (3, 3)
    assert np.all(R[np.triu_indices(3, 1)] == 0)   # upper empty
