"""DFA-through-time (Algorithm 1): correctness, learning, alignment."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dfa as D
from repro.core.miru import (MiRUConfig, init_dfa_feedback,
                             init_miru_params, miru_forward)
from repro.data.synthetic import make_permuted_tasks
from repro.utils import accuracy

CFG = MiRUConfig(n_x=28, n_h=64, n_y=10)


def _setup(seed=0):
    params = init_miru_params(jax.random.PRNGKey(seed), CFG)
    psi = init_dfa_feedback(jax.random.PRNGKey(seed + 1), CFG)
    task = make_permuted_tasks(seed, n_tasks=1, n_train=400,
                               n_test=200)[0]
    return params, psi, task


def test_output_layer_gradient_exact():
    """DFA's readout gradient IS the true gradient (lines 9-10)."""
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train[:64])
    y = jnp.asarray(task.y_train[:64])
    _, g_dfa = D.dfa_grads(params, psi, CFG, x, y)
    _, g_bp = D.bptt_grads(params, CFG, x, y)
    np.testing.assert_allclose(g_dfa["w_o"], g_bp["w_o"], rtol=2e-4,
                               atol=1e-6)
    np.testing.assert_allclose(g_dfa["b_o"], g_bp["b_o"], rtol=2e-4,
                               atol=1e-6)


def test_hidden_grads_shapes_finite():
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train[:32])
    y = jnp.asarray(task.y_train[:32])
    loss, g = D.dfa_grads(params, psi, CFG, x, y)
    for k, p in params.items():
        assert g[k].shape == p.shape
        assert bool(jnp.isfinite(g[k]).all()), k
    assert float(loss) > 0


def test_no_transposed_forward_weights():
    """Structural property: hidden grads do not depend on W_o (the whole
    point of DFA — no backward locking through the readout weights)."""
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train[:32])
    y = jnp.asarray(task.y_train[:32])

    def hidden_grad_wrt_wo(w_o):
        p = dict(params, w_o=w_o)
        _, g = D.dfa_grads(p, psi, CFG, x, y)
        return jnp.sum(jnp.abs(g["w_h"]))

    # d(hidden grad)/d(W_o) flows only through δ_o (the error), never
    # through a W_oᵀ product — check the Jacobian exists but the grads
    # match those from a *random* W_o direction, i.e. swapping Ψ changes
    # hidden grads, swapping W_o's transpose does not enter:
    psi2 = init_dfa_feedback(jax.random.PRNGKey(99), CFG)
    _, g1 = D.dfa_grads(params, psi, CFG, x, y)
    _, g2 = D.dfa_grads(params, psi2, CFG, x, y)
    assert float(jnp.abs(g1["w_h"] - g2["w_h"]).max()) > 1e-7


def test_dfa_learns_single_task():
    """DFA + SGD + ζ reaches high accuracy (Fig. 4's software-DFA)."""
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train)
    y = jnp.asarray(task.y_train)

    @jax.jit
    def step(params):
        loss, g = D.dfa_grads(params, psi, CFG, x, y)
        newp, _ = D.sgd_kwta_update(params, g, lr=0.2, keep_frac=0.57,
                                    hidden_lr_scale=0.3)
        return newp, loss

    for _ in range(150):
        params, loss = step(params)
    logits, _ = miru_forward(params, CFG, jnp.asarray(task.x_test))
    acc = float(accuracy(logits, jnp.asarray(task.y_test)))
    assert acc > 0.8, acc


def test_dfa_within_5pct_of_bp():
    """The paper's headline: accuracy within ~5% of the BP baseline."""
    from repro.optim import adam, apply_updates
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train)
    y = jnp.asarray(task.y_train)
    xt = jnp.asarray(task.x_test)
    yt = jnp.asarray(task.y_test)

    p_bp = dict(params)
    opt = adam(1e-3)
    st = opt.init(p_bp)

    @jax.jit
    def bp_step(p, st):
        loss, g = D.bptt_grads(p, CFG, x, y)
        up, st = opt.update(g, st, p)
        return apply_updates(p, up), st

    p_dfa = dict(params)

    @jax.jit
    def dfa_step(p, xb, yb):
        _, g = D.dfa_grads(p, psi, CFG, xb, yb)
        newp, _ = D.sgd_kwta_update(p, g, lr=0.2, keep_frac=0.57,
                                    hidden_lr_scale=0.3)
        return newp

    for _ in range(150):
        p_bp, st = bp_step(p_bp, st)
    rng = np.random.default_rng(0)
    xh = np.asarray(task.x_train)
    yh = np.asarray(task.y_train)
    for _ in range(400):          # SGD needs more passes than Adam
        idx = rng.integers(0, xh.shape[0], 64)
        p_dfa = dfa_step(p_dfa, jnp.asarray(xh[idx]), jnp.asarray(yh[idx]))
    acc_bp = float(accuracy(miru_forward(p_bp, CFG, xt)[0], yt))
    acc_dfa = float(accuracy(miru_forward(p_dfa, CFG, xt)[0], yt))
    assert acc_bp - acc_dfa < 0.07, (acc_bp, acc_dfa)


def test_kwta_update_sparsity_and_masks():
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train[:32])
    y = jnp.asarray(task.y_train[:32])
    _, g = D.dfa_grads(params, psi, CFG, x, y)
    newp, masks = D.sgd_kwta_update(params, g, lr=0.1, keep_frac=0.5)
    frac = float(jnp.mean(masks["w_h"].astype(jnp.float32)))
    assert abs(frac - 0.5) < 0.02
    # Where the mask is zero, the parameter is untouched.
    unchanged = jnp.where(masks["w_h"], 0.0, newp["w_h"] - params["w_h"])
    np.testing.assert_allclose(unchanged, 0.0, atol=0)


def test_time_norm_controls_scale():
    """Without 1/n_T the hidden grad norm scales ~n_T× larger."""
    params, psi, task = _setup()
    x = jnp.asarray(task.x_train[:32])
    y = jnp.asarray(task.y_train[:32])
    _, g_norm = D.dfa_grads(params, psi, CFG, x, y, time_norm=True)
    _, g_raw = D.dfa_grads(params, psi, CFG, x, y, time_norm=False)
    ratio = float(jnp.linalg.norm(g_raw["w_h"])
                  / jnp.linalg.norm(g_norm["w_h"]))
    assert abs(ratio - x.shape[1]) < 1e-3
