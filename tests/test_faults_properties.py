"""Property-based tests for the fault-mask algebra (repro.faults).

Via tests/_hypothesis_compat.py (real hypothesis when installed, the
deterministic mini-runner otherwise):

  * mask application is idempotent — ``apply_cell_faults`` is a
    projection, so read-side and prepare-side masking compose without
    drift;
  * zero-rate masks are bitwise identity for any key and geometry;
  * column remapping never maps two logical columns onto one spare
    (the colmap stays injective) and never increases effective damage.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.faults import (FaultSpec, apply_cell_faults, effective_masks,
                          remap_columns, sample_fault_state)
from tests._hypothesis_compat import given, settings, st


def _tiles(n_in, n_out, spec, seed):
    params = {"w": jnp.zeros((n_in, n_out)),
              "u": jnp.zeros((n_out, n_out))}
    return sample_fault_state(params, jax.random.PRNGKey(seed), spec)


@settings(max_examples=8)
@given(st.integers(2, 24), st.integers(2, 24), st.integers(0, 10_000),
       st.floats(0.0, 0.3), st.floats(0.0, 0.3), st.integers(0, 4))
def test_mask_application_idempotent(n_in, n_out, seed, p0, p1, n_sp):
    spec = FaultSpec(sa0_rate=p0, sa1_rate=p1, dead_col_rate=0.05,
                     n_spare_cols=n_sp)
    fstate = _tiles(n_in, n_out, spec, seed)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_in, n_out))
    once = apply_cell_faults(w, fstate["w"])
    twice = apply_cell_faults(once, fstate["w"])
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=8)
@given(st.integers(2, 32), st.integers(2, 32), st.integers(0, 10_000))
def test_zero_rate_masks_are_bitwise_identity(n_in, n_out, seed):
    fstate = _tiles(n_in, n_out, FaultSpec(), seed)
    for tile in fstate.values():
        assert not np.asarray(tile["stuck"]).any()
    w = jax.random.normal(jax.random.PRNGKey(seed), (n_in, n_out))
    np.testing.assert_array_equal(
        np.asarray(apply_cell_faults(w, fstate["w"])), np.asarray(w))


@settings(max_examples=8)
@given(st.integers(2, 20), st.integers(2, 20), st.integers(1, 5),
       st.integers(0, 10_000), st.floats(0.0, 0.25))
def test_remap_injective_and_never_worse(n_in, n_out, n_sp, seed, rate):
    spec = FaultSpec(sa0_rate=rate, sa1_rate=0.05, dead_col_rate=0.1,
                     n_spare_cols=n_sp)
    fstate = _tiles(n_in, n_out, spec, seed)
    remapped = remap_columns(fstate)
    for name in fstate:
        cm = np.asarray(remapped[name]["colmap"])
        assert len(np.unique(cm)) == len(cm), \
            "two logical columns mapped onto one physical column"
        before = int(np.asarray(effective_masks(fstate[name])[0]).sum())
        after = int(np.asarray(effective_masks(remapped[name])[0]).sum())
        assert after <= before
