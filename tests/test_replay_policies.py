"""repro.replay: the policy registry, policy-equivalence properties,
the scan-carried in-graph (loss_aware) buffer, and the wiring through
ReplaySpec / scenario metadata / telemetry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  build_batch_schedule, run_continual)
from repro.core.replay import ReplayBuffer
from repro.replay import (ReplayPolicy, available_policies,
                          get_policy_class, ingraph_init, ingraph_insert,
                          ingraph_mix, ingraph_sample, make_policy,
                          per_example_ce, register_policy,
                          unregister_policy)
from repro.scenarios import (build_scenario, get_scenario, run_compiled,
                             run_sweep, scenario_miru_config)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_exposes_the_policy_suite():
    names = set(available_policies())
    assert {"reservoir", "ring", "class_balanced", "task_stratified",
            "loss_aware"} <= names


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown replay policy"):
        make_policy("not-a-policy", 8)
    with pytest.raises(ValueError, match="unknown replay policy"):
        build_batch_schedule(
            TrainerSpec(algo="dfa", epochs_per_task=1),
            ReplaySpec(capacity=8, policy="not-a-policy"),
            build_scenario("permuted", 0, n_tasks=1, n_train=32,
                           n_test=16))


def test_register_unregister_roundtrip():
    @register_policy("tmp_pol")
    class _Tmp(ReplayPolicy):
        def select_insert(self, y, task_id=0):
            return 0

        def select_sample(self, rng, batch):
            return np.zeros(batch, np.int64)

        @property
        def occupancy(self):
            return 1

    try:
        assert "tmp_pol" in available_policies()
        assert make_policy("tmp_pol", 4).select_insert(0) == 0
    finally:
        unregister_policy("tmp_pol")
    assert "tmp_pol" not in available_policies()


def test_in_graph_policy_refuses_host_buffer():
    with pytest.raises(ValueError, match="in-graph"):
        ReplayBuffer(8, (4,), policy="loss_aware")


def test_replayspec_policy_resolution():
    assert ReplaySpec().resolved_policy == "reservoir"
    assert ReplaySpec(policy="ring").resolved_policy == "ring"
    # Scenario preference applies only when the caller didn't pin one.
    sc = get_scenario("class_incremental")
    assert sc.replay_policy == "class_balanced"
    assert sc.resolve_replay(None).resolved_policy == "class_balanced"
    assert sc.resolve_replay(
        ReplaySpec(policy="reservoir")).resolved_policy == "reservoir"
    assert get_scenario("permuted").resolve_replay(
        None).resolved_policy == "reservoir"


# ---------------------------------------------------------------------------
# Policy-equivalence properties
# ---------------------------------------------------------------------------

def test_ring_equals_reservoir_for_first_capacity_offers():
    """Both fill slots 0..C-1 in order, consuming identical quantizer
    key chains — buffers are bit-identical until the first post-fill
    offer (where reservoir may reject but ring never does)."""
    C = 16
    res = ReplayBuffer(C, (3, 2), n_bits=4, seed=11, policy="reservoir")
    rin = ReplayBuffer(C, (3, 2), n_bits=4, seed=11, policy="ring")
    rng = np.random.default_rng(2)
    xs = rng.random((C, 3, 2)).astype(np.float32)
    ys = rng.integers(0, 5, C)
    assert res.add_batch(xs, ys) == C
    assert rin.add_batch(xs, ys) == C
    np.testing.assert_array_equal(res._feat, rin._feat)
    np.testing.assert_array_equal(res._label, rin._label)
    np.testing.assert_array_equal(np.asarray(res._qkey),
                                  np.asarray(rin._qkey))
    assert res.size == rin.size == C
    # Past capacity the policies may diverge — ring is deterministic.
    slots = [rin.policy.select_insert(0) for _ in range(C)]
    assert slots == list(range(C))            # FIFO wraps in order


def test_class_balanced_occupancy_invariant_class_incremental():
    """Under a (heavily imbalanced) class-incremental stream: the buffer
    always runs at full capacity once filled, every seen class keeps
    members (early classes are never crowded out), and long-run shares
    balance to within ±1."""
    C, n_classes = 24, 8
    policy = make_policy("class_balanced", C, seed=3, n_classes=n_classes)
    buf = ReplayBuffer(C, (4,), n_bits=4, seed=3, policy=policy)
    rng = np.random.default_rng(0)
    offered = 0
    for t in range(4):                         # classes (2t, 2t+1)
        for _ in range(60 * (t + 1)):          # later classes flood
            y = int(2 * t + rng.integers(0, 2))
            buf.add(rng.random(4).astype(np.float32), y, task_id=t)
            offered += 1
        sizes = policy.group_sizes()
        assert sum(sizes.values()) == min(offered, C)   # full utilization
        assert all(v >= 1 for v in sizes.values())      # nobody starves
    assert set(sizes) == set(range(n_classes))
    assert max(sizes.values()) - min(sizes.values()) <= 1   # ±1 balance
    # Bookkeeping matches storage: each group's slots hold its label.
    for g, slots in policy._members.items():
        assert all(int(buf._label[s]) == g for s in slots)


def test_task_stratified_keeps_every_task_represented():
    C = 20
    policy = make_policy("task_stratified", C, seed=5, n_tasks=5)
    buf = ReplayBuffer(C, (4,), n_bits=4, seed=5, policy=policy)
    rng = np.random.default_rng(1)
    for t in range(5):
        for _ in range(40 * (t + 1)):
            buf.add(rng.random(4).astype(np.float32),
                    int(rng.integers(0, 10)), task_id=t)
    sizes = policy.group_sizes()
    assert set(sizes) == set(range(5))
    assert sum(sizes.values()) == C
    assert max(sizes.values()) - min(sizes.values()) <= 1


def test_balanced_sampling_is_group_uniform():
    """Rehearsal draws are uniform over seen groups even when the stream
    (and therefore a plain reservoir) is dominated by one group."""
    C = 24
    policy = make_policy("class_balanced", C, seed=7, n_classes=3)
    buf = ReplayBuffer(C, (2,), n_bits=4, seed=7, policy=policy)
    rng = np.random.default_rng(3)
    stream = [0] * 500 + [1] * 50 + [2] * 50   # 5:1:1 imbalance
    for y in stream:
        buf.add(rng.random(2).astype(np.float32), y)
    _, labels = buf.sample(rng, 3000)
    hist = np.bincount(labels, minlength=3) / 3000
    assert np.abs(hist - 1 / 3).max() < 0.05   # class-uniform, not 5:1:1


def test_ingraph_schedule_is_fresh_only():
    """loss_aware cannot be materialized: its schedule is the fresh-only
    stream — bitwise the ratio-0 schedule (mixing happens at run time
    from the scan-carried buffer)."""
    tasks = build_scenario("permuted", 0, n_tasks=2, n_train=64, n_test=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, seed=4)
    s_la = build_batch_schedule(tr, ReplaySpec(capacity=32,
                                               policy="loss_aware"), tasks)
    s_r0 = build_batch_schedule(tr, ReplaySpec(capacity=32, ratio=0.0),
                                tasks)
    for a, b in zip(s_la.x + s_la.y, s_r0.x + s_r0.y):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# The in-graph (scan-carried) buffer
# ---------------------------------------------------------------------------

BITS = 4


def _stream(seed, n_steps=8, B=4, shape=(3, 2)):
    kx, kp = jax.random.split(jax.random.PRNGKey(seed))
    xs = jax.random.uniform(kx, (n_steps, B, *shape))
    ys = jnp.arange(n_steps * B).reshape(n_steps, B) % 5
    prios = jax.random.uniform(kp, (n_steps, B))
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(seed + 99), i))(jnp.arange(n_steps))
    return keys, xs, ys, prios


def test_ingraph_insert_scan_bitwise_matches_python_loop():
    """The buffer is a pure function of (state, key, inputs): the same
    step sequence yields bit-identical state whether driven by a Python
    loop of jitted calls or one ``lax.scan`` — the property that makes
    the loop and compiled training paths comparable."""
    C, shape = 12, (3, 2)
    keys, xs, ys, prios = _stream(0)

    step = jax.jit(lambda st, k, x, y, p: ingraph_insert(
        st, k, x, y, p, BITS))
    st_loop = ingraph_init(C, shape, BITS)
    for i in range(xs.shape[0]):
        st_loop = step(st_loop, keys[i], xs[i], ys[i], prios[i])

    def body(st, inp):
        k, x, y, p = inp
        return ingraph_insert(st, k, x, y, p, BITS), None

    st_scan, _ = jax.lax.scan(body, ingraph_init(C, shape, BITS),
                              (keys, xs, ys, prios))
    for name in st_loop:
        np.testing.assert_array_equal(np.asarray(st_loop[name]),
                                      np.asarray(st_scan[name]), name)


def test_ingraph_buffer_bitwise_stable_under_seed_reordering():
    """vmapping the scan over a seed axis must give each seed exactly
    its solo result, regardless of how the seed batch is ordered."""
    C, shape = 10, (3, 2)

    def final_state(seed):
        keys, xs, ys, prios = _stream(0)       # same data stream
        keys = jax.vmap(jax.random.fold_in,
                        in_axes=(0, None))(keys, seed)

        def body(st, inp):
            k, x, y, p = inp
            return ingraph_insert(st, k, x, y, p, BITS), None

        st, _ = jax.lax.scan(body, ingraph_init(C, shape, BITS),
                             (keys, xs, ys, prios))
        return st

    fwd = jax.jit(jax.vmap(final_state))(jnp.array([0, 1, 2]))
    rev = jax.jit(jax.vmap(final_state))(jnp.array([2, 1, 0]))
    solo = jax.jit(final_state)(jnp.asarray(1))
    for name in solo:
        np.testing.assert_array_equal(np.asarray(fwd[name][1]),
                                      np.asarray(rev[name][1]), name)
        np.testing.assert_array_equal(np.asarray(solo[name]),
                                      np.asarray(fwd[name][1]), name)


def test_ingraph_insert_semantics():
    """Fill while free; once full, evict-min-priority only when beaten;
    invalid rows are never offered."""
    C, shape = 4, (2,)
    st = ingraph_init(C, shape, BITS)
    key = jax.random.PRNGKey(0)
    xs = jnp.full((4, 2), 0.5)
    st = ingraph_insert(st, key, xs, jnp.arange(4),
                        jnp.array([3.0, 1.0, 2.0, 4.0]), BITS)
    assert int(st["size"]) == 4
    # Lower than the current min (1.0): rejected.
    st2 = ingraph_insert(st, key, xs[:1], jnp.array([9]),
                         jnp.array([0.5]), BITS)
    np.testing.assert_array_equal(np.asarray(st2["label"]),
                                  np.asarray(st["label"]))
    # Beats the min: replaces exactly the argmin slot (slot 1).
    st3 = ingraph_insert(st, key, xs[:1], jnp.array([9]),
                         jnp.array([1.5]), BITS)
    assert int(st3["label"][1]) == 9
    assert float(st3["prio"][1]) == pytest.approx(1.5)
    # Invalid rows don't enter even with winning priority.
    st4 = ingraph_insert(st, key, xs[:1], jnp.array([9]),
                         jnp.array([9.9]), BITS,
                         valid=jnp.array([False]))
    np.testing.assert_array_equal(np.asarray(st4["label"]),
                                  np.asarray(st["label"]))
    assert int(st4["size"]) == 4


def test_ingraph_sample_prefers_high_priority_and_mix_layout():
    C, shape = 8, (2,)
    st = ingraph_init(C, shape, BITS)
    xs = jnp.tile(jnp.array([[0.25, 0.75]]), (4, 1))
    st = ingraph_insert(st, jax.random.PRNGKey(1), xs, jnp.arange(4),
                        jnp.array([0.01, 0.01, 10.0, 0.01]), BITS)
    _, labels = ingraph_sample(st, jax.random.PRNGKey(2), 200, BITS)
    counts = np.bincount(np.asarray(labels), minlength=4)
    assert counts[2] > 150                      # ∝ priority
    # Mix splices the rehearsal rows into the batch tail, gated on
    # `active`; an inactive mix returns the fresh batch untouched.
    B, n_rep = 6, 2
    x = jnp.zeros((B, 2))
    y = jnp.full((B,), 7)
    xm, ym = ingraph_mix(st, jax.random.PRNGKey(3), x, y, n_rep,
                         jnp.asarray(True), BITS)
    assert np.asarray(ym)[:B - n_rep].tolist() == [7] * (B - n_rep)
    assert set(np.asarray(ym)[B - n_rep:].tolist()) <= {0, 1, 2, 3}
    assert float(jnp.abs(xm[B - n_rep:]).sum()) > 0
    xi, yi = ingraph_mix(st, jax.random.PRNGKey(3), x, y, n_rep,
                         jnp.asarray(False), BITS)
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(y))


def test_per_example_ce_matches_mean_loss():
    from repro.utils import softmax_cross_entropy
    logits = jax.random.normal(jax.random.PRNGKey(0), (16, 5))
    labels = jnp.arange(16) % 5
    per = per_example_ce(logits, labels)
    assert per.shape == (16,)
    assert float(per.mean()) == pytest.approx(
        float(softmax_cross_entropy(logits, labels)), rel=1e-6)


# ---------------------------------------------------------------------------
# End-to-end wiring
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_setup():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    return cfg, TrainerSpec(algo="dfa", epochs_per_task=1), tasks


def test_reservoir_policy_bit_identical_to_default(small_setup):
    """The acceptance gate: ReplaySpec(policy="reservoir") is the
    pre-policy-subsystem behavior bit-for-bit — identical schedules
    (the golden hash in tests/test_determinism.py) and identical
    compiled accuracies to the unspecified-policy default."""
    cfg, trainer, tasks = small_setup
    base = run_compiled(cfg, trainer, tasks,
                        replay=ReplaySpec(capacity=32), device="ideal")
    named = run_compiled(cfg, trainer, tasks,
                         replay=ReplaySpec(capacity=32,
                                           policy="reservoir"),
                         device="ideal")
    np.testing.assert_array_equal(base["R_full"], named["R_full"])
    assert base["MA"] == named["MA"]
    for k in base["params"]:
        np.testing.assert_array_equal(np.asarray(base["params"][k]),
                                      np.asarray(named["params"][k]))


@pytest.mark.parametrize("policy", ["ring", "class_balanced",
                                    "task_stratified", "loss_aware"])
def test_policies_loop_compiled_parity(small_setup, policy):
    """Every policy — host-materialized or scan-carried — returns
    bit-identical accuracies from the Python loop and the compiled
    scan-over-tasks (the reservoir case is the existing
    tests/test_scenarios.py gate)."""
    cfg, trainer, tasks = small_setup
    rspec = ReplaySpec(capacity=32, policy=policy)
    loop = run_continual(cfg, trainer, tasks, replay=rspec, device="ideal")
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    assert comp["compiled"]
    np.testing.assert_array_equal(loop["R"], comp["R"])
    assert loop["MA"] == comp["MA"]


def test_loss_aware_vmapped_seeds(small_setup):
    cfg, trainer, tasks = small_setup
    comp = run_compiled(cfg, trainer, tasks,
                        replay=ReplaySpec(capacity=32,
                                          policy="loss_aware"),
                        device="ideal", seeds=[0, 1])
    assert comp["compiled"]
    single = run_compiled(cfg, dataclasses.replace(trainer, seed=0),
                          tasks, replay=ReplaySpec(capacity=32,
                                                   policy="loss_aware"),
                          device="ideal")
    np.testing.assert_array_equal(comp["per_seed"][0]["R"], single["R"])


@pytest.mark.slow
def test_loss_aware_class_incremental_no_collapse():
    """The task-boundary collapse regression: on the class-incremental
    stream, loss_aware replay must land within 0.10 of class_balanced
    average accuracy. Before class-aware eviction + class-normalized
    sampling, every boundary flooded the buffer with current-task rows
    (fresh CE under a never-seen-these-classes model beats any stored
    score) and ACC collapsed to last-task-only (~0.25 vs ~0.79)."""
    tasks = build_scenario("class_incremental", seed=0, n_tasks=4,
                           n_train=48, n_test=96, imbalance=3.0)
    cfg = scenario_miru_config(tasks, n_h=100)
    trainer = TrainerSpec(algo="adam", epochs_per_task=3)

    def acc(policy):
        out = run_compiled(cfg, trainer, tasks,
                           replay=ReplaySpec(capacity=32, policy=policy),
                           device="ideal")
        return out["metrics"]["average_accuracy"]

    balanced = acc("class_balanced")
    aware = acc("loss_aware")
    assert balanced > 0.6          # the reference policy itself works
    assert aware >= balanced - 0.10, (aware, balanced)


def test_run_sweep_resolves_scenario_policy(small_setup):
    grid = run_sweep(["class_incremental"], ["ideal"],
                     TrainerSpec(algo="dfa", epochs_per_task=1),
                     n_h=16,
                     scenario_kwargs=dict(n_tasks=2, n_train=64,
                                          n_test=32))
    cell = grid["cells"]["class_incremental/ideal"]
    assert cell["replay_policy"] == "class_balanced"
    # An explicit caller choice overrides the scenario preference.
    grid2 = run_sweep(["class_incremental"], ["ideal"],
                      TrainerSpec(algo="dfa", epochs_per_task=1),
                      ReplaySpec(capacity=48, policy="reservoir"),
                      n_h=16,
                      scenario_kwargs=dict(n_tasks=2, n_train=64,
                                           n_test=32))
    assert grid2["cells"]["class_incremental/ideal"][
        "replay_policy"] == "reservoir"


def test_replay_dram_traffic_metered():
    """Host-buffer inserts and rehearsal draws land in the replay_*
    telemetry counters with the right byte accounting (4-bit codes in a
    uint8 container + int32 label)."""
    from repro.telemetry.meters import Telemetry
    tele = Telemetry(enabled=True)
    buf = ReplayBuffer(8, (4,), n_bits=4, seed=1, telemetry=tele)
    rng = np.random.default_rng(0)
    buf.add_batch(rng.random((8, 4)).astype(np.float32),
                  np.arange(8))
    buf.sample(rng, 5)
    snap = tele.snapshot()
    row_bytes = 4 * 1 + 4
    assert snap["replay_writes"] == 8
    assert snap["replay_write_bytes"] == 8 * row_bytes
    assert snap["replay_reads"] == 5
    assert snap["replay_read_bytes"] == 5 * row_bytes
    # The report surfaces the traffic as off-chip DRAM energy.
    from repro.telemetry.energy import replay_traffic
    rep = replay_traffic(snap)
    assert rep["bytes"] == 13 * row_bytes
    assert rep["dram_energy_j"] > 0
    assert replay_traffic({}) is None