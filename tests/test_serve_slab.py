"""Property suite for the serve engine's state slab (serve/slab.py).

Slot-allocator invariants under adversarial op sequences: free-list
conservation, no double occupancy, LRU book consistency, pin safety —
plus the serving-critical guarantee that evict → reload round-trips a
user's hidden state bit-identically.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serve.slab import SlabFullError, StateSlab

N_H = 7


def _fill(slab: StateSlab, uid, seed: int) -> np.ndarray:
    """Write a distinctive full-precision row for ``uid`` and return it."""
    rng = np.random.default_rng(seed)
    row = rng.standard_normal(slab.n_h).astype(np.float32)
    slab.h = slab.h.at[slab.slot(uid)].set(jnp.asarray(row))
    return row


# ---------------------------------------------------------------------------
# Deterministic unit behavior
# ---------------------------------------------------------------------------

def test_acquire_is_idempotent_and_slots_distinct():
    slab = StateSlab(4, N_H)
    slots = {u: slab.acquire(u) for u in "abcd"}
    assert sorted(slots.values()) == [0, 1, 2, 3]
    for u in "abcd":
        assert slab.acquire(u) == slots[u]   # resident: same slot back
    slab.check()
    assert slab.n_free == 0


def test_new_user_gets_zero_state_even_in_recycled_slot():
    slab = StateSlab(1, N_H)
    slab.acquire("a")
    _fill(slab, "a", seed=0)
    slab.release("a")                        # departed, state dropped
    slab.acquire("b")                        # recycles slot 0
    assert np.array_equal(slab.read("b"), np.zeros(N_H, np.float32))


def test_evict_reload_bit_identity():
    slab = StateSlab(2, N_H)
    slab.acquire("a")
    row = _fill(slab, "a", seed=1)
    slab.evict("a")
    assert not slab.is_resident("a") and "a" in slab.spilled
    # Churn the slab while 'a' is spilled.
    for u in ("b", "c", "d"):
        slab.acquire(u)
        _fill(slab, u, seed=hash(u) % 100)
    slab.acquire("a")
    assert np.array_equal(slab.read("a"), row)      # bitwise
    assert slab.reloads == 1
    slab.check()


def test_lru_eviction_order_respects_touch():
    slab = StateSlab(3, N_H)
    for u in ("a", "b", "c"):
        slab.acquire(u)
    slab.touch("a")                          # a becomes MRU: order b, c, a
    slab.acquire("d")                        # evicts b (LRU)
    assert "b" in slab.spilled
    assert slab.resident == ("c", "a", "d")
    slab.acquire("e")                        # evicts c
    assert "c" in slab.spilled
    slab.check()


def test_pinned_streams_are_never_evicted():
    slab = StateSlab(2, N_H)
    slab.acquire("a")
    slab.pin("a")
    slab.acquire("b")
    slab.pin("b")
    assert not slab.can_acquire("c")
    with pytest.raises(SlabFullError):
        slab.acquire("c")
    slab.unpin("a")                          # a unpinned → evictable
    assert slab.can_acquire("c")
    slab.acquire("c")
    assert "a" in slab.spilled and slab.is_resident("b")
    slab.check()


def test_pin_non_resident_raises():
    slab = StateSlab(2, N_H)
    with pytest.raises(KeyError):
        slab.pin("ghost")
    slab.acquire("a")
    slab.evict("a")
    with pytest.raises(KeyError):
        slab.pin("a")                        # spilled is not resident


def test_evict_pinned_raises_and_release_unpins():
    slab = StateSlab(2, N_H)
    slab.acquire("a")
    slab.pin("a")
    with pytest.raises(ValueError):
        slab.evict("a")
    slab.release("a")                        # release drops the pin too
    slab.acquire("b")
    slab.pin("b")
    slab.check()
    assert slab.n_free == 1


# ---------------------------------------------------------------------------
# Property: invariants hold under adversarial op sequences
# ---------------------------------------------------------------------------

_OPS = ("acquire", "release", "evict", "pin", "unpin", "touch")


@settings(max_examples=12)
@given(st.integers(1, 5), st.integers(0, 10_000), st.data())
def test_slab_invariants_under_random_ops(n_slots, seed, data):
    """Any sequence of slab operations preserves the structural
    invariants: every slot free xor occupied by exactly one uid, the LRU
    book tracks exactly the resident set, spilled ∩ resident = ∅,
    pinned ⊆ resident — and eviction round-trips state bitwise."""
    rng = np.random.default_rng(seed)
    slab = StateSlab(n_slots, N_H)
    uids = [f"u{i}" for i in range(2 * n_slots + 2)]
    shadow: dict = {}                       # uid → expected row
    for step in range(40):
        op = _OPS[int(rng.integers(len(_OPS)))]
        uid = uids[int(rng.integers(len(uids)))]
        if op == "acquire":
            if slab.can_acquire(uid):
                was_tracked = slab.is_resident(uid) or uid in slab.spilled
                slab.acquire(uid)
                if not was_tracked:
                    # fresh residency: give it a distinctive row
                    shadow[uid] = _fill(slab, uid, seed=step)
            else:
                with pytest.raises(SlabFullError):
                    slab.acquire(uid)
        elif op == "release":
            slab.release(uid)
            shadow.pop(uid, None)
        elif op == "evict":
            if slab.is_resident(uid) and uid not in slab._pinned:
                slab.evict(uid)
        elif op == "pin":
            if slab.is_resident(uid):
                slab.pin(uid)
        elif op == "unpin":
            slab.unpin(uid)
        elif op == "touch":
            if slab.is_resident(uid):
                slab.touch(uid)
        slab.check()
        # the uid the op touched keeps its state bitwise
        if uid in shadow and (slab.is_resident(uid) or uid in slab.spilled):
            assert np.array_equal(slab.read(uid), shadow[uid]), \
                f"state of {uid} corrupted by {op}"
    # final sweep: every surviving uid's state is bit-identical
    for u, row in shadow.items():
        if slab.is_resident(u) or u in slab.spilled:
            assert np.array_equal(slab.read(u), row), \
                f"state of {u} corrupted by churn"


@settings(max_examples=8)
@given(st.integers(1, 4), st.integers(0, 10_000))
def test_free_list_conservation_under_churn(n_slots, seed):
    """#free + #resident == n_slots at every point, and acquire after
    arbitrary churn always succeeds while any slot is unpinned."""
    rng = np.random.default_rng(seed)
    slab = StateSlab(n_slots, N_H)
    for i in range(60):
        uid = f"u{int(rng.integers(0, 3 * n_slots))}"
        if rng.integers(2) and slab.is_resident(uid):
            slab.release(uid)
        else:
            slab.acquire(uid)               # nothing pinned: always room
        assert slab.n_free + len(slab.resident) == slab.n_slots
        slab.check()
