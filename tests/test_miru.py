"""MiRU cell semantics (eqs. 1-3) and compactness claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.miru import (MiRUConfig, gru_param_count, init_dfa_feedback,
                             init_miru_params, miru_cell, miru_forward,
                             miru_param_count)


def _cfg(**kw):
    base = dict(n_x=12, n_h=32, n_y=5, beta=0.8, lam=0.5)
    base.update(kw)
    return MiRUConfig(**base)


def test_cell_equations():
    """One step matches eqs. (1)-(2) computed by hand."""
    cfg = _cfg()
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.n_h))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, cfg.n_x))
    h_new, pre = miru_cell(params, cfg, h, x)
    pre_hand = x @ params["w_h"] + (cfg.beta * h) @ params["u_h"] \
        + params["b_h"]
    h_hand = cfg.lam * h + (1 - cfg.lam) * jnp.tanh(pre_hand)
    np.testing.assert_allclose(pre, pre_hand, rtol=1e-6)
    np.testing.assert_allclose(h_new, h_hand, rtol=1e-6)


def test_forward_shapes_and_intermediates():
    cfg = _cfg()
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 7, cfg.n_x))
    logits, aux = miru_forward(params, cfg, x)
    assert logits.shape == (4, cfg.n_y)
    assert aux["h_all"].shape == (4, 7, cfg.n_h)
    assert aux["h_prev"].shape == (4, 7, cfg.n_h)
    # h_prev is h_all shifted by one (h⁰ = 0).
    np.testing.assert_allclose(aux["h_prev"][:, 1:], aux["h_all"][:, :-1],
                               rtol=1e-6)
    np.testing.assert_allclose(aux["h_prev"][:, 0], 0.0, atol=0)


def test_lam_extremes():
    """λ→0: h = tanh path only; λ large: h barely moves (paper §II-B)."""
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 5, 12))
    cfg0 = _cfg(lam=0.0)
    params = init_miru_params(jax.random.PRNGKey(0), cfg0)
    _, aux0 = miru_forward(params, cfg0, x)
    pre0 = aux0["pre"]
    np.testing.assert_allclose(aux0["h_all"], jnp.tanh(pre0), rtol=1e-6)

    cfg9 = _cfg(lam=0.95)
    _, aux9 = miru_forward(params, cfg9, x)
    # With strong update coefficient the state changes slowly.
    assert float(jnp.abs(jnp.diff(aux9["h_all"], axis=1)).max()) < \
        float(jnp.abs(jnp.diff(aux0["h_all"], axis=1)).max())


def test_beta_zero_limit():
    """β→0 removes history from the candidate (paper: 'hidden activation
    becomes almost entirely dependent on the current input')."""
    cfg = _cfg(beta=1e-6)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 4, cfg.n_x))
    _, aux = miru_forward(params, cfg, x)
    pre_direct = x @ params["w_h"] + params["b_h"]
    np.testing.assert_allclose(aux["pre"], pre_direct, atol=1e-4)


def test_param_count_vs_gru():
    """MiRU removes the two gate weight sets: ~3× fewer recurrent-core
    parameters than GRU (the paper's compactness claim)."""
    cfg = _cfg(n_x=28, n_h=100, n_y=10)
    miru_n = miru_param_count(cfg)
    gru_n = gru_param_count(28, 100, 10)
    core_miru = 28 * 100 + 100 * 100 + 100
    core_gru = 3 * core_miru
    assert gru_n - miru_n == core_gru - core_miru
    assert miru_n == 28 * 100 + 100 * 100 + 100 + 100 * 10 + 10


def test_invalid_coefficients_rejected():
    with pytest.raises(ValueError):
        _cfg(beta=0.0)
    with pytest.raises(ValueError):
        _cfg(lam=1.0)


def test_kwta_readout():
    cfg = _cfg(readout_k=2)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 6, cfg.n_x))
    logits, _ = miru_forward(params, cfg, x)
    probs = jax.nn.softmax(logits, axis=-1)
    # Only ~k classes carry probability mass.
    mass_top2 = jnp.sort(probs, axis=-1)[:, -2:].sum(-1)
    assert float(mass_top2.min()) > 0.99


@settings(max_examples=15, deadline=None)
@given(st.floats(0.05, 1.0), st.floats(0.0, 0.95))
def test_state_bounded(beta, lam):
    """Hidden state stays in (-1, 1): convex combos of tanh outputs."""
    cfg = _cfg(beta=beta, lam=lam)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.n_x))
    _, aux = miru_forward(params, cfg, x)
    assert float(jnp.abs(aux["h_all"]).max()) <= 1.0


def test_psi_shape_and_frozen_scale():
    cfg = _cfg()
    psi = init_dfa_feedback(jax.random.PRNGKey(3), cfg)
    assert psi.shape == (cfg.n_y, cfg.n_h)
    assert 0.1 < float(jnp.std(psi)) < 1.0
