"""Cross-layer integration: the paper's features inside the LM substrate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine


def test_wbs_quantized_lm_forward():
    """QuantMode.WBS: every projection routed through the paper's
    weighted-bit-streaming crossbar kernel — the M2RU crossbar as a
    deployable quantized execution mode (DESIGN.md §4)."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.arange(2 * 8).reshape(2, 8) % cfg.vocab
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((2, 8), jnp.float32)}
    ref_logits = lm.forward(params, cfg, batch)

    wbs_cfg = dataclasses.replace(cfg, quant_mode="wbs")
    wbs_logits = lm.forward(params, wbs_cfg, batch)
    assert bool(jnp.isfinite(wbs_logits).all())
    # 8-bit activations: quantized forward tracks the float forward.
    denom = float(jnp.abs(ref_logits).max())
    rel = float(jnp.abs(wbs_logits - ref_logits).max()) / denom
    assert rel < 0.15, rel
    # Argmax predictions overwhelmingly agree.
    agree = float(jnp.mean(
        (ref_logits.argmax(-1) == wbs_logits.argmax(-1))
        .astype(jnp.float32)))
    assert agree > 0.8, agree


def test_serve_engine_ssm():
    """Slot engine over the attention-free arch (SSM state caches)."""
    cfg = get_smoke_config("mamba2-370m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=32,
                                       eos_token=-1), params)
    reqs = [eng.submit([3, 1, 4, 1, 5], max_new=6),
            eng.submit([2, 7, 1, 8], max_new=6),
            eng.submit([9, 9, 9], max_new=6)]
    eng.run_until_drained()
    assert all(r.done and len(r.tokens) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.tokens)


def test_serve_engine_moe():
    """Slot engine over an MoE arch (router inside decode)."""
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=24,
                                       eos_token=-1), params)
    req = eng.submit([5, 6, 7], max_new=5)
    eng.run_until_drained()
    assert req.done and len(req.tokens) == 5


def test_trainer_on_ssm_arch(tmp_path):
    """Production trainer end-to-end on the SSD stack."""
    from repro.data.pipeline import ShardedBatcher
    from repro.data.synthetic import lm_token_batch
    from repro.train import TrainConfig, Trainer
    cfg = get_smoke_config("mamba2-370m")

    def gen(rng, step):
        return lm_token_batch(rng, 4, 24, cfg.vocab)

    tcfg = TrainConfig(steps=30, lr=2e-3, warmup_steps=3,
                       checkpoint_every=1000, log_every=1000,
                       checkpoint_dir=str(tmp_path))
    t = Trainer(cfg, tcfg, ShardedBatcher(gen, seed=0))
    hist = t.run()
    assert np.isfinite([h["loss"] for h in hist]).all()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_miru_fused_kernel_in_training():
    """The Pallas miru_scan kernel inside a jitted DFA training step."""
    from repro.core.dfa import dfa_grads, sgd_kwta_update
    from repro.core.miru import (MiRUConfig, init_dfa_feedback,
                                 init_miru_params, miru_forward)
    cfg = MiRUConfig(n_x=12, n_h=32, n_y=4)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    psi = init_dfa_feedback(jax.random.PRNGKey(1), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(2), (8, 6, 12))
    y = jnp.arange(8) % 4

    @jax.jit
    def step(p):
        loss, g = dfa_grads(p, psi, cfg, x, y, use_fused=True)
        newp, _ = sgd_kwta_update(p, g, 0.2, 0.57, 0.3)
        return newp, loss

    p = params
    losses = []
    for _ in range(15):
        p, loss = step(p)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    # Fused and unfused forwards agree on the trained params.
    lf, _ = miru_forward(p, cfg, x, use_fused=True)
    lu, _ = miru_forward(p, cfg, x, use_fused=False)
    np.testing.assert_allclose(lf, lu, rtol=1e-4, atol=1e-4)
