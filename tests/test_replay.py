"""Reservoir sampler, stochastic quantizer, replay buffer (§IV-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.replay import (ReplayBuffer, ReservoirSampler, Xorshift32,
                               code_dtype, dequantize,
                               lfsr_stochastic_quantize, round_trip_bound,
                               stochastic_quantize, uniform_quantize)


# ---------------------------------------------------------------------------
# Xorshift32
# ---------------------------------------------------------------------------

def test_xorshift_known_sequence():
    """13/17/5 xorshift from seed 1 — classic known values."""
    rng = Xorshift32(1)
    assert rng.next() == 270369
    assert rng.next() == 67634689


def test_xorshift_uniformity():
    rng = Xorshift32(12345)
    vals = np.array([rng.randint(0, 9) for _ in range(20000)])
    counts = np.bincount(vals, minlength=10)
    # Each bucket within 10% of expectation — xorshift is unbiased
    # (the paper's reason for rejecting an LFSR).
    assert np.abs(counts - 2000).max() < 200


def test_xorshift_modulus_bias_bound_vs_rejection_mode():
    """The hardware-faithful modulus reducer carries modulo bias; the
    rejection mode does not. The analytic bound on the faithful path:
    per value, |P(v) − 1/span| ≤ 2⁻³²  (negligible for small spans —
    test_xorshift_uniformity's span of 10), but residues below
    r = 2³² mod span are overweighted by ⌈2³²/span⌉/⌊2³²/span⌋, which
    approaches 2× as span → 2³². At span = 3·2³⁰ (r = 2³⁰) the biased
    path puts probability 1/2 — not 1/3 — on values below 2³⁰; the
    rejection path restores 1/3."""
    span = 3 * 2 ** 30
    n = 4000
    faithful = Xorshift32(123)
    frac_f = np.mean([faithful.randint(0, span - 1) < 2 ** 30
                      for _ in range(n)])
    unbiased = Xorshift32(123, mode="reject")
    frac_u = np.mean([unbiased.randint(0, span - 1) < 2 ** 30
                      for _ in range(n)])
    assert abs(frac_f - 0.5) < 0.04       # the documented 2× overweight
    assert abs(frac_u - 1 / 3) < 0.04     # rejection: exactly uniform


def test_reject_mode_does_not_alter_the_word_stream():
    """mode='reject' changes only how words reduce to a range; the raw
    13/17/5 stream (which hardware-equivalence seeds pin) is untouched,
    and the default mode stays 'modulus'."""
    assert Xorshift32(1, mode="reject").next() == 270369
    assert Xorshift32(1).mode == "modulus"
    assert ReservoirSampler(capacity=4, seed=3)._rng.mode == "modulus"
    with pytest.raises(ValueError, match="unknown randint mode"):
        Xorshift32(1, mode="sometimes")


# ---------------------------------------------------------------------------
# Reservoir sampler
# ---------------------------------------------------------------------------

def test_reservoir_fills_then_replaces():
    s = ReservoirSampler(capacity=8, seed=3)
    first = [s.offer() for _ in range(8)]
    assert first == list(range(8))          # fills in order
    later = [s.offer() for _ in range(100)]
    kept = [x for x in later if x is not None]
    assert all(0 <= x < 8 for x in kept)
    assert 0 < len(kept) < 100              # some kept, some rejected


def test_reservoir_uniform_inclusion():
    """After a long stream, every element has ≈k/n inclusion probability.
    Statistical test over many independent streams."""
    n, k, trials = 60, 10, 400
    hits = np.zeros(n)
    for t in range(trials):
        s = ReservoirSampler(capacity=k, seed=1000 + t)
        buf = [-1] * k
        for i in range(n):
            slot = s.offer()
            if slot is not None:
                buf[slot] = i
        for v in buf:
            if v >= 0:
                hits[v] += 1
    p = hits / trials
    expected = k / n
    # Mean inclusion close to k/n across positions (± 4 σ binomial).
    sigma = np.sqrt(expected * (1 - expected) / trials)
    assert np.abs(p.mean() - expected) < 2 * sigma
    assert np.abs(p - expected).max() < 6 * sigma


# ---------------------------------------------------------------------------
# Stochastic quantizer (eqs. 4-6)
# ---------------------------------------------------------------------------

def test_stochastic_quantize_unbiased():
    x = jnp.full((200_000,), 0.37)
    q = stochastic_quantize(x, jax.random.PRNGKey(0), 4)
    deq = dequantize(q, 4)
    # E[deq] == x (unbiased); truncation would give floor error ~1/16.
    assert abs(float(deq.mean()) - 0.37) < 1e-3
    tr = dequantize(uniform_quantize(x, 4), 4)
    assert abs(float(tr.mean()) - 0.37) > 0.015


def test_quantize_range_and_codes():
    x = jnp.linspace(0, 1, 1000)
    q = stochastic_quantize(x, jax.random.PRNGKey(1), 4)
    assert q.dtype == jnp.uint8
    assert int(q.max()) <= 15
    assert int(q.min()) >= 0


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 1.0), st.sampled_from([2, 4, 8]))
def test_quantize_error_bounded(val, bits):
    x = jnp.full((64,), val)
    deq = dequantize(stochastic_quantize(x, jax.random.PRNGKey(3), bits),
                     bits)
    assert float(jnp.abs(deq - val).max()) <= 1.0 / 2 ** bits + 1e-6


def test_stochastic_quantize_unbiased_away_from_top_code():
    """E[dequantize(q)] = x exactly for x ≤ 1 − 2⁻ⁿ (the property the
    'unbiased' claim actually holds on); inside the clip region the
    expectation pins at 1 − 2⁻ⁿ with the worst case round_trip_bound(n)
    at x = 1.0 — a replayed 1.0 pixel always comes back one LSB dim."""
    n = 50_000
    for bits in (2, 4):
        top_safe = 1.0 - 2.0 ** -bits
        for v in np.linspace(0.0, top_safe, 5):
            x = jnp.full((n,), float(v))
            deq = dequantize(stochastic_quantize(
                x, jax.random.PRNGKey(int(v * 997) + bits), bits), bits)
            # mean of n Bernoulli-rounded codes: 4σ ≤ LSB·2/√n
            tol = 2.0 ** -bits * 2.0 / np.sqrt(n) + 1e-6
            assert abs(float(deq.mean()) - v) < tol, (bits, v)
        # Clip region: x = 1.0 deterministically hits the top code.
        q_top = stochastic_quantize(jnp.ones((64,)),
                                    jax.random.PRNGKey(9), bits)
        assert int(q_top.min()) == 2 ** bits - 1
        err = 1.0 - float(dequantize(q_top, bits)[0])
        assert err == pytest.approx(round_trip_bound(bits))
        # The bound is tight: nothing errs worse anywhere in [0, 1].
        xs = jnp.linspace(0.0, 1.0, 257)
        deq = dequantize(stochastic_quantize(
            xs, jax.random.PRNGKey(3), bits), bits)
        assert float(jnp.abs(deq - xs).max()) <= \
            round_trip_bound(bits) + 1e-6


def test_lfsr_rounder_matches_semantics():
    """Hardware LFSR rounder: output codes within 1 LSB of input scale."""
    x = np.linspace(0, 0.95, 37)
    q = lfsr_stochastic_quantize(x, 4, seed=5)
    deq = q / 16.0
    assert np.abs(deq - x).max() <= 1 / 16 + 1e-9


def test_vmm_error_stochastic_vs_uniform():
    """Fig. 5a: stochastic 4-bit keeps VMM error < ~5 %, below uniform."""
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    exact = x @ w
    ref = float(jnp.abs(exact).mean())
    q_s = dequantize(stochastic_quantize(x, jax.random.PRNGKey(2), 4), 4)
    q_u = dequantize(uniform_quantize(x, 4), 4)
    err_s = float(jnp.abs(q_s @ w - exact).mean()) / ref
    err_u = float(jnp.abs(q_u @ w - exact).mean()) / ref
    assert err_s < 0.05
    assert err_s < err_u


# ---------------------------------------------------------------------------
# Replay buffer
# ---------------------------------------------------------------------------

def test_replay_buffer_end_to_end():
    buf = ReplayBuffer(capacity=32, feature_shape=(7, 4), n_bits=4)
    rng = np.random.default_rng(0)
    xs = rng.random((100, 7, 4)).astype(np.float32)
    ys = rng.integers(0, 10, 100)
    added = buf.add_batch(xs, ys)
    assert buf.size == 32
    assert added >= 32
    feats, labels = buf.sample(rng, 16)
    assert feats.shape == (16, 7, 4)
    assert feats.min() >= 0 and feats.max() <= 1
    assert labels.shape == (16,)


def test_add_batch_bit_identical_to_sequential_adds():
    """The vectorized add_batch (one chained-key scan + one vmapped
    quantize) must walk exactly the per-example path: same reservoir
    slots, same key chain, same quantizer draws, same final key."""
    seq = ReplayBuffer(capacity=37, feature_shape=(4, 5), n_bits=4, seed=7)
    vec = ReplayBuffer(capacity=37, feature_shape=(4, 5), n_bits=4, seed=7)
    rng = np.random.default_rng(0)
    for _ in range(6):
        xs = rng.random((23, 4, 5)).astype(np.float32)
        ys = rng.integers(0, 10, 23)
        added_seq = sum(bool(seq.add(x, int(y))) for x, y in zip(xs, ys))
        assert vec.add_batch(xs, ys) == added_seq
    np.testing.assert_array_equal(vec._feat, seq._feat)
    np.testing.assert_array_equal(vec._label, seq._label)
    assert vec.size == seq.size
    np.testing.assert_array_equal(np.asarray(vec._qkey),
                                  np.asarray(seq._qkey))


def test_feat_dtype_sized_by_bits_12bit_roundtrip():
    """Regression: storage dtype must follow n_bits. A hard-coded uint8
    container silently truncated the high bits of 9–16-bit codes
    (stochastic_quantize returns uint16 there); a 12-bit buffer must
    round-trip within one 12-bit LSB."""
    assert code_dtype(4) == np.uint8
    assert code_dtype(8) == np.uint8
    assert code_dtype(12) == np.uint16
    assert code_dtype(16) == np.uint16
    with pytest.raises(ValueError):
        code_dtype(17)
    buf = ReplayBuffer(capacity=16, feature_shape=(5,), n_bits=12, seed=3)
    rng = np.random.default_rng(0)
    xs = rng.random((16, 5)).astype(np.float32)
    assert buf.add_batch(xs, np.arange(16)) == 16
    assert buf._feat.dtype == np.uint16
    assert int(buf._feat.max()) > 255          # high bits actually stored
    # First 16 offers fill slots in order, so storage aligns with xs.
    deq = buf._feat.astype(np.float32) / 2.0 ** 12
    assert np.abs(deq - xs).max() <= 2.0 ** -12 + 1e-7


def test_replay_buffer_memory_halved():
    """8→4-bit storage: the paper's 2× memory claim (uint8 container with
    4-bit codes would pack 2/byte in RTL; here we assert code range)."""
    buf = ReplayBuffer(capacity=16, feature_shape=(28, 28), n_bits=4)
    rng = np.random.default_rng(1)
    buf.add_batch(rng.random((20, 28, 28)).astype(np.float32),
                  np.zeros(20, np.int64))
    assert buf._feat.max() <= 15   # fits in 4 bits


def test_replay_empty_raises():
    buf = ReplayBuffer(capacity=4, feature_shape=(2,))
    with pytest.raises(ValueError):
        buf.sample(np.random.default_rng(0), 1)
