"""core.kwta — exact ζ semantics and softmax approximation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kwta import kwta, kwta_global, kwta_mask, kwta_softmax


def test_mask_counts_exact_with_ties():
    x = jnp.array([[3.0, 1.0, 1.0, 1.0, 0.5]])
    m = kwta_mask(x, 3, by_magnitude=False)
    assert int(m.sum()) == 3
    assert bool(m[0, 0])
    # ties broken by position: indices 1,2 admitted, 3 not.
    assert bool(m[0, 1]) and bool(m[0, 2]) and not bool(m[0, 3])


def test_by_magnitude_keeps_large_negatives():
    x = jnp.array([-5.0, 0.1, 4.0, -0.2])
    y = kwta(x, k=2, axis=0)
    np.testing.assert_array_equal(np.nonzero(np.asarray(y))[0], [0, 2])


def test_keep_frac():
    x = jnp.arange(1.0, 101.0)
    y = kwta(x, keep_frac=0.57, axis=0)
    assert int((y != 0).sum()) == 57


def test_kwta_global_flattens():
    x = jax.random.normal(jax.random.PRNGKey(0), (10, 10))
    y = kwta_global(x, 0.25)
    assert int((y != 0).sum()) == 25
    thr = jnp.sort(jnp.abs(x).reshape(-1))[-25]
    assert float(jnp.abs(y[y != 0]).min()) >= float(thr) - 1e-7


def test_kwta_softmax_mass():
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 10))
    p = kwta_softmax(logits, 3)
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert (np.count_nonzero(np.asarray(p) > 1e-8, axis=1) <= 3).all()


def test_k_edge_cases():
    x = jnp.array([1.0, -2.0, 3.0])
    np.testing.assert_array_equal(kwta(x, k=3, axis=0), x)
    np.testing.assert_array_equal(kwta(x, k=0, axis=0), jnp.zeros(3))
    with pytest.raises(ValueError):
        kwta(x)                       # neither k nor keep_frac
    with pytest.raises(ValueError):
        kwta(x, k=1, keep_frac=0.5)   # both


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(2, 64), st.data())
def test_winners_are_topk(r, n, data):
    k = data.draw(st.integers(1, n))
    x = jax.random.normal(jax.random.PRNGKey(r * 131 + n), (r, n))
    y = kwta(x, k=k)
    mag = np.abs(np.asarray(x))
    for row in range(r):
        nz = np.nonzero(np.asarray(y[row]))[0]
        assert len(nz) == k
        kth = np.sort(mag[row])[-k]
        assert (mag[row][nz] >= kth - 1e-7).all()
