"""repro.fleet — sharded device-fleet simulation.

Pins the subsystem's three contracts:

  * zero-heterogeneity parity: a fleet run with ``het_profile="none"``
    is bit-identical to ``run_compiled``'s seed-vmapped path on the same
    Xorshift32-derived seeds (the fleet axis adds no arithmetic);
  * mesh-shape invariance: the same fleet over 1/2/8 emulated host
    devices returns identical results and telemetry (subprocess — the
    device count must be set before jax imports);
  * per-device independence: Xorshift32 seed streams are pairwise
    distinct and fleet-seed-keyed; heterogeneity draws are deterministic
    and strictly positive.

Plus the prepared-weights cache (backends hoist the per-forward weight
pad/scale out of the per-step loop) staying bitwise-neutral.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core.continual import ReplaySpec, TrainerSpec
from repro.fleet import (HET_PROFILES, FleetSpec, device_seeds,
                         distribution, draw_heterogeneity, fleet_aggregate,
                         fleet_shard_count, run_fleet,
                         supports_heterogeneity)
from repro.scenarios import build_scenario, run_compiled
from repro.scenarios.sweep import scenario_miru_config

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def small_setup():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    return cfg, TrainerSpec(algo="dfa", epochs_per_task=1), tasks


# ---------------------------------------------------------------------------
# FleetSpec / seed streams / heterogeneity draws
# ---------------------------------------------------------------------------

def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="n_devices"):
        FleetSpec(n_devices=0)
    with pytest.raises(ValueError, match="het_profile"):
        FleetSpec(het_profile="extreme")
    assert FleetSpec(het_profile="mild").profile is HET_PROFILES["mild"]


def test_device_seeds_distinct_and_keyed():
    """The Xorshift32 chain gives pairwise-distinct per-device streams,
    reproducibly keyed on the fleet seed."""
    a = device_seeds(FleetSpec(n_devices=64, seed=0))
    assert len(set(a)) == 64
    assert a == device_seeds(FleetSpec(n_devices=64, seed=0))
    b = device_seeds(FleetSpec(n_devices=64, seed=1))
    assert set(a).isdisjoint(set(b))
    # Prefix property: a bigger fleet extends, not reshuffles.
    assert device_seeds(FleetSpec(n_devices=8, seed=0)) == a[:8]


def test_heterogeneity_draws():
    assert draw_heterogeneity(FleetSpec(het_profile="none")) is None
    spec = FleetSpec(n_devices=32, het_profile="mild", seed=5)
    het = draw_heterogeneity(spec)
    assert set(het) == {"prog_sigma", "read_sigma", "write_sigma",
                        "drift_rate"}
    for name, v in het.items():
        assert v.shape == (32,) and v.dtype == jnp.float32
        assert np.all(np.asarray(v) > 0), name          # physical sigmas
        assert np.std(np.asarray(v)) > 0, name          # actual spread
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(draw_heterogeneity(spec)[name]))
    harsh = draw_heterogeneity(
        FleetSpec(n_devices=32, het_profile="harsh", seed=5))
    assert np.asarray(harsh["read_sigma"]).mean() \
        > np.asarray(het["read_sigma"]).mean()


def test_supports_heterogeneity():
    assert supports_heterogeneity(get_backend("analog_state"))
    assert not supports_heterogeneity(get_backend("ideal"))


def test_fleet_shard_count():
    # 1 host device in-process: always 1 shard.
    assert fleet_shard_count(8) == max(
        d for d in range(1, min(len(jax.devices()), 8) + 1) if 8 % d == 0)
    assert fleet_shard_count(8, max_shards=1) == 1
    assert fleet_shard_count(1) == 1


# ---------------------------------------------------------------------------
# Zero-heterogeneity parity with run_compiled
# ---------------------------------------------------------------------------

def test_zero_het_parity_with_run_compiled(small_setup):
    """het_profile="none" attaches nothing to the device-state pytree,
    so the fleet program is run_compiled's seed-vmapped program — the
    results must match bit for bit, per device."""
    cfg, trainer, tasks = small_setup
    fleet = FleetSpec(n_devices=3, het_profile="none", seed=11)
    seeds = device_seeds(fleet)
    fl = run_fleet(cfg, trainer, tasks, fleet,
                   replay=ReplaySpec(capacity=32), device="ideal")
    rc = run_compiled(cfg, trainer, tasks, replay=ReplaySpec(capacity=32),
                      device="ideal", seeds=seeds)
    assert fl["device_seeds"] == seeds
    for i in range(3):
        np.testing.assert_array_equal(
            fl["per_device"][i]["R_full"], rc["per_seed"][i]["R_full"])
        assert fl["per_device"][i]["losses"] \
            == rc["per_seed"][i]["losses"]
    # Device 0's final params are the seed-0 run's final params.
    for name, v in rc["params"].items():
        np.testing.assert_array_equal(
            np.asarray(fl["params"][name]), np.asarray(v), name)
    assert fl["metrics"] == rc["metrics"]


def test_heterogeneous_fleet_differs_across_devices(small_setup):
    """A mild-profile fleet on the conductance-domain backend: runs end
    to end, per-chip results actually differ (the draws bite), and the
    het overlay is reported."""
    cfg, trainer, tasks = small_setup
    fleet = FleetSpec(n_devices=2, het_profile="mild", seed=4)
    fl = run_fleet(cfg, trainer, tasks, fleet,
                   replay=ReplaySpec(capacity=32), device="analog_state")
    assert set(fl["het"]) == {"prog_sigma", "read_sigma", "write_sigma",
                              "drift_rate"}
    r0, r1 = (fl["per_device"][i]["R_full"] for i in range(2))
    assert not np.array_equal(r0, r1)


def test_het_profile_requires_stateful_backend(small_setup):
    cfg, trainer, tasks = small_setup
    with pytest.raises(ValueError, match="analog_state"):
        run_fleet(cfg, trainer, tasks,
                  FleetSpec(n_devices=2, het_profile="mild"),
                  device="ideal")


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------

def test_distribution_schema():
    d = distribution([1.0, 2.0, 3.0, 4.0])
    assert set(d) == {"mean", "std", "min", "max", "p50", "p95", "p99"}
    assert d["min"] == 1.0 and d["max"] == 4.0
    assert d["p50"] == pytest.approx(2.5)


def test_fleet_aggregate_sections(small_setup):
    """Aggregate over a metered fleet run: energy, lifetime and learning
    sections all present with the full percentile schema, and the
    per-device energy books sum back to the fleet totals."""
    cfg, trainer, tasks = small_setup
    backend = get_backend("wbs")
    backend.telemetry.enable()
    try:
        fleet = FleetSpec(n_devices=2, het_profile="none", seed=2)
        fl = run_fleet(cfg, trainer, tasks, fleet,
                       replay=ReplaySpec(capacity=32), device=backend)
        agg = fleet_aggregate(fl)
    finally:
        backend.telemetry.disable()
    for key in ("average_accuracy", "forgetting", "power_mw",
                "gops_per_w", "lifetime_years", "lifetime_hot_tail_years",
                "writes_per_device_update"):
        assert set(agg[key]) >= {"p50", "p95", "p99"}, key
    assert agg["n_devices"] == 2
    assert {"min_accuracy_device", "max_forgetting_device",
            "min_lifetime_device"} <= set(agg["hot_tail"])
    # ζ within-chip percentiles rode through the lifetime projection.
    assert set(agg["zeta_rate_percentiles"]) == {"p50", "p90", "p99"}


# ---------------------------------------------------------------------------
# Mesh-shape invariance (emulated host devices; subprocess because the
# device count must be fixed before jax import — same idiom as
# tests/test_moe_ep.py)
# ---------------------------------------------------------------------------

MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core.continual import ReplaySpec, TrainerSpec
    from repro.fleet import FleetSpec, run_fleet
    from repro.scenarios import build_scenario
    from repro.scenarios.sweep import scenario_miru_config

    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=1)
    fleet = FleetSpec(n_devices=8, het_profile="none", seed=3)

    runs = {}
    for shards in (1, 2, 8):
        out = run_fleet(cfg, trainer, tasks, fleet,
                        replay=ReplaySpec(capacity=32), device="ideal",
                        max_shards=shards)
        assert out["n_shards"] == shards, (shards, out["n_shards"])
        runs[shards] = out
    ref = runs[1]
    for shards in (2, 8):
        for i in range(8):
            np.testing.assert_array_equal(
                ref["per_device"][i]["R_full"],
                runs[shards]["per_device"][i]["R_full"])
        for name in ref["params"]:
            np.testing.assert_array_equal(
                np.asarray(ref["params"][name]),
                np.asarray(runs[shards]["params"][name]), name)
    print("MESH-INVARIANT-OK")
""")


@pytest.mark.slow
def test_mesh_shape_invariance():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "MESH-INVARIANT-OK" in proc.stdout


# ---------------------------------------------------------------------------
# Prepared-weights cache (the per-forward pad/scale hoist)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["wbs", "cmos"])
def test_prepared_weights_bitwise_neutral(name):
    """device_vmm through a prepare_weights cache is the same bits as
    the uncached call — the hoist moves work, never changes it."""
    backend = get_backend(name)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(jax.random.fold_in(key, 1), (24, 12))
    drive = jax.random.normal(jax.random.fold_in(key, 2), (4, 24))
    params = {"w_h": w}
    prepared = backend.prepare_weights(params)
    assert prepared is not None and "w_h" in prepared
    y_plain = backend.device_vmm(drive, w, key, tag="w_h")
    y_prep = backend.device_vmm(drive, w, key, tag="w_h",
                                prepared=prepared)
    np.testing.assert_array_equal(np.asarray(y_plain), np.asarray(y_prep))


def test_prepare_weights_default_none():
    assert get_backend("ideal").prepare_weights({"w": jnp.ones((4, 4))}) \
        is None
