"""Circuit cost model vs Table I / Fig. 5c / Fig. 5d."""
import pytest

from repro.analog.costmodel import HardwareConstants, M2RUCostModel


@pytest.fixture
def m():
    return M2RUCostModel()          # the paper's 28×100×10, 8-bit config


def test_step_latency_1_85us(m):
    assert m.step_latency_s() == pytest.approx(1.85e-6, rel=1e-6)


def test_throughput_19305_seq_per_s(m):
    assert m.throughput_seq_per_s(28) == pytest.approx(19305, rel=1e-3)


def test_15_gops(m):
    assert m.gops() == pytest.approx(15.0, rel=0.02)


def test_power_48_62_mw(m):
    assert m.power_w() * 1e3 == pytest.approx(48.62, rel=1e-3)


def test_training_power_56_97_mw(m):
    assert m.power_w(training=True) * 1e3 == pytest.approx(56.97, rel=1e-3)


def test_efficiency_312_gops_per_watt(m):
    # Paper reports 312; model yields 310 (0.6 % — the paper's quoted
    # GOPS is rounded to 15).
    assert m.gops_per_watt() == pytest.approx(312, rel=0.02)


def test_3_21_pj_per_op(m):
    assert m.pj_per_op() == pytest.approx(3.21, rel=0.02)


def test_29x_vs_digital(m):
    assert m.efficiency_gain_vs_digital() == pytest.approx(29.0, rel=1e-6)


def test_power_breakdown_analog_dominates(m):
    """Fig. 5d: ADCs + Op-Amps dominate the budget."""
    brk = m.power_breakdown_w()
    analog = brk["adc"] + brk["opamp"]
    assert analog > 0.6 * sum(brk.values())
    assert brk["adc"] > brk["opamp"] > brk["crossbar"]


def test_latency_linear_in_bits(m):
    """Fig. 5c: bit precision adds linearly (one cycle per bit/crossbar)."""
    import dataclasses
    lat = [dataclasses.replace(m, n_bits=nb).step_cycles()
           for nb in (2, 4, 8, 16)]
    diffs = [b - a for a, b in zip(lat, lat[1:])]
    assert diffs[0] * 2 == diffs[1]
    assert diffs[1] * 2 == diffs[2]


def test_tiling_caps_interpolation(m):
    """Fig. 5c: without tiling the serialized interpolation dominates and
    grows with n_h; with tiling it is capped at 16 cycles."""
    import dataclasses
    for nh in (100, 256, 512):
        tiled = dataclasses.replace(m, n_h=nh, tiled=True)
        untiled = dataclasses.replace(m, n_h=nh, tiled=False)
        assert tiled.interp_cycles() <= 16
        assert untiled.interp_cycles() == nh
        assert untiled.step_latency_s() > tiled.step_latency_s()


def test_scaling_with_hidden_size(m):
    """Latency grows with n_h untiled; only weakly tiled (Fig. 5c)."""
    import dataclasses
    t100 = dataclasses.replace(m, n_h=100, tiled=True).step_latency_s()
    t512 = dataclasses.replace(m, n_h=512, tiled=True).step_latency_s()
    u100 = dataclasses.replace(m, n_h=100, tiled=False).step_latency_s()
    u512 = dataclasses.replace(m, n_h=512, tiled=False).step_latency_s()
    assert (u512 / u100) > 3.0          # untiled scales ~linearly
    assert (t512 / t100) < 1.6          # tiled nearly flat


def test_lifespan_integration(m):
    yrs_dense = m.lifespan_years(1.0)
    yrs_sparse = m.lifespan_years(0.53)
    assert yrs_sparse > 1.8 * yrs_dense / 1.07
