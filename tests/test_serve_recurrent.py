"""Correctness suite for the continuous-batching recurrent serve engine.

The serving determinism contract (docs/serving.md): on a deterministic
substrate every batch lane is computed row-independently, so a request's
full output stream is bitwise identical regardless of which requests
ride along, which slot it lands in, how arrivals interleave, and how the
engine chunks its frames. Golden = solo serve (one request alone,
batch_slots=1, chunk=T).

Plus: scripted-clock latency attribution (queue-wait/decode split
asserted against hand-computed percentiles), the shared-telemetry-
accumulator pin and its ``fresh_meter`` escape hatch, and admission
control under a bounded queue.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core.miru import MiRUConfig, init_miru_params, miru_apply_readout
from repro.serve import (RecurrentServeConfig, RecurrentServeEngine,
                         TrafficSpec, make_arrivals, replay, request_frames,
                         serve_backend)

CFG = MiRUConfig(n_x=6, n_h=12, n_y=4)


@pytest.fixture(scope="module")
def params():
    return init_miru_params(jax.random.PRNGKey(0), CFG)


def _engine(params, **kw):
    kw.setdefault("device", "wbs")
    kw.setdefault("fresh_meter", True)
    return RecurrentServeEngine(CFG, RecurrentServeConfig(**kw), params)


def _solo_golden(params, spec: TrafficSpec) -> dict:
    """Serve every request alone — fresh single-slot engine per uid
    chain is wrong (state carries across a user's bursts), so replay
    each user's bursts in order through a batch_slots=1 engine."""
    out = {}
    engines: dict = {}
    for a, frames in replay(spec):
        eng = engines.get(a.uid)
        if eng is None:
            eng = engines[a.uid] = _engine(params, batch_slots=1,
                                           chunk=int(spec.frames_max))
        req = eng.submit(frames, uid=a.uid)
        eng.run_until_drained()
        out[a.rid] = np.asarray(req.logits)
    return out


# ---------------------------------------------------------------------------
# The determinism contract
# ---------------------------------------------------------------------------

def test_output_stream_invariant_to_batch_composition(params):
    """Co-batched serving (shared slab, interleaved arrivals, slot churn,
    eviction/reload) reproduces every request's solo output stream
    bitwise."""
    spec = TrafficSpec(n_requests=12, n_users=5, frames_min=3,
                       frames_max=10, n_x=CFG.n_x, seed=7)
    golden = _solo_golden(params, spec)
    eng = _engine(params, batch_slots=3, chunk=4)
    reqs = [eng.submit(frames, uid=a.uid) for a, frames in replay(spec)]
    eng.run_until_drained()
    assert eng.slab.evictions > 0, "scenario must exercise spill/reload"
    for a, req in zip(make_arrivals(spec), reqs):
        assert np.array_equal(np.asarray(req.logits), golden[a.rid]), \
            f"request {a.rid} diverged under co-batching"


def test_output_stream_invariant_to_slot_permutation(params):
    """Same traffic, submission order permuted → different slot
    assignments and co-residents, same per-request streams bitwise.
    Only single-burst users may be permuted freely, so each request gets
    its own uid here (same-user bursts must serialize in order — that
    ordering is pinned in test_same_user_bursts_serialize_in_order)."""
    spec = TrafficSpec(n_requests=6, frames_min=4, frames_max=8,
                       n_x=CFG.n_x, seed=3)
    traffic = list(replay(spec))
    streams = {}
    for perm_seed in (0, 1):
        order = np.random.default_rng(perm_seed).permutation(len(traffic))
        eng = _engine(params, batch_slots=4, chunk=3)
        reqs = {}
        for i in order:
            a, frames = traffic[i]
            reqs[a.rid] = eng.submit(frames, uid=f"r{a.rid}")
        eng.run_until_drained()
        streams[perm_seed] = {rid: np.asarray(r.logits)
                              for rid, r in reqs.items()}
    for rid in streams[0]:
        assert np.array_equal(streams[0][rid], streams[1][rid]), \
            f"request {rid} depends on submission order"


def test_output_stream_invariant_to_chunking(params):
    """The recurrence is causal: chunk width never changes a stream."""
    frames = request_frames(TrafficSpec(n_x=CFG.n_x, seed=11), rid=0,
                            n_frames=9)
    outs = []
    for chunk in (1, 4, 9):
        eng = _engine(params, batch_slots=2, chunk=chunk)
        req = eng.submit(frames, uid="u")
        eng.run_until_drained()
        outs.append(np.asarray(req.logits))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])


def test_matches_direct_device_recurrence(params):
    """The engine is the kernel: served logits == one fused
    device_recurrence call + readout, bitwise, including h0 resumption
    across a user's consecutive bursts."""
    bk = get_backend("wbs")
    f1 = request_frames(TrafficSpec(n_x=CFG.n_x, seed=5), 0, 6)
    f2 = request_frames(TrafficSpec(n_x=CFG.n_x, seed=5), 1, 4)
    eng = _engine(params, batch_slots=2, chunk=3)
    r1 = eng.submit(f1, uid="u")
    r2 = eng.submit(f2, uid="u")             # same user: state carries
    eng.run_until_drained()
    h_all, _, _ = bk.device_recurrence(params, CFG, jnp.asarray(f1)[None],
                                       jax.random.PRNGKey(0))
    assert np.array_equal(np.asarray(r1.logits),
                          np.asarray(miru_apply_readout(params, CFG,
                                                        h_all[0])))
    h_all2, _, _ = bk.device_recurrence(params, CFG, jnp.asarray(f2)[None],
                                        jax.random.PRNGKey(0),
                                        h0=h_all[:, -1])
    assert np.array_equal(np.asarray(r2.logits),
                          np.asarray(miru_apply_readout(params, CFG,
                                                        h_all2[0])))


def test_pipeline_off_matches_pipeline_on(params):
    """Host↔device pipelining is a scheduling optimization only."""
    spec = TrafficSpec(n_requests=6, n_users=3, frames_min=3,
                       frames_max=7, n_x=CFG.n_x, seed=2)
    streams = {}
    for pipeline in (True, False):
        eng = _engine(params, batch_slots=2, chunk=4, pipeline=pipeline)
        reqs = [eng.submit(f, uid=a.uid) for a, f in replay(spec)]
        eng.run_until_drained()
        streams[pipeline] = [np.asarray(r.logits) for r in reqs]
    for a, b in zip(streams[True], streams[False]):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Scheduling semantics
# ---------------------------------------------------------------------------

def test_same_user_bursts_serialize_in_order(params):
    """Two bursts from one user must not co-batch (state hazard); the
    second runs after the first finishes, and a later user's request may
    overtake the blocked one."""
    eng = _engine(params, batch_slots=4, chunk=2)
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    a1 = eng.submit(request_frames(spec, 0, 6), uid="u")
    a2 = eng.submit(request_frames(spec, 1, 4), uid="u")
    b = eng.submit(request_frames(spec, 2, 2), uid="v")
    eng.step()
    assert a1.cursor > 0 and a2.cursor == 0 and b.cursor > 0
    eng.run_until_drained()
    assert a2.done and a1.t_done <= a2.t_admit


def test_admission_control_rejects_when_queue_full(params):
    eng = _engine(params, batch_slots=1, chunk=2, max_queue=2)
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    reqs = [eng.submit(request_frames(spec, i, 3), uid=f"u{i}")
            for i in range(5)]
    # slot admission happens at step time: all 5 queue-or-reject first
    assert [r.rejected for r in reqs] == [False, False, True, True, True]
    assert eng.rejected == 3
    eng.run_until_drained()
    assert sum(r.done for r in reqs) == 2
    assert eng.request_stats()["rejected"] == 3


def test_slab_pressure_spills_and_reloads(params):
    """More concurrent users than slots: LRU spill under pressure, and
    returning users' streams still match their solo goldens (covered by
    the invariance test; here pin the mechanism counters)."""
    spec = TrafficSpec(n_requests=10, n_users=6, frames_min=2,
                       frames_max=5, n_x=CFG.n_x, seed=13)
    eng = _engine(params, batch_slots=2, chunk=3)
    for a, f in replay(spec):
        eng.submit(f, uid=a.uid)
    eng.run_until_drained()
    st = eng.slab.stats()
    assert st["evictions"] > 0
    assert st["resident"] <= 2
    eng.slab.check()


# ---------------------------------------------------------------------------
# Scripted-clock latency attribution
# ---------------------------------------------------------------------------

class ScriptedClock:
    """Returns t0 + n*dt on the n-th call — latency arithmetic becomes
    exact, so histogram percentiles are hand-computable."""

    def __init__(self, t0: float = 100.0, dt: float = 1.0):
        self.t = t0 - dt
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


def test_scripted_clock_latency_split(params):
    """batch_slots=1 serializes three single-chunk requests; with a
    clock that advances 1 s per read, every timestamp is known in
    advance and the latency histograms must match exactly."""
    clock = ScriptedClock(t0=0.0, dt=1.0)
    eng = _engine(params, batch_slots=1, chunk=8, pipeline=False,
                  clock=clock)
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    reqs = [eng.submit(request_frames(spec, i, 3), uid=f"u{i}")
            for i in range(3)]
    # Clock reads so far: t_submit = 0, 1, 2.
    eng.run_until_drained()
    # Each engine step admits one request (slot frees only at retire):
    # step k reads admit(t) then finish(t+1). Admits at 3, 5, 7;
    # finishes at 4, 6, 8.
    assert [r.t_submit for r in reqs] == [0.0, 1.0, 2.0]
    assert [r.t_admit for r in reqs] == [3.0, 5.0, 7.0]
    assert [r.t_done for r in reqs] == [4.0, 6.0, 8.0]
    # queue_wait = admit - submit = [3, 4, 5] s → ms
    qw = eng.queue_wait
    assert (qw.p50, qw.percentile(0), qw.percentile(100)) == \
        (4000.0, 3000.0, 5000.0)
    # decode = done - admit = 1 s each
    assert eng.decode.summary()["p50"] == 1000.0
    assert eng.decode.summary()["p99"] == 1000.0
    # end-to-end = [4, 5, 6] s
    lat = eng.latency.summary()
    assert lat["count"] == 3 and lat["p50"] == 5000.0
    assert lat["min"] == 4000.0 and lat["max"] == 6000.0
    assert lat["p99"] == pytest.approx(5980.0)   # linear interpolation
    stats = eng.request_stats()
    assert stats["latency_ms"]["p50"] == 5000.0
    # throughput over the scripted span: 3 requests in (8 - 0) s
    assert stats["sequences_per_s"] == pytest.approx(3 / 8)


def test_lm_engine_scripted_clock(params):
    """The LM ServeEngine honors the same injectable clock: queue-wait /
    decode / end-to-end split asserted under a scripted clock."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_smoke_config("qwen2-0.5b")
    lm_params = lm.init_params(jax.random.PRNGKey(0), cfg)
    clock = ScriptedClock(t0=0.0, dt=1.0)
    eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=16,
                                       eos_token=-1, clock=clock), lm_params)
    r1 = eng.submit([1, 2], max_new=2)       # t_submit = 0
    r2 = eng.submit([3, 4], max_new=2)       # t_submit = 1
    eng.run_until_drained()
    # First step admits both (reads 2, 3); both finish at the second
    # decode step (reads 4, 5).
    assert (r1.t_submit, r2.t_submit) == (0.0, 1.0)
    assert (r1.t_admit, r2.t_admit) == (2.0, 3.0)
    assert (r1.t_done, r2.t_done) == (4.0, 5.0)
    assert eng.queue_wait.summary()["p50"] == 2000.0
    assert eng.decode.summary()["p50"] == 2000.0
    assert eng.latency.summary()["min"] == 4000.0
    assert eng.latency.summary()["max"] == 4000.0


# ---------------------------------------------------------------------------
# Telemetry isolation
# ---------------------------------------------------------------------------

def test_engines_share_accumulator_per_backend_name(params):
    """Documented behavior: two engines resolving the same backend
    *name* (without fresh_meter) share one telemetry accumulator — a
    second engine's traffic lands on the first engine's counters."""
    bk = serve_backend("wbs")
    bk.telemetry.reset()
    was_enabled = bk.telemetry.enabled
    try:
        e1 = _engine(params, fresh_meter=False, meter=True, batch_slots=1)
        e2 = _engine(params, fresh_meter=False, meter=True, batch_slots=1)
        assert e1.backend is e2.backend is bk
        spec = TrafficSpec(n_x=CFG.n_x, seed=0)
        e1.submit(request_frames(spec, 0, 4), uid="a")
        e1.run_until_drained()
        after_e1 = e1.telemetry.total("macs")
        e2.submit(request_frames(spec, 1, 4), uid="b")
        e2.run_until_drained()
        assert e1.telemetry.total("macs") > after_e1, \
            "e2's traffic must land on the shared accumulator"
    finally:
        bk.telemetry.reset()
        if not was_enabled:
            bk.telemetry.disable()


def test_fresh_meter_isolates_counters(params):
    """The escape hatch: fresh_meter engines own a private backend, so
    concurrent engines meter independently."""
    e1 = _engine(params, meter=True, batch_slots=1)   # fresh_meter=True
    e2 = _engine(params, meter=True, batch_slots=1)
    assert e1.backend is not e2.backend
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    e1.submit(request_frames(spec, 0, 4), uid="a")
    e1.run_until_drained()
    assert e1.telemetry.total("macs") > 0
    assert e2.telemetry.total("macs") == 0, \
        "fresh_meter engine must not see the other engine's activity"
    # and the shared per-name instance saw nothing either
    assert serve_backend("wbs").telemetry.total("macs") == 0


def test_metered_energy_report(params):
    """pJ/request allocation: shares proportional to frames served, all
    finite, summing to the metered total."""
    eng = _engine(params, meter=True, batch_slots=2, chunk=4)
    spec = TrafficSpec(n_requests=5, n_users=3, frames_min=3,
                       frames_max=8, n_x=CFG.n_x, seed=1)
    for a, f in replay(spec):
        eng.submit(f, uid=a.uid)
    eng.run_until_drained()
    en = eng.request_stats()["energy"]
    assert en["total_j"] > 0 and np.isfinite(en["gops_per_w"])
    assert en["pj_per_request"]["count"] == 5
    assert en["power_mw"] > 0


# ---------------------------------------------------------------------------
# Graceful degradation (repro.faults): deadlines, chip failure
# ---------------------------------------------------------------------------

class ListClock:
    """Returns a scripted sequence of times (last value repeats)."""

    def __init__(self, vals):
        self.vals = list(vals)
        self.reads = 0

    def __call__(self) -> float:
        v = self.vals[min(self.reads, len(self.vals) - 1)]
        self.reads += 1
        return v


def test_deadline_times_out_stale_requests(params):
    """Queue wait beyond ``deadline_s`` drops the request with
    ``timed_out=True`` at admission instead of serving it. Scripted
    clock: submits at t=0,1,2 (same user → serialize); the first
    admission pass runs at t=3 and admits request 0; every later pass
    sees t=10, so requests 1 and 2 (ages 9 and 8 > 5) time out."""
    clock = ListClock([0.0, 1.0, 2.0, 3.0, 3.0] + [10.0] * 60)
    eng = _engine(params, batch_slots=2, chunk=4, deadline_s=5.0,
                  clock=clock)
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    reqs = [eng.submit(request_frames(spec, i, 5), uid="u")
            for i in range(3)]
    eng.run_until_drained()
    st = eng.request_stats()
    assert st["requests"] == 1 and st["timed_out"] == 2
    assert reqs[0].done and not reqs[0].timed_out
    assert reqs[1].timed_out and reqs[1].done and reqs[1].t_done == 10.0
    assert reqs[2].timed_out
    assert eng.pending == 0


def test_no_deadline_keeps_clock_read_sequence(params):
    """Deadline-free configs must not read the clock in _admit — the
    scripted-clock latency tests' exact read counts are a contract."""
    clock = ListClock(list(range(100)))
    eng = _engine(params, batch_slots=2, chunk=4, clock=clock)
    spec = TrafficSpec(n_x=CFG.n_x, seed=0)
    eng.submit(request_frames(spec, 0, 4), uid="a")
    eng.run_until_drained()
    # exactly t_submit, t_admit, t_done
    assert clock.reads == 3


def test_chip_failure_outputs_bitwise_identical(params):
    """A chip death mid-dispatch aborts before the RNG is consumed,
    migrates every slab row through the host-spill path, and retries —
    so every request's output stream is bitwise identical to the
    failure-free run, and the failure is visible only in the
    counters."""
    spec = TrafficSpec(n_requests=8, n_users=3, frames_min=4,
                       frames_max=11, n_x=CFG.n_x, seed=5)

    def run(fail_at=()):
        eng = _engine(params, batch_slots=2, chunk=4,
                      fail_at_steps=fail_at)
        reqs = [eng.submit(f, uid=a.uid) for a, f in replay(spec)]
        eng.run_until_drained()
        eng.slab.check()
        return eng, reqs

    e0, r0 = run()
    e1, r1 = run(fail_at=(1, 4))
    for a, b in zip(r0, r1):
        assert np.array_equal(np.asarray(a.logits), np.asarray(b.logits))
    s0, s1 = e0.request_stats(), e1.request_stats()
    assert s0["chip_failures"] == 0 and s0["retried"] == 0
    assert s1["chip_failures"] == 2 and s1["retried"] >= 2
    assert s1["requests"] == s0["requests"] == spec.n_requests
    # the replacement slab reloaded the migrated rows
    assert s1["slab"]["reloads"] > 0


def test_chip_failure_migrates_spilled_rows(params):
    """Rows spilled to host before the failure survive the migration:
    the evicted user's stream continues bitwise on the replacement
    chip."""
    spec = TrafficSpec(n_requests=10, n_users=6, frames_min=3,
                       frames_max=9, n_x=CFG.n_x, seed=2)

    def run(fail_at=()):
        eng = _engine(params, batch_slots=2, chunk=3,
                      fail_at_steps=fail_at)
        reqs = [eng.submit(f, uid=a.uid) for a, f in replay(spec)]
        eng.run_until_drained()
        eng.slab.check()
        return eng, reqs

    e0, r0 = run()
    assert e0.slab.evictions > 0, "scenario must exercise spill"
    e1, r1 = run(fail_at=(3,))
    for a, b in zip(r0, r1):
        assert np.array_equal(np.asarray(a.logits), np.asarray(b.logits))


def test_lm_engine_deadline(params):
    """The LM ServeEngine's deadline: a queued request whose wait
    exceeds ``deadline_s`` is dropped with ``timed_out=True``."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_smoke_config("qwen2-0.5b")
    lm_params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # both requests fit the slots: no timeout at deadline_s=None-like
    clock = ListClock([0.0, 1.0, 20.0] + [20.0] * 40)
    eng = ServeEngine(cfg, ServeConfig(batch_slots=1, max_len=16,
                                       eos_token=-1, deadline_s=5.0,
                                       clock=clock), lm_params)
    r1 = eng.submit([1, 2], max_new=2)       # t_submit = 0
    r2 = eng.submit([3, 4], max_new=2)       # t_submit = 1
    eng.run_until_drained()
    # admission pass at t=20: both exceed the 5 s deadline.
    assert r1.timed_out and r2.timed_out
    assert eng.timed_out == 2
    assert eng.request_stats()["timed_out"] == 2
    assert eng.request_stats()["requests"] == 0
