"""analog_state retention-drift cadence (ROADMAP item): drift ticks on a
configurable update cadence instead of per-update, with the same total
relaxation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analog.crossbar import CrossbarSpec
from repro.backends import DeviceSpec, get_backend


def _backend(rate=0.05, cadence=1, write_sigma=0.0):
    spec = CrossbarSpec(write_sigma=write_sigma, prog_sigma=0.0,
                        read_sigma=0.0, drift_rate=rate, w_clip=1.0,
                        drift_cadence=cadence)
    return get_backend("analog_state",
                       spec=DeviceSpec(input_bits=8, adc_bits=8,
                                       weight_clip=1.0, crossbar=spec))


def _relax(cadence, n_updates, rate=0.05):
    """n_updates zero-magnitude updates (pure retention) at a cadence."""
    be = _backend(rate=rate, cadence=cadence)
    params = {"w_h": jnp.array([[0.8, -0.6, 0.3]])}
    state = be.init_device_state(params, jax.random.PRNGKey(0))
    zeros = {"w_h": jnp.zeros_like(params["w_h"])}
    for i in range(n_updates):
        params, _, state = be.device_apply_update(
            params, zeros, jax.random.PRNGKey(i), state=state)
    return np.asarray(params["w_h"]), state


@pytest.mark.parametrize("cadence", [2, 3, 4])
def test_drift_magnitude_is_cadence_invariant(cadence):
    """After N updates (cadence | N), total relaxation equals the
    per-update baseline: (1−rate)^N either way."""
    n = 12
    w1, _ = _relax(1, n)
    wk, _ = _relax(cadence, n)
    np.testing.assert_allclose(wk, w1, rtol=1e-5)
    np.testing.assert_allclose(
        w1, np.array([[0.8, -0.6, 0.3]]) * (0.95 ** n), rtol=1e-4)


def test_cadence_one_keeps_legacy_state_shape():
    """Default cadence keeps the device-state pytree exactly as before —
    pairs only, no tick counter (checkpoint compatibility)."""
    _, state1 = _relax(1, 2)
    assert set(state1) == {"w_h"}
    _, state3 = _relax(3, 2)
    assert set(state3) == {"w_h", "_ticks"}
    assert int(state3["_ticks"]) == 2


def test_cadence_invariant_under_scan():
    """The counter lives in the device state, so the cadence fires
    correctly when the train loop is a lax.scan (the compiled sweep)."""
    def run(cadence):
        be = _backend(cadence=cadence)
        params = {"w_h": jnp.array([[0.8, -0.6, 0.3]])}
        state = be.init_device_state(params, jax.random.PRNGKey(0))
        zeros = {"w_h": jnp.zeros_like(params["w_h"])}

        @jax.jit
        def go(params, state):
            def body(c, k):
                p, s = c
                p, _, s = be.device_apply_update(p, zeros, k, state=s)
                return (p, s), None
            keys = jax.random.split(jax.random.PRNGKey(7), 12)
            (p, _), _ = jax.lax.scan(body, (params, state), keys)
            return p

        return np.asarray(go(params, state)["w_h"])

    np.testing.assert_allclose(run(3), run(1), rtol=1e-5)


def test_writes_compose_with_cadence():
    """Written devices still land their (noisy) deltas on non-drift
    updates; unwritten entries stay pure retention."""
    be = _backend(rate=0.1, cadence=2)
    params = {"w_h": jnp.array([[0.5, -0.5]])}
    state = be.init_device_state(params, jax.random.PRNGKey(0))
    dw = {"w_h": jnp.array([[0.1, 0.0]])}
    p1, applied, state = be.device_apply_update(
        params, dw, jax.random.PRNGKey(1), state=state)
    # Update 1: no drift fires (cadence 2); only column 0 written.
    assert float(applied["w_h"][0, 1]) == 0.0
    assert float(p1["w_h"][0, 0]) == pytest.approx(0.6, abs=1e-6)
    assert float(p1["w_h"][0, 1]) == pytest.approx(-0.5, abs=1e-6)
    zeros = {"w_h": jnp.zeros_like(params["w_h"])}
    p2, _, state = be.device_apply_update(
        p1, zeros, jax.random.PRNGKey(2), state=state)
    # Update 2: the cadence fires 2 ticks → (1-0.1)² on both devices.
    np.testing.assert_allclose(np.asarray(p2["w_h"]),
                               np.asarray(p1["w_h"]) * 0.81, rtol=1e-5)


def test_drift_ticks_metered():
    """Telemetry meters the cadence-amortized tick rate: N updates at any
    cadence k (k | N) record N drift ticks."""
    for cadence in (1, 3):
        be = _backend(cadence=cadence)
        be.telemetry.enable()
        params = {"w_h": jnp.array([[0.4]])}
        state = be.init_device_state(params, jax.random.PRNGKey(0))
        zeros = {"w_h": jnp.zeros_like(params["w_h"])}

        def step_fn(p, s, dw, k):
            # dw enters as a jit argument — a tracer, like the trainer's
            # computed updates — so the tick delta lands in the pending
            # buffer and flushes once per execution.
            out = be.device_apply_update(p, dw, k, state=s)
            be.telemetry.emit_pending()     # the train step's flush point
            return out

        step = jax.jit(step_fn)
        for i in range(6):
            params, _, state = step(params, state, zeros,
                                    jax.random.PRNGKey(i))
        assert be.telemetry.total("drift_ticks") == 6, cadence


def test_no_drift_no_ticks():
    be = _backend(rate=0.0, cadence=1, write_sigma=0.1)
    be.telemetry.enable()
    params = {"w_h": jnp.array([[0.4]])}
    state = be.init_device_state(params, jax.random.PRNGKey(0))
    params, _, state = be.device_apply_update(
        params, {"w_h": jnp.array([[0.1]])}, jax.random.PRNGKey(1),
        state=state)
    assert be.telemetry.total("drift_ticks") == 0
