"""EP/shard_map MoE == global sort-based MoE, on a real multi-device mesh.

Runs in a subprocess with 8 forced host devices (the main test process
must keep the single real device for everything else).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed import ShardingContext, sharding_scope
    from repro.models import moe as moe_mod

    # Case 1 — exchange mode: 4 experts % 2 (model axis) == 0. Ample
    # capacity so the two dispatch algorithms drop nothing.
    # Case 2 — replicated mode: 3 experts ∤ 2, tiny bank → fully local.
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for n_experts, top_k in ((4, 2), (3, 2)):
        cfg = dataclasses.replace(get_smoke_config("granite-moe-3b-a800m"),
                                  n_experts=n_experts, top_k=top_k,
                                  capacity_factor=8.0)
        p = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))

        y_global = moe_mod.moe_ffn(p, cfg, x)      # no context → global

        ctx = ShardingContext(mesh=mesh, batch_axes=("data",),
                              sequence_parallel=True, moe_mode="ep")
        with sharding_scope(ctx):
            fn = jax.jit(
                lambda p_, x_, c=cfg: moe_mod.moe_ffn(cfg=c, p=p_, x=x_),
                in_shardings=(None,
                              NamedSharding(mesh, P("data", "model",
                                                    None))),
                out_shardings=NamedSharding(mesh, P("data", "model",
                                                    None)))
            y_ep = fn(p, x)

        err = float(jnp.abs(y_global - y_ep).max())
        denom = float(jnp.abs(y_global).max())
        print("ERR", n_experts, err, denom)
        assert err < 1e-4 * max(denom, 1.0), (n_experts, err, denom)
    print("OK")
""")


def test_ep_moe_matches_global_multidevice():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
