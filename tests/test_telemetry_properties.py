"""Property-based tests for the Telemetry pending-buffer protocol
(satellite of the observability PR).

The accumulator's exactness contract under jit (meters.py): deltas
recorded at trace time land in a pending buffer, multiplied by the
active ``scaled`` scopes, and ``emit_pending`` drains them into one
io_callback that fires once per *execution* of the compiled program —
so the counters equal delta × Π(scales) × executions regardless of how
many times XLA retraces or how the scopes nest. Properties over the
scaled × deferred × recompile matrix:

  exactness       counters = delta · Π(scales) · n_executions
  recompile       a retrace (new shape) drains its own pending — traces
                  never double-count each other's deltas
  deferred        interior flushes inside ``deferred()`` are suppressed;
                  exactly one top-level flush counts everything once
  rollback        a trace aborted inside ``deferred()`` restores the
                  pending buffer to its entry state (no leakage into the
                  next successful trace)
  scope unwind    ``scaled`` restores the multiplier on exception
  concrete path   records with a concrete anchor count immediately,
                  still scale-multiplied, and never touch the pending
                  buffer
"""
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.telemetry.meters import Telemetry


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3),
       st.booleans())
def test_scaled_jit_exactness(scale_a, scale_b, n_exec, nested):
    """delta × Π(scales) × executions, for flat and nested scopes."""
    tele = Telemetry(enabled=True)

    def f(x):
        if nested:
            with tele.scaled(scale_a):
                with tele.scaled(scale_b):
                    tele.record({"macs/w": 2}, anchor=x)
        else:
            with tele.scaled(scale_a * scale_b):
                tele.record({"macs/w": 2}, anchor=x)
        tele.emit_pending()
        return x * 2.0

    jf = jax.jit(f)
    for i in range(n_exec):
        jf(jnp.float32(i)).block_until_ready()
    assert tele.snapshot().get("macs/w", 0) == 2 * scale_a * scale_b \
        * n_exec
    assert tele._pending == {}


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3))
def test_recompile_each_trace_counts_once(scale, n_shapes, n_exec):
    """Each retrace (distinct input shape) drains its own pending buffer:
    total = Σ_shapes delta · scale · executions_of_that_shape."""
    tele = Telemetry(enabled=True)

    def f(x):
        with tele.scaled(scale):
            tele.record({"vmm_rows/t": 5}, anchor=x)
        tele.emit_pending()
        return x.sum()

    jf = jax.jit(f)
    for shape in range(1, n_shapes + 1):     # each shape → one retrace
        for i in range(n_exec):
            jf(jnp.ones((shape,)) * i).block_until_ready()
    assert tele.snapshot().get("vmm_rows/t", 0) == \
        5 * scale * n_shapes * n_exec


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(0, 3))
def test_deferred_suppresses_interior_flushes(scale, n_exec, n_interior):
    """A metered sub-function that flushes itself, traced inside a
    ``deferred()`` scope: its interior emit_pending must be a no-op and
    the single top-level flush counts everything exactly once."""
    tele = Telemetry(enabled=True)

    def f(x):
        with tele.deferred():
            with tele.scaled(scale):
                tele.record({"macs/a": 3}, anchor=x)
                for _ in range(n_interior):
                    tele.emit_pending()      # suppressed, not dropped
        tele.emit_pending()                  # the one real flush
        return x + 1.0

    jf = jax.jit(f)
    for i in range(n_exec):
        jf(jnp.float32(i)).block_until_ready()
    assert tele.snapshot().get("macs/a", 0) == 3 * scale * n_exec
    assert tele._pending == {}


def test_deferred_exception_rolls_back_pending():
    """A trace aborted inside ``deferred()`` (shape error, interrupt)
    restores the pending buffer: the partial trace's deltas must not
    leak into the next successful trace's flush."""
    tele = Telemetry(enabled=True)

    def seed(x):
        tele.record({"macs/kept": 1}, anchor=x)
        return x

    jax.make_jaxpr(seed)(1.0)               # pending: {"macs/kept": 1}
    entry = dict(tele._pending)

    def aborts(x):
        tele.record({"macs/leaked": 7}, anchor=x)
        raise RuntimeError("trace aborted")

    with pytest.raises(RuntimeError, match="trace aborted"):
        with tele.deferred():
            jax.make_jaxpr(aborts)(1.0)
    assert tele._pending == entry            # rollback, no leakage
    assert not tele._deferred                # flag restored too

    # The surviving pending flushes normally afterwards.
    def ok(x):
        tele.emit_pending()
        return x * 1.0

    jax.jit(ok)(jnp.float32(0)).block_until_ready()
    snap = tele.snapshot()
    assert snap.get("macs/kept", 0) == 1
    assert "macs/leaked" not in snap


def test_scaled_restores_multiplier_on_exception():
    tele = Telemetry(enabled=True)
    with pytest.raises(ValueError):
        with tele.scaled(8):
            raise ValueError("boom")
    assert tele._scale == 1
    tele.record({"macs/x": 1})               # concrete: immediate
    assert tele.snapshot()["macs/x"] == 1    # not ×8


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4))
def test_concrete_anchor_counts_immediately(scale, delta):
    tele = Telemetry(enabled=True)
    with tele.scaled(scale):
        tele.record({"adc_conversions/h": delta}, anchor=None)
    assert tele._pending == {}
    assert tele.counters["adc_conversions/h"] == delta * scale


def test_disabled_is_inert():
    tele = Telemetry(enabled=False)
    with tele.scaled(4), tele.deferred():
        tele.record({"macs/x": 3}, anchor=None)
    tele.emit_pending()
    assert tele.snapshot() == {} and tele._pending == {}
