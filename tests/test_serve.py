"""Serving engine: slots, continuous batching, determinism."""
import jax

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine


def _engine(slots=4, max_len=32):
    cfg = get_smoke_config("qwen2-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(cfg, ServeConfig(batch_slots=slots, max_len=max_len,
                                        eos_token=-1), params), cfg


def test_single_request():
    eng, cfg = _engine()
    req = eng.submit([1, 2, 3], max_new=5)
    eng.run_until_drained()
    assert req.done
    assert len(req.tokens) == 5
    assert all(0 <= t < cfg.vocab for t in req.tokens)


def test_more_requests_than_slots():
    eng, _ = _engine(slots=2)
    reqs = [eng.submit([i + 1, i + 2], max_new=4) for i in range(5)]
    eng.run_until_drained()
    assert all(r.done and len(r.tokens) == 4 for r in reqs)


def test_greedy_deterministic():
    outs = []
    for _ in range(2):
        eng, _ = _engine()
        req = eng.submit([5, 6, 7, 8], max_new=6)
        eng.run_until_drained()
        outs.append(req.tokens)
    assert outs[0] == outs[1]


def test_prompt_conditioning_changes_output():
    eng, _ = _engine()
    r1 = eng.submit([1, 2, 3, 4], max_new=6)
    r2 = eng.submit([90, 91, 92, 93], max_new=6)
    eng.run_until_drained()
    assert r1.tokens != r2.tokens
