"""Per-arch smoke tests (reduced configs, real CPU step) + layer units.

Brief requirement (f): every assigned architecture instantiates a reduced
config and runs one forward/train step on CPU asserting output shapes and
no NaNs; plus prefill/decode consistency and component-level checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import lm
from repro.optim import adamw, apply_updates

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1)),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.random((B, 8, cfg.d_model)), cfg.dtype)
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.random((B, cfg.n_frontend_tokens, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = lm.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    l0 = None
    for i in range(3):
        params, opt_state, loss = step(params, opt_state)
        assert bool(jnp.isfinite(loss)), arch
        l0 = float(loss) if l0 is None else l0
    assert float(loss) < l0 + 0.5      # not diverging


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, MAX = 2, 12
    enc_len = 8 if cfg.is_encoder_decoder else 0
    caches = lm.init_cache(cfg, B, MAX, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        caches["cross_k"] = jnp.full_like(caches["cross_k"], 0.1)
        caches["cross_v"] = jnp.full_like(caches["cross_v"], 0.1)
        caches["enc_len"] = jnp.full((B,), enc_len, jnp.int32)
    toks = jnp.ones((B, 1), jnp.int32)
    for t in range(3):
        logits, caches = lm.decode_step(params, cfg, caches, toks,
                                        jnp.int32(t))
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen3-4b",
                                  "mamba2-370m"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode reproduces the full forward (caches exact up
    to bf16 cache rounding; SSD recurrence == chunked scan)."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 9
    toks = (jnp.arange(B * S).reshape(B, S) * 7) % cfg.vocab
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    full = lm.forward(params, cfg, batch)
    caches = lm.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = lm.decode_step(params, cfg, caches, toks[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(lg[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    tol = 1e-4 if arch == "mamba2-370m" else 5e-2   # bf16 KV rounding
    assert float(jnp.abs(full - dec).max()) < tol


def test_moe_capacity_and_balance():
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("granite-moe-3b-a800m")
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y = moe_mod.moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    stats = moe_mod.moe_load_stats(p, cfg, x)
    assert stats["frac_per_expert"].shape == (cfg.n_experts,)
    np.testing.assert_allclose(float(stats["frac_per_expert"].sum()), 1.0,
                               rtol=1e-5)


def test_moe_matches_dense_expert_eval():
    """With capacity ample and k=E, MoE == mean over all experts (weights
    uniform when router logits are equal)."""
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(get_smoke_config("granite-moe-3b-a800m"),
                              n_experts=2, top_k=2, capacity_factor=4.0)
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))     # uniform gates
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    y = moe_mod.moe_ffn(p, cfg, x)
    xt = x.reshape(-1, cfg.d_model)
    outs = []
    for e in range(2):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    want = (0.5 * outs[0] + 0.5 * outs[1]).reshape(x.shape)
    np.testing.assert_allclose(y, want, rtol=2e-2, atol=2e-3)


def test_ssd_chunked_vs_recurrent():
    """SSD chunked scan == step-by-step recurrence (state-space duality)."""
    from repro.models.ssm import ssd_chunked, ssd_recurrent_step
    b, l, h, p, g, n = 2, 13, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jnp.log(jnp.linspace(1, 4, h))
    B = jax.random.normal(ks[2], (b, l, g, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    y_chunk, final = ssd_chunked(x, dt, a_log, B, C, chunk=4)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        y_t, state = ssd_recurrent_step(state, x[:, t], dt[:, t], a_log,
                                        B[:, t], C[:, t])
        ys.append(y_t)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_rec, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(final, state, rtol=2e-4, atol=2e-4)


def test_int8_kv_cache_quantizer():
    from repro.models.attention import (CacheSpec, cache_insert,
                                        cache_read, init_kv_cache)
    cfg = get_smoke_config("yi-34b")
    spec = CacheSpec(batch=2, max_len=4, dtype="int8")
    cache = init_kv_cache(cfg, spec)
    kvd = cfg.n_kv_heads * cfg.hd()
    k_new = jax.random.normal(jax.random.PRNGKey(0), (2, 1, kvd))
    v_new = jax.random.normal(jax.random.PRNGKey(1), (2, 1, kvd))
    cache = cache_insert(cache, k_new, v_new, jnp.int32(0),
                         jax.random.PRNGKey(2))
    k_read, v_read = cache_read(cache)
    err = float(jnp.abs(k_read[:, 0].astype(jnp.float32)
                        - k_new[:, 0]).max())
    scale = float(jnp.abs(k_new).max()) / 127
    assert err <= 2 * scale     # within one quant step (stochastic)


def test_full_config_param_counts():
    """Full configs' parameter totals land near the published sizes."""
    expected = {
        "internlm2-1.8b": (1.6e9, 2.2e9),
        "qwen3-4b": (3.5e9, 4.6e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "yi-34b": (32e9, 36e9),
        "deepseek-v3-671b": (630e9, 700e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_counts()["total"]
        assert lo < n < hi, (arch, f"{n:.3e}")


def test_miru_mixer_option():
    """DESIGN §5: MiRU as an ablation sequence mixer inside the LM block."""
    cfg = dataclasses.replace(get_smoke_config("internlm2-1.8b"),
                              mixer="miru")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = lm.forward(params, cfg, batch)
    assert bool(jnp.isfinite(logits).all())
